"""Docs checker: validate fenced code blocks and internal links in markdown.

Checks, per file:

- ```python fenced blocks must be syntactically valid (compiled, not run);
- inline markdown links ``[text](target)`` with a relative target must
  point at an existing file or directory (resolved against the md file's
  directory; ``#anchor`` suffixes are stripped; absolute URLs and pure
  in-page anchors are skipped);
- fenced blocks must be balanced (every ``` opener has a closer).

A directory argument expands to every ``*.md`` beneath it (recursively,
sorted), so ``docs/`` keeps new documents covered without a CI edit.

Exit code 0 = clean, 1 = any failure (failures are listed).

Run:  python tools/check_docs.py README.md ISSUE.md ROADMAP.md docs/
"""
from __future__ import annotations

import pathlib
import re
import sys

# inline links; excludes images ![..](..) by requiring no leading !
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def iter_fences(lines):
    """Yield (language, start_line, [code lines]) per fenced block.

    Any line starting with ``` toggles fence state — the same rule for
    openers and closers, so an opener with trailing info text (e.g.
    ```python title=x) can't desync the parser.  Language is the first
    word after the opening backticks.
    """
    block, lang, start = None, None, 0
    for i, line in enumerate(lines, 1):
        s = line.strip()
        if s.startswith("```"):
            if block is None:
                info = s[3:].strip()
                block, lang, start = [], info.split()[0] if info else "", i
            else:
                yield lang, start, block
                block = None
        elif block is not None:
            block.append(line)
    if block is not None:
        yield "<unclosed>", start, block


def check_file(path: pathlib.Path):
    errors = []
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()

    in_code = False
    for lang, start, code in iter_fences(lines):
        if lang == "<unclosed>":
            errors.append(f"{path}:{start}: unclosed code fence")
            continue
        if lang == "python":
            try:
                compile("\n".join(code), f"{path}:{start}", "exec")
            except SyntaxError as e:
                errors.append(f"{path}:{start}: python block does not "
                              f"compile: {e.msg} (block line {e.lineno})")

    # strip fenced blocks before link checking (code may contain brackets)
    stripped, in_code = [], False
    for line in lines:
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if not in_code:
            stripped.append(line)
    for i, line in enumerate(stripped, 1):
        for target in LINK_RE.findall(line):
            if re.match(r"^[a-z]+://", target) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errors.append(f"{path}: broken link -> {target}")
    return errors


def expand(argv):
    """Resolve CLI args to md files: directories recurse to their *.md."""
    files, missing = [], []
    for name in argv:
        p = pathlib.Path(name)
        if p.is_dir():
            found = sorted(p.rglob("*.md"))
            if not found:
                missing.append(f"{p}: directory holds no .md files")
            files.extend(found)
        elif p.exists():
            files.append(p)
        else:
            missing.append(f"{p}: file not found")
    return files, missing


def main(argv):
    if not argv:
        print("usage: check_docs.py FILE.md|DIR [FILE.md|DIR ...]")
        return 2
    files, all_errors = expand(argv)
    for p in files:
        all_errors.extend(check_file(p))
    for e in all_errors:
        print(f"FAIL {e}")
    if not all_errors:
        print(f"docs OK ({len(files)} files)")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
