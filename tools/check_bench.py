#!/usr/bin/env python
"""CI perf-regression gate over the serving BENCH_*.json artifacts.

Compares the current run's benchmark JSONs against a baseline directory
(the previous main run's ``bench-json`` artifact, or — when none exists —
the committed repo-root ``BENCH_*.json`` files) and fails the build when a
guarded metric regresses past its threshold:

- throughput (``*_qps``) may not drop below 70% of baseline,
- tail wait (``p99_wait_us``) may not regress past 2x baseline,
- plus absolute invariants that hold at any scale: async results stay
  bit-identical to the oracle, deadline-bounded waits stay within budget,
  and the adaptive replay stays at zero overflow re-runs.

Relative rules only fire when the baseline ran the same workload shape
(same ``queries`` / ``n_docs``): the committed baselines are full-size
runs while CI runs smoke sizes, and comparing a 256-query QPS against a
64-query QPS would gate on corpus scale, not code.  Absolute rules always
fire.

Usage:
    python tools/check_bench.py --baseline-dir baseline \
        --current-dir bench-artifacts
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import re
import sys
from typing import Iterator, List, Optional, Tuple

# metric kinds: relative (need a same-scale baseline) ---------------------
#   min_ratio  current >= baseline * threshold        (bigger is better)
#   max_ratio  current <= max(baseline, floor) * threshold (smaller better)
# absolute (baseline-free invariants) -------------------------------------
#   min_abs    current >= threshold
#   max_abs    current <= threshold
#   equals     current == threshold


@dataclasses.dataclass(frozen=True)
class Rule:
    path: str            # dotted; "runs[deadline_us]" aligns list items
    kind: str
    threshold: float
    floor: float = 0.0   # max_ratio: noise floor for tiny baselines

    @property
    def relative(self) -> bool:
        return self.kind in ("min_ratio", "max_ratio")


RULES = {
    "BENCH_batched_qps.json": [
        Rule("batched_qps", "min_ratio", 0.70),
        Rule("speedup", "min_abs", 1.0),
    ],
    "BENCH_admission_latency.json": [
        Rule("runs[deadline_us].served_qps", "min_ratio", 0.70),
        Rule("runs[deadline_us].p99_wait_us", "max_ratio", 2.0, floor=200.0),
        Rule("runs[deadline_us].p99_wait_within_deadline", "equals", 1),
    ],
    "BENCH_adaptive_qps.json": [
        Rule("flusher.background_flusher.served_qps", "min_ratio", 0.70),
        Rule("flusher.background_flusher.p99_wait_us", "max_ratio", 2.0,
             floor=1000.0),
        Rule("identical_to_query_batch", "equals", 1),
        Rule("adaptive.rerun_calls_after", "max_abs", 0),
        Rule("adaptive.qps_ratio_vs_static", "min_abs", 0.70),
    ],
    "BENCH_sharded_qps.json": [],  # multi-device artifact: no gate yet
    "BENCH_concurrent_qps.json": [
        # overlapped-dispatch invariants (absolute — any workload scale):
        # both serving modes stay bit-identical to the query_batch oracle,
        # the overlapped run really overlaps (window high-water >= 2), and
        # the overlapped flusher does not COST throughput vs synchronous.
        # The ratio's upside is hardware-bound (~1.0x on a single-hardware-
        # thread host where all forced devices multiplex one core, rising
        # toward the replica-row bound with spare cores — see the benchmark
        # docstring), so the floor is a median-of-passes no-loss check with
        # a noise band, not a speedup claim.
        Rule("identical_to_query_batch", "equals", 1),
        Rule("modes.overlapped.overlap_high_water", "min_abs", 2),
        Rule("qps_ratio_overlapped_vs_sync", "min_abs", 0.85),
        Rule("modes.overlapped.served_qps", "min_ratio", 0.70),
        Rule("modes.overlapped.p99_wait_us", "max_ratio", 2.0,
             floor=1000.0),
    ],
    "BENCH_slo_burn.json": [
        # open-loop load-harness invariants (absolute — any workload scale):
        # every completed ticket stays bit-identical to the host oracle,
        # the overlap pipeline loses no buckets (dispatches == collects),
        # the wall-clock run leaks no threads and no query errors, burn at
        # the calibrated low-utilization operating point stays small, and
        # the overload point actually burns (the harness can tell the two
        # apart — a burn metric that never moves gates nothing).  Committed
        # full-size runs show ~0.01 calibrated / ~0.35 overload; the CI
        # bands (0.10 ceiling / 0.15 floor) leave smoke-size noise room.
        Rule("identical_to_oracle", "equals", 1),
        Rule("dispatch_collect_balanced", "equals", 1),
        Rule("thread_leak", "max_abs", 0),
        Rule("errors_total", "max_abs", 0),
        Rule("calibrated_burn_rate", "max_abs", 0.10),
        Rule("overload_burn_rate", "min_abs", 0.15),
        # throughput at the overload point is capacity-bound — relative
        # rule so a same-scale rerun can't silently lose half its serving
        # rate to a scheduling regression
        Rule("virtual_runs[rate_x].served_qps", "min_ratio", 0.70),
    ],
    "BENCH_boolean_qps.json": [
        # expression-DAG serving invariants (absolute — any workload
        # scale): every expression result through the async flusher stays
        # bit-identical to the numpy set-algebra oracle, the shared-
        # subtree workload actually exercises the subexpression cache
        # (nonzero hits AND at least one device-free host merge), and
        # throughput gates relatively on a same-scale baseline.
        Rule("identical_to_oracle", "equals", 1),
        Rule("subexpr_cache_hits", "min_abs", 1),
        Rule("subexpr_host_merges", "min_abs", 1),
        Rule("served_qps", "min_ratio", 0.70),
    ],
    "BENCH_suggest_qps.json": [
        # suggestion-service invariants (absolute — any workload scale):
        # every served top-K list stays bit-identical to the numpy oracle
        # (deterministic tie-break included, folded across the plain and
        # mesh sections), warmed serving never retraces a count
        # executable, the Zipf-head workload actually exercises the
        # result cache, and the hashbin pre-filter never keeps more than
        # it examined.  Throughput gates relatively on a same-scale
        # baseline for both the cached and the pure-device serving loops.
        Rule("identical_to_oracle", "equals", 1),
        Rule("count_traces_serving", "max_abs", 0),
        Rule("result_cache_hits", "min_abs", 1),
        Rule("prefilter_selectivity", "max_abs", 1.0),
        Rule("served_qps", "min_ratio", 0.70),
        Rule("device_qps", "min_ratio", 0.70),
    ],
    "BENCH_observability.json": [
        # observability invariants (absolute — any workload scale): the
        # instrumented stack is read-only (bit-identical to the oracle
        # with tracing on AND off), the traced drain leaks no open spans,
        # the post-pass registry cut is internally consistent and survives
        # both exposition round-trips, every executed signature carries a
        # CostModel-residual attribution, and tracing costs <= 5% QPS
        # (median-of-interleaved-passes vs metrics-only serving).
        Rule("identical_to_oracle", "equals", 1),
        Rule("leaked_spans", "max_abs", 0),
        Rule("snapshot_consistent", "equals", 1),
        Rule("trace_shape.all_requests_closed_once", "equals", 1),
        Rule("residual_coverage", "min_abs", 1.0),
        Rule("residuals_attributed", "equals", 1),
        Rule("overhead.qps_ratio_traced_vs_metrics", "min_abs", 0.95),
        Rule("served_qps.traced", "min_ratio", 0.70),
    ],
    "BENCH_mesh2d_qps.json": [
        # 2-D topology invariants (absolute — hold at any workload scale):
        # every layout stays bit-identical to the single-device baseline,
        # and the 2x2 layout never loses to the pure z-shard 1x4 on the
        # replica-friendly workload (committed full-size runs show >= 1.5x;
        # the CI floor is 1.0 to keep smoke runs noise-proof)
        Rule("identical_to_baseline", "equals", 1),
        Rule("speedup_2x2_vs_1x4", "min_abs", 1.0),
        Rule("layouts[layout].qps", "min_ratio", 0.70),
        Rule("baseline.qps", "min_ratio", 0.70),
    ],
}

_SCALE_KEYS = ("queries", "n_docs", "vocab", "vocab_kept", "distinct_pool",
               "set_size", "n_terms", "overlap", "n_sets", "top_k")


def _walk(base, cur, segs: List[str], label: str
          ) -> Iterator[Tuple[str, object, object]]:
    """Yield (label, baseline_value, current_value) for a rule path.

    A segment ``name[key]`` descends into the list ``name`` on both sides,
    pairing items whose ``key`` fields match (unpaired items are skipped —
    a changed sweep is a config change, not a regression).
    """
    if not segs:
        yield (label, base, cur)
        return
    seg, rest = segs[0], segs[1:]
    m = re.fullmatch(r"(\w+)\[(\w+)\]", seg)
    if m:
        name, align = m.group(1), m.group(2)
        base_items = {item.get(align): item for item in base.get(name, [])}
        for item in (cur or {}).get(name, []):
            mate = base_items.get(item.get(align))
            if mate is not None:
                yield from _walk(mate, item, rest,
                                 f"{label}.{name}[{align}={item.get(align)}]")
        return
    if not isinstance(cur, dict) or seg not in cur:
        return
    base_val = base.get(seg) if isinstance(base, dict) else None
    yield from _walk(base_val, cur[seg], rest, f"{label}.{seg}")


def _same_scale(base: dict, cur: dict) -> bool:
    return all(base.get(k) == cur.get(k)
               for k in _SCALE_KEYS if k in base or k in cur)


def check_file(name: str, base: Optional[dict], cur: dict) -> List[str]:
    """Return a list of human-readable failures for one benchmark file."""
    failures = []
    comparable = base is not None and _same_scale(base, cur)
    if base is not None and not comparable:
        print(f"  {name}: baseline ran a different workload shape "
              "(seed baseline?) — relative rules skipped")
    for rule in RULES.get(name, []):
        if rule.relative and not comparable:
            continue
        # absolute rules evaluate the current run alone: walk it against
        # itself so list alignment never depends on what the baseline has
        walk_base = cur if not rule.relative else (base or {})
        pairs = list(_walk(walk_base, cur, rule.path.split("."), name))
        if not pairs:
            # distinguish "metric gone from the current run" (a regression
            # of the benchmark contract) from "nothing aligned with the
            # baseline" (a sweep/config change — documented as skipped)
            if list(_walk(cur, cur, rule.path.split("."), name)):
                print(f"  {name}.{rule.path}: no baseline-aligned items "
                      "(sweep changed?) — skipped")
            else:
                failures.append(f"{name}.{rule.path}: metric missing")
            continue
        for label, b, c in pairs:
            if rule.kind == "min_abs" and not c >= rule.threshold:
                failures.append(
                    f"{label}: {c:.4g} < required {rule.threshold:.4g}")
            elif rule.kind == "max_abs" and not c <= rule.threshold:
                failures.append(
                    f"{label}: {c:.4g} > allowed {rule.threshold:.4g}")
            elif rule.kind == "equals" and not bool(c) == bool(rule.threshold):
                failures.append(
                    f"{label}: {c!r} != expected {bool(rule.threshold)!r}")
            elif rule.kind == "min_ratio":
                if b is None:
                    continue
                limit = b * rule.threshold
                if not c >= limit:
                    failures.append(
                        f"{label}: {c:.4g} < {rule.threshold:.0%} of "
                        f"baseline {b:.4g}")
            elif rule.kind == "max_ratio":
                if b is None:
                    continue
                limit = max(b, rule.floor) * rule.threshold
                if not c <= limit:
                    failures.append(
                        f"{label}: {c:.4g} > {rule.threshold:g}x baseline "
                        f"{b:.4g} (floor {rule.floor:g})")
    return failures


def check_dirs(baseline_dir: pathlib.Path,
               current_dir: pathlib.Path) -> List[str]:
    failures: List[str] = []
    checked = 0
    for name in sorted(RULES):
        cur_path = current_dir / name
        if not cur_path.exists():
            continue
        cur = json.loads(cur_path.read_text())
        base_path = baseline_dir / name
        base = (json.loads(base_path.read_text())
                if base_path.exists() else None)
        if base is None:
            print(f"  {name}: no baseline — absolute rules only")
        file_failures = check_file(name, base, cur)
        status = "FAIL" if file_failures else "ok"
        print(f"  {name}: {status}")
        failures.extend(file_failures)
        checked += 1
    if checked == 0:
        failures.append(f"no BENCH_*.json found under {current_dir}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", type=pathlib.Path, required=True)
    ap.add_argument("--current-dir", type=pathlib.Path, required=True)
    args = ap.parse_args()
    print(f"bench regression gate: {args.current_dir} vs "
          f"baseline {args.baseline_dir}")
    failures = check_dirs(args.baseline_dir, args.current_dir)
    if failures:
        print("\nREGRESSIONS:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("no benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
