"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitmap_filter import bitmap_filter_pallas
from repro.kernels.group_intersect import group_match_pallas


@pytest.mark.parametrize("k", [2, 3, 4])
@pytest.mark.parametrize("G", [1, 7, 128, 1000])
@pytest.mark.parametrize("m,W", [(1, 2), (2, 8), (3, 4), (4, 2)])
def test_bitmap_filter_sweep(k, G, m, W):
    rng = np.random.default_rng(k * 1000 + G + m * 10 + W)
    imgs = rng.integers(0, 1 << 32, size=(k, G, m, W),
                        dtype=np.uint64).astype(np.uint32)
    imgs[rng.random((k, G, m, W)) < 0.6] = 0
    x = jnp.asarray(imgs)
    out_ref = np.asarray(ref.bitmap_filter_ref(x))
    out_pal = np.asarray(bitmap_filter_pallas(x, interpret=True))
    np.testing.assert_array_equal(out_ref, out_pal)


@pytest.mark.parametrize("dtype", [np.uint32, np.int32])
def test_bitmap_filter_dtypes(dtype):
    rng = np.random.default_rng(0)
    imgs = rng.integers(0, 1 << 31, size=(2, 64, 2, 8), dtype=np.int64).astype(dtype)
    x = jnp.asarray(imgs)
    out_ref = np.asarray(ref.bitmap_filter_ref(x))
    out_pal = np.asarray(bitmap_filter_pallas(x, interpret=True))
    np.testing.assert_array_equal(out_ref, out_pal)


def test_bitmap_filter_all_pass_all_fail():
    ones = jnp.full((3, 32, 2, 4), 0xFFFFFFFF, dtype=jnp.uint32)
    assert np.asarray(bitmap_filter_pallas(ones, interpret=True)).all()
    zeros = jnp.zeros((3, 32, 2, 4), dtype=jnp.uint32)
    assert not np.asarray(bitmap_filter_pallas(zeros, interpret=True)).any()


@pytest.mark.parametrize("S", [1, 8, 57, 256])
@pytest.mark.parametrize("ga,gb", [(8, 8), (16, 32), (40, 16), (128, 128)])
def test_group_match_sweep(S, ga, gb):
    rng = np.random.default_rng(S * 100 + ga + gb)
    a = rng.integers(0, 500, size=(S, ga)).astype(np.int32)
    b = rng.integers(0, 500, size=(S, gb)).astype(np.int32)
    a[rng.random((S, ga)) < 0.25] = -1
    b[rng.random((S, gb)) < 0.25] = -1
    out_ref = np.asarray(ref.group_match_ref(jnp.asarray(a), jnp.asarray(b)))
    out_pal = np.asarray(
        group_match_pallas(jnp.asarray(a), jnp.asarray(b), interpret=True))
    np.testing.assert_array_equal(out_ref, out_pal)


@pytest.mark.parametrize("B", [1, 3, 9])
@pytest.mark.parametrize("G", [7, 128, 300])
def test_bitmap_filter_batched_folds_grid(B, G):
    """(B, k, G, m, W) batch axis == B independent unbatched calls."""
    rng = np.random.default_rng(B * 17 + G)
    imgs = rng.integers(0, 1 << 32, size=(B, 3, G, 2, 8),
                        dtype=np.uint64).astype(np.uint32)
    imgs[rng.random(imgs.shape) < 0.6] = 0
    x = jnp.asarray(imgs)
    out_ref = np.asarray(ref.bitmap_filter_ref(x))
    assert out_ref.shape == (B, G)
    out_pal = np.asarray(bitmap_filter_pallas(x, interpret=True))
    np.testing.assert_array_equal(out_ref, out_pal)
    for b in range(B):
        np.testing.assert_array_equal(
            out_ref[b], np.asarray(bitmap_filter_pallas(x[b], interpret=True)))


@pytest.mark.parametrize("B,S", [(1, 8), (4, 13), (6, 64)])
def test_group_match_batched_folds_rows(B, S):
    rng = np.random.default_rng(B * 31 + S)
    a = rng.integers(0, 300, size=(B, S, 16)).astype(np.int32)
    b = rng.integers(0, 300, size=(B, S, 24)).astype(np.int32)
    a[rng.random(a.shape) < 0.25] = -1
    b[rng.random(b.shape) < 0.25] = -1
    out_ref = np.asarray(ref.group_match_ref(jnp.asarray(a), jnp.asarray(b)))
    assert out_ref.shape == (B, S, 16)
    out_pal = np.asarray(
        group_match_pallas(jnp.asarray(a), jnp.asarray(b), interpret=True))
    np.testing.assert_array_equal(out_ref, out_pal)
    for i in range(B):
        np.testing.assert_array_equal(
            out_ref[i],
            np.asarray(group_match_pallas(jnp.asarray(a[i]), jnp.asarray(b[i]),
                                          interpret=True)))


def test_group_match_sentinel_never_matches():
    a = jnp.full((4, 8), -1, dtype=jnp.int32)
    b = jnp.full((4, 8), -1, dtype=jnp.int32)
    out = np.asarray(group_match_pallas(a, b, interpret=True))
    assert not out.any()


def test_ops_dispatch_paths_agree():
    rng = np.random.default_rng(7)
    imgs = jnp.asarray(rng.integers(0, 1 << 32, size=(2, 200, 2, 8),
                                    dtype=np.uint64).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ops.bitmap_filter(imgs, use_pallas=True)),
        np.asarray(ops.bitmap_filter(imgs, use_pallas=False)),
    )
    a = jnp.asarray(rng.integers(0, 99, size=(16, 16)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, 99, size=(16, 24)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.group_match(a, b, use_pallas=True)),
        np.asarray(ops.group_match(a, b, use_pallas=False)),
    )


def test_vocab_mask_roundtrip_and_and():
    rng = np.random.default_rng(3)
    v = 50257
    m1 = rng.random(v) < 0.3
    m2 = rng.random(v) < 0.5
    p1 = ops.pack_vocab_mask(jnp.asarray(m1))
    p2 = ops.pack_vocab_mask(jnp.asarray(m2))
    both = ops.vocab_mask_and(jnp.stack([p1, p2]))
    un = np.asarray(ops.unpack_vocab_mask(both, v))
    np.testing.assert_array_equal(un, m1 & m2)
