"""Property-based differential hardening of the serving stack.

Random postings + random query batches through the full serving pipeline
(plan -> bucket -> execute -> scatter) must be bit-identical to the numpy
host oracle — on the plain device engine, the z-sharded mesh, and the 2-D
replica x shard topology, and under forced capacity overflow (where the
enlarged re-run must keep results exact, never truncate).

Every property has two drivers: a seeded, always-running variant
(parametrized seeds — deterministic, no extra deps) and a hypothesis
``@given`` variant over the same check function (via the
``_hypothesis_compat`` shim: skips cleanly where hypothesis is not
installed, explores fresh seeds where it is).  Mesh variants carry the
usual >= 4 devices skip; the CI multi-device job runs them.
"""
import numpy as np
import pytest
import jax
from _hypothesis_compat import given, settings, st

from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, intersect_device_batch, intersect_sharded_batch,
    make_shard_mesh, set_sort_key,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import preprocess_prefix
from repro.exec.topology import make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine

N_DEVICES = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

SEED_MAX = (1 << 31) - 1


def _random_postings(rng, n_terms=8, max_len=400, universe=1 << 18):
    """Random inverted index with a shared overlap pool (so conjunctions
    are routinely nonempty) and wildly varying list sizes (so plans route
    across hashbin / host / device and several shape signatures)."""
    common = rng.choice(universe, 40, replace=False).astype(np.uint32)
    postings = {}
    for t in range(n_terms):
        n = int(rng.integers(5, max_len))
        own = rng.choice(universe, n, replace=False).astype(np.uint32)
        postings[t] = np.unique(np.concatenate([own, common]))
    return postings


def _random_queries(rng, n_terms, n=24):
    return [sorted(set(rng.integers(0, n_terms, size=int(rng.integers(1, 5)))
                       .tolist()))
            for _ in range(n)]


def _np_oracle(postings, q):
    out = postings[sorted(set(q))[0]]
    for t in sorted(set(q))[1:]:
        out = np.intersect1d(out, postings[t])
    return out.astype(np.uint32)


# ---------------------------------------------------------------------------
# full pipeline differential: plan -> bucket -> execute == numpy oracle
# ---------------------------------------------------------------------------

def _check_engine_differential(seed, **engine_kw):
    rng = np.random.default_rng(seed)
    postings = _random_postings(rng)
    queries = _random_queries(rng, len(postings))
    eng = SearchEngine(postings, seed=3, use_device=True, **engine_kw)
    for q, r in zip(queries, eng.query_batch(queries)):
        assert np.array_equal(r.doc_ids, _np_oracle(postings, q)), (seed, q)
    # the async front-end over the same pipeline: submit / drain
    aeng = AsyncSearchEngine(postings, seed=3, flush_tier=8,
                             result_cache=0, **engine_kw)
    tickets = [aeng.submit(list(q)) for q in queries]
    aeng.drain()
    for q, t in zip(queries, tickets):
        assert t.done and t.error is None, (seed, q)
        assert np.array_equal(t.value.doc_ids, _np_oracle(postings, q)), \
            (seed, q)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_differential_seeded(seed):
    _check_engine_differential(seed)


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_engine_differential_property(seed):
    _check_engine_differential(seed)


@multi_device
@pytest.mark.parametrize("seed", [0, 1])
def test_sharded_differential_seeded(seed):
    _check_engine_differential(seed, mesh=make_shard_mesh(N_DEVICES),
                               shard_min_g=4)


@multi_device
@settings(max_examples=2, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_sharded_differential_property(seed):
    _check_engine_differential(seed, mesh=make_shard_mesh(N_DEVICES),
                               shard_min_g=4)


@multi_device
@pytest.mark.parametrize("seed", [0, 1])
def test_mesh2d_differential_seeded(seed):
    _check_engine_differential(seed, topology=make_topology(2, 2),
                               shard_min_g=4)


@multi_device
@settings(max_examples=2, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_mesh2d_differential_property(seed):
    _check_engine_differential(seed, topology=make_topology(2, 2),
                               shard_min_g=4)


# ---------------------------------------------------------------------------
# forced overflow: the enlarged re-run keeps results exact at any capacity
# ---------------------------------------------------------------------------

def _overlapping_device_row(rng, k=2, n=800, overlap=300):
    """k preprocessed device sets with >> capacity survivors in common."""
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 22, overlap, replace=False).astype(np.uint32)
    sets = []
    for _ in range(k):
        own = rng.choice(1 << 22, n, replace=False).astype(np.uint32)
        sets.append(np.unique(np.concatenate([own, common])))
    idxs = [preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
            for s in sets]
    row = sorted((DeviceSet.from_host(i) for i in idxs), key=set_sort_key)
    truth = sets[0]
    for s in sets[1:]:
        truth = np.intersect1d(truth, s)
    return row, truth.astype(np.uint32)


def _check_forced_overflow(seed, cap):
    rng = np.random.default_rng(seed)
    row, truth = _overlapping_device_row(rng)
    assert len(truth) > cap  # the premise: survivors overflow the buffer
    EXEC_COUNTERS.reset()
    out = intersect_device_batch([row, row], capacity=cap, use_pallas=False)
    for res, stats in out:
        assert np.array_equal(res, truth), (seed, cap)
        assert stats["r"] == len(truth)
    assert EXEC_COUNTERS["rerun_calls"] >= 1


@pytest.mark.parametrize("seed,cap", [(0, 1), (1, 2), (2, 7)])
def test_forced_overflow_seeded(seed, cap):
    _check_forced_overflow(seed, cap)


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX),
       cap=st.sampled_from([1, 2, 7]))
def test_forced_overflow_property(seed, cap):
    _check_forced_overflow(seed, cap)


@multi_device
@pytest.mark.parametrize("seed", [0])
def test_forced_overflow_sharded_seeded(seed):
    rng = np.random.default_rng(seed)
    mesh = make_shard_mesh(N_DEVICES)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 22, 300, replace=False).astype(np.uint32)
    sets = [np.unique(np.concatenate(
        [rng.choice(1 << 22, 3000, replace=False).astype(np.uint32), common]))
        for _ in range(2)]
    idxs = [preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
            for s in sets]
    row = sorted((DeviceSet.from_host(i).shard(mesh) for i in idxs),
                 key=set_sort_key)
    truth = np.intersect1d(sets[0], sets[1]).astype(np.uint32)
    EXEC_COUNTERS.reset()
    out = intersect_sharded_batch([row, row], mesh, capacity_per_shard=2,
                                  use_pallas=False)
    for res, stats in out:
        assert np.array_equal(res, truth)
        assert stats["r"] == len(truth)
    assert EXEC_COUNTERS["sharded_rerun_calls"] >= 1
