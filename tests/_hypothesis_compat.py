"""Optional-import shim for hypothesis.

When hypothesis is installed the real ``given``/``settings``/``st`` are
re-exported unchanged.  When it is missing (minimal CI images), property
tests degrade to clean per-test skips instead of killing collection of the
whole module — the unit tests in the same files still run.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: tolerates any attribute access /
        call chain used at module scope (st.lists(st.integers(...), ...))."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg replacement so pytest doesn't hunt for fixtures
            # matching the property's parameter names
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco
