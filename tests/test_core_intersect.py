"""Unit + property tests for the paper's intersection algorithms."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hashing import (
    BitMixPermutation, default_permutation, random_hash_family,
)
from repro.core.partition import (
    choose_t, preprocess_fixed, preprocess_multiresolution, preprocess_prefix,
)
from repro.core.intersect import hashbin, intgroup, rangroup, rangroupscan


def make_sets(rng, k=2, n=2000, overlap=100, universe=1 << 24):
    common = rng.choice(universe, overlap, replace=False).astype(np.uint32)
    out = []
    for _ in range(k):
        own = rng.choice(universe, n, replace=False).astype(np.uint32)
        out.append(np.unique(np.concatenate([own, common])))
    return out


def truth_of(sets):
    out = sets[0]
    for s in sets[1:]:
        out = np.intersect1d(out, s)
    return out


@pytest.fixture(scope="module")
def shared():
    fam64 = random_hash_family(1, 64, seed=11)
    fam = random_hash_family(2, 256, seed=12)
    perm = default_permutation(13)
    return fam64, fam, perm


# ---------------------------------------------------------------- unit tests

@pytest.mark.parametrize("n,overlap", [(100, 5), (3000, 30), (5000, 2500)])
def test_intgroup_matches_oracle(shared, n, overlap):
    fam64, _, _ = shared
    rng = np.random.default_rng(n)
    a, b = make_sets(rng, 2, n, overlap)
    ia = preprocess_fixed(a, w=64, family=fam64)
    ib = preprocess_fixed(b, w=64, family=fam64)
    res, stats = intgroup(ia, ib)
    assert np.array_equal(res, truth_of([a, b]))
    assert stats.r == len(res)


@pytest.mark.parametrize("k", [2, 3, 4])
def test_rangroup_k_matches_oracle(shared, k):
    _, fam, perm = shared
    rng = np.random.default_rng(k)
    sets = make_sets(rng, k, 2000, 50)
    idxs = [preprocess_prefix(s, w=256, m=2, family=fam, perm=perm) for s in sets]
    res, stats = rangroup(idxs)
    assert np.array_equal(res, truth_of(sets))


@pytest.mark.parametrize("k", [2, 3, 4])
@pytest.mark.parametrize("w,m", [(64, 1), (64, 4), (256, 2), (512, 2)])
def test_rangroupscan_matches_oracle(shared, k, w, m):
    _, _, perm = shared
    fam = random_hash_family(m, w, seed=w + m)
    rng = np.random.default_rng(k * w + m)
    sets = make_sets(rng, k, 1500, 40)
    idxs = [preprocess_prefix(s, w=w, m=m, family=fam, perm=perm) for s in sets]
    res, stats = rangroupscan(idxs)
    assert np.array_equal(res, truth_of(sets))
    # the filter may never produce false negatives:
    assert stats.r == len(truth_of(sets))


@pytest.mark.parametrize("n1,n2", [(100, 50000), (1000, 1000), (17, 9999)])
def test_hashbin_matches_oracle(shared, n1, n2):
    _, fam, perm = shared
    rng = np.random.default_rng(n1 + n2)
    common = rng.choice(1 << 24, 13, replace=False).astype(np.uint32)
    a = np.unique(np.concatenate([rng.choice(1 << 24, n1).astype(np.uint32), common]))
    b = np.unique(np.concatenate([rng.choice(1 << 24, n2).astype(np.uint32), common]))
    pa = preprocess_prefix(a, w=256, m=2, family=fam, perm=perm)
    pb = preprocess_prefix(b, w=256, m=2, family=fam, perm=perm)
    res, stats = hashbin(pa, pb)
    assert np.array_equal(res, truth_of([a, b]))
    # Theorem 3.11 comparison budget (generous constant):
    assert stats.comparisons <= 8 * min(n1, n2) * max(
        1, math.log2(max(n1, n2) / min(n1, n2) + 2) + 2
    )


def test_permutation_is_bijective():
    perm = default_permutation(5)
    x = np.arange(100000, dtype=np.uint32)
    y = perm.forward(x)
    assert len(np.unique(y)) == len(x)
    assert np.array_equal(perm.inverse(y), x)


def test_choose_t_matches_theorem():
    # t_i = ceil(log2(n_i / sqrt(w)))
    assert choose_t(1024, 64) == math.ceil(math.log2(1024 / 8))
    assert choose_t(10_000_000, 64) == math.ceil(math.log2(10_000_000 / 8))
    assert choose_t(4, 256) == 0


def test_multiresolution_space_linear():
    rng = np.random.default_rng(0)
    vals = rng.choice(1 << 24, 4096, replace=False).astype(np.uint32)
    mr = preprocess_multiresolution(vals, w=64, m=1)
    # O(n): images over all resolutions <= 2 * 2^T * (m+1) + n words
    assert mr.storage_words() <= 6 * len(vals) + 64
    # every resolution reproduces the same set
    for t in [0, 2, mr.T // 2, mr.T]:
        view = mr.at(t)
        assert np.array_equal(np.sort(view.values), np.sort(vals))
        assert view.G == 1 << t


def test_group_size_optimizer_a11():
    """A.1.1: optimal group sizes s1*=sqrt(w n1/n2) minimize bytes touched."""
    w, n1, n2 = 64, 1000, 64000
    s1 = math.sqrt(w * n1 / n2)
    s2 = math.sqrt(w * n2 / n1)
    assert s1 * s2 == pytest.approx(w)
    t_opt = n1 / s1 + n2 / s2
    t_fixed = (n1 + n2) / math.sqrt(w)
    assert t_opt < t_fixed  # skew makes the optimizer strictly better


# ------------------------------------------------------------ property tests

small_set = st.lists(
    st.integers(min_value=0, max_value=(1 << 20) - 1), min_size=1, max_size=300,
    unique=True
)


@settings(max_examples=40, deadline=None)
@given(a=small_set, b=small_set, w=st.sampled_from([64, 256]), m=st.integers(1, 3))
def test_property_rangroupscan_equals_oracle(a, b, w, m):
    fam = random_hash_family(m, w, seed=m * w)
    perm = default_permutation(w)
    a = np.asarray(sorted(a), dtype=np.uint32)
    b = np.asarray(sorted(b), dtype=np.uint32)
    pa = preprocess_prefix(a, w=w, m=m, family=fam, perm=perm)
    pb = preprocess_prefix(b, w=w, m=m, family=fam, perm=perm)
    res, _ = rangroupscan([pa, pb])
    assert np.array_equal(res, np.intersect1d(a, b))


@settings(max_examples=30, deadline=None)
@given(a=small_set, b=small_set)
def test_property_result_is_subset_and_commutative(a, b):
    fam = random_hash_family(2, 64, seed=9)
    perm = default_permutation(9)
    a = np.asarray(sorted(a), dtype=np.uint32)
    b = np.asarray(sorted(b), dtype=np.uint32)
    pa = preprocess_prefix(a, w=64, m=2, family=fam, perm=perm)
    pb = preprocess_prefix(b, w=64, m=2, family=fam, perm=perm)
    r1, _ = rangroupscan([pa, pb])
    r2, _ = rangroupscan([pb, pa])
    assert np.array_equal(r1, r2)  # commutative
    assert np.all(np.isin(r1, a)) and np.all(np.isin(r1, b))  # subset


@settings(max_examples=25, deadline=None)
@given(a=small_set, b=small_set)
def test_property_filter_no_false_negatives(a, b):
    """If a group tuple contains a common element, its images always pass
    the AND test (word representations are exact on the hash images)."""
    fam = random_hash_family(1, 64, seed=4)
    perm = default_permutation(4)
    a = np.asarray(sorted(a), dtype=np.uint32)
    b = np.asarray(sorted(b), dtype=np.uint32)
    pa = preprocess_prefix(a, w=64, m=1, family=fam, perm=perm)
    pb = preprocess_prefix(b, w=64, m=1, family=fam, perm=perm)
    res, stats = rangroupscan([pa, pb])
    truth = np.intersect1d(a, b)
    assert np.array_equal(res, truth)
    if len(truth):
        assert stats.tuples_survived > 0


@settings(max_examples=20, deadline=None)
@given(
    sets=st.lists(small_set, min_size=2, max_size=4),
    algo=st.sampled_from(["rangroup", "rangroupscan"]),
)
def test_property_k_way(sets, algo):
    fam = random_hash_family(2, 64, seed=3)
    perm = default_permutation(3)
    arrs = [np.asarray(sorted(s), dtype=np.uint32) for s in sets]
    idxs = [preprocess_prefix(s, w=64, m=2, family=fam, perm=perm) for s in arrs]
    fn = rangroup if algo == "rangroup" else rangroupscan
    res, _ = fn(idxs)
    truth = arrs[0]
    for s in arrs[1:]:
        truth = np.intersect1d(truth, s)
    assert np.array_equal(res, truth)
