"""Tests for the 2-D mesh topology subsystem (data-parallel replicas
composed with z-sharding).

Covers: the replica balancer's least-loaded accounting (pure python),
planner routing by ``(shards, replicas)``, oracle equivalence of
``intersect_mesh2d_batch`` across the 1x4 / 2x2 / 4x1 layouts, the
per-(query, shard) forced-overflow re-run property, engine end-to-end
equivalence with balancer spreading, and topology-aware compile warming.

Mesh tests need >= 4 devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` exported before jax initializes — the CI multi-device job
does this).  On a single-device run those skip, but the subprocess oracle
test always runs: it re-executes bit-identity vs ``query_batch`` across
all three layouts, the forced-overflow property, the balancer
distribution, and warming zero-traces in a fresh interpreter with the
flag set, so the acceptance guarantees are exercised by every tier-1 run.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, ReplicatedDeviceSet, clear_exec_jit_cache,
    intersect_device_batch, intersect_mesh2d_batch, make_mesh2d,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.plan import plan_query
from repro.exec.topology import ReplicaBalancer, make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine, zipf_query_log

N_DEVICES = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

LAYOUTS = ((1, 4), (2, 2), (4, 1))


# ---------------------------------------------------------------------------
# Replica balancer (pure python — runs on any device count)
# ---------------------------------------------------------------------------

def test_balancer_least_loaded_pick_and_release():
    bal = ReplicaBalancer(3)
    # empty: ties break by replica id
    assert bal.acquire(10.0) == 0
    # replica 0 has 10 in flight -> next goes elsewhere
    assert bal.acquire(1.0) == 1
    assert bal.acquire(1.0) == 2
    # 1 and 2 tie on in-flight; cumulative weight breaks it (2 < 1? no:
    # both 1.0 -> id breaks) — release 1 fully, it becomes least loaded
    bal.release(1, 1.0)
    assert bal.acquire(1.0) == 1
    loads = bal.loads()
    assert [d["dispatched"] for d in loads] == [1, 2, 1]
    assert loads[0]["in_flight"] == 10.0
    # release never goes negative
    bal.release(2, 99.0)
    assert bal.loads()[2]["in_flight"] == 0.0


def test_balancer_degenerates_to_weighted_round_robin_when_idle():
    """Synchronous serving (acquire -> execute -> release) always sees zero
    in-flight load, so equal-weight buckets spread evenly."""
    bal = ReplicaBalancer(4)
    for _ in range(12):
        r = bal.acquire(5.0)
        bal.release(r, 5.0)
    assert [d["dispatched"] for d in bal.loads()] == [3, 3, 3, 3]


# ---------------------------------------------------------------------------
# Planner routing by (shards, replicas) — metadata only, no mesh needed
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    """Three overlapping sets big enough to split over 4 shards
    (t = 8/9/10 -> 256/512/1024 z-groups)."""
    rng = np.random.default_rng(0)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 24, 60, replace=False).astype(np.uint32)
    raw, idxs = {}, {}
    for name, n in [("a", 3000), ("b", 5000), ("c", 9000)]:
        s = np.unique(np.concatenate(
            [rng.choice(1 << 24, n, replace=False).astype(np.uint32), common]))
        raw[name] = s
        idxs[name] = preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
    return raw, idxs


def test_plan_routes_by_shards_and_replicas(corpus):
    _, idxs = corpus
    # 2-D mesh + low threshold -> both axes stamped into the signature
    sig = plan_query(idxs, ["a", "b"], mesh_shards=2, mesh_replicas=2,
                     shard_min_g=64).sig
    assert (sig.shards, sig.replicas) == (2, 2)
    # pure data-parallel topology: shards == 1 never blocks alignment
    sig = plan_query(idxs, ["a", "b"], mesh_shards=1, mesh_replicas=4,
                     shard_min_g=64).sig
    assert (sig.shards, sig.replicas) == (1, 4)
    # below the size threshold -> single-device, replicas not stamped
    sig = plan_query(idxs, ["a", "b"], mesh_shards=2, mesh_replicas=2,
                     shard_min_g=1 << 20).sig
    assert (sig.shards, sig.replicas) == (1, 1)
    # alignment failure on the shard axis blocks the whole mesh route
    fam, perm = idxs["a"].family, idxs["a"].perm
    tiny = preprocess_prefix(np.arange(1, 9, dtype=np.uint32), w=256, m=2,
                             family=fam, perm=perm, t=1)
    mixed = dict(idxs, tiny=tiny)
    sig = plan_query(mixed, ["tiny", "c"], hashbin_ratio=float("inf"),
                     mesh_shards=4, mesh_replicas=2, shard_min_g=64).sig
    assert (sig.shards, sig.replicas) == (1, 1)
    # layouts never share a bucket: all four routings are distinct sigs
    sigs = {
        plan_query(idxs, ["a", "b"], mesh_shards=s, mesh_replicas=r,
                   shard_min_g=64).sig
        for r, s in [(1, 4), (2, 2), (4, 1), (1, 1)]
    }
    assert len(sigs) == 4


# ---------------------------------------------------------------------------
# Topology construction
# ---------------------------------------------------------------------------

@multi_device
def test_topology_layout_and_row_meshes():
    topo = make_topology(2, 2)
    assert (topo.replicas, topo.shards) == (2, 2)
    assert topo.describe() == "2x2"
    devices = {d for r in range(2) for d in topo.replica_devices(r)}
    assert len(devices) == 4
    # rows are disjoint; the row mesh is cached (jit cache key identity)
    assert topo.row_mesh(0) is topo.row_mesh(0)
    assert set(topo.row_mesh(0).devices.ravel()).isdisjoint(
        topo.row_mesh(1).devices.ravel())
    assert topo.replica_device(1) == topo.replica_devices(1)[0]


@multi_device
def test_mesh2d_replicas_must_be_pow2():
    if len(jax.devices()) < 6:
        pytest.skip("needs 6 devices to attempt a 3x2 grid")
    with pytest.raises(AssertionError):
        make_mesh2d(3, 2)


# ---------------------------------------------------------------------------
# Oracle equivalence across layouts
# ---------------------------------------------------------------------------

def _replicated(idxs, topo):
    """Build ReplicatedDeviceSet mirrors the way BatchedEngine.add does."""
    out = {}
    for name, idx in idxs.items():
        ds = DeviceSet.from_host(idx)
        if topo.shards > 1:
            rows = tuple(ds.shard(topo.row_mesh(r), topo.shard_axis)
                         for r in range(topo.replicas))
        else:
            rows = tuple(ds.place(topo.replica_device(r))
                         for r in range(topo.replicas))
        out[name] = ReplicatedDeviceSet(rows)
    return out


def truth_of(raw, names):
    out = raw[names[0]]
    for n in names[1:]:
        out = np.intersect1d(out, raw[n])
    return out


@multi_device
@pytest.mark.parametrize("layout", LAYOUTS)
def test_mesh2d_matches_host_and_device_oracles(corpus, layout):
    raw, idxs = corpus
    topo = make_topology(*layout)
    sets = _replicated(idxs, topo)
    for names in [["a", "b"], ["b", "c"], ["a", "b", "c"]]:
        truth = truth_of(raw, names)
        host, _ = rangroupscan([idxs[n] for n in names])
        row = [sets[n] for n in names]
        # batch of three (arg order varies) + check vs single-device path
        out = intersect_mesh2d_batch([row, row[::-1], row], topo,
                                     use_pallas=False)
        unsharded = intersect_device_batch(
            [[DeviceSet.from_host(idxs[n]) for n in names]], use_pallas=False)
        assert np.array_equal(host, truth)
        assert np.array_equal(unsharded[0][0], truth)
        for res, stats in out:
            assert np.array_equal(res, truth), (layout, names)
            assert stats["r"] == len(truth)
            assert stats["n_shards"] == layout[1]
            assert stats["n_replicas"] == layout[0]
        # survivors aggregate identically however the mesh is laid out
        assert out[0][1]["tuples_survived"] == \
            unsharded[0][1]["tuples_survived"]


@multi_device
def test_mesh2d_spreads_batch_rows_over_replicas(corpus):
    _, idxs = corpus
    topo = make_topology(4, 1)
    sets = _replicated(idxs, topo)
    row = [sets["a"], sets["b"]]
    # full local-G capacity: overflow impossible, so call counts are exact
    cap = 1 << max(sets["a"].t, sets["b"].t)
    out = intersect_mesh2d_batch([row] * 8, topo, capacity_per_shard=cap,
                                 use_pallas=False)
    # contiguous slices: 8 queries over 4 rows = 2 per replica
    assert [stats["replica"] for _, stats in out] == \
        [0, 0, 1, 1, 2, 2, 3, 3]
    EXEC_COUNTERS.reset()
    intersect_mesh2d_batch([row] * 8, topo, capacity_per_shard=cap,
                           use_pallas=False)
    assert EXEC_COUNTERS["mesh2d_calls"] == 1
    assert EXEC_COUNTERS["mesh2d_row_dispatches"] == 4
    # a 1-query bucket pads B to the replica count, but padding-only rows
    # are never dispatched: one row runs, three stay idle
    EXEC_COUNTERS.reset()
    (res, stats), = intersect_mesh2d_batch([row], topo,
                                           capacity_per_shard=cap,
                                           use_pallas=False)
    assert stats["replica"] == 0
    assert EXEC_COUNTERS["mesh2d_row_dispatches"] == 1


@multi_device
def test_mesh2d_mixed_signature_rejected(corpus):
    _, idxs = corpus
    topo = make_topology(2, 2)
    sets = _replicated(idxs, topo)
    with pytest.raises(AssertionError):
        intersect_mesh2d_batch(
            [[sets["a"], sets["b"]], [sets["a"], sets["c"]]],
            topo, use_pallas=False)


# ---------------------------------------------------------------------------
# Forced overflow: per-(query, shard) flags, ONE enlarged re-run, exact
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("layout", LAYOUTS)
def test_mesh2d_forced_overflow_rerun_is_exact(corpus, layout):
    raw, idxs = corpus
    topo = make_topology(*layout)
    sets = _replicated(idxs, topo)
    truth = truth_of(raw, ["a", "b"])
    row = [sets["a"], sets["b"]]
    EXEC_COUNTERS.reset()
    out = intersect_mesh2d_batch([row] * 4, topo, capacity_per_shard=2,
                                 use_pallas=False)
    for res, stats in out:
        assert np.array_equal(res, truth), layout
        assert stats["r"] == len(truth)
        assert stats["capacity_per_shard"] > 2  # re-ran at local G
    assert EXEC_COUNTERS["mesh2d_rerun_calls"] == 1
    assert EXEC_COUNTERS["mesh2d_calls"] == 2


# ---------------------------------------------------------------------------
# Engine end-to-end over a topology
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(3000, vocab=400, mean_len=40, seed=3)
    return inverted_index(docs)


@multi_device
@pytest.mark.parametrize("layout", LAYOUTS)
def test_search_engine_topology_matches_baseline(postings, layout):
    topo = make_topology(*layout)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=4)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 48, seed=11)
    plans = [eng.plan(q) for q in log]
    assert any(p.algorithm == "device" and p.sig.replicas == layout[0]
               and p.sig.shards == layout[1] for p in plans), (
        "threshold routed nothing to the mesh")
    got = eng.query_batch(log)
    want = base.query_batch(log)
    for q, a, b in zip(log, got, want):
        assert np.array_equal(a.doc_ids, b.doc_ids), (layout, q)
    assert any(r.algorithm == "rangroupscan/mesh2d" for r in got)


@multi_device
def test_balancer_spreads_single_device_buckets(postings):
    """With the mesh threshold out of reach, every bucket is single-device
    and the topology's balancer must spread them across replica rows."""
    topo = make_topology(4, 1)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 48, seed=11)
    EXEC_COUNTERS.reset()
    got = eng.query_batch(log)
    for q, a, b in zip(log, got, base.query_batch(log)):
        assert np.array_equal(a.doc_ids, b.doc_ids), q
    assert EXEC_COUNTERS["replica_dispatches"] > 0
    assert EXEC_COUNTERS["mesh2d_calls"] == 0
    dispatched = [d["dispatched"] for d in topo.load_snapshot()]
    assert sum(dispatched) == EXEC_COUNTERS["replica_dispatches"]
    # least-loaded spreading: no replica hoards, none starves
    assert sum(1 for d in dispatched if d > 0) >= 3
    assert {r.stats.get("replica") for r in got
            if "replica" in r.stats} >= {0, 1, 2}


@multi_device
def test_query_many_balancer_path_on_2x2_topology(postings):
    """Regression: name-keyed ``BatchedEngine.query_many`` must resolve
    per-replica mirrors through the engine's lazy builders — raw mapping
    access crashed with KeyError once topology mirrors went lazy (nothing
    populates them at add time anymore)."""
    topo = make_topology(2, 2)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
    base = SearchEngine(postings, seed=3, use_device=True)
    names = [str(t) for t in sorted(eng.index)[:4]]
    queries = [[names[0], names[1]], [names[2], names[3]],
               [names[0], names[2]]]
    EXEC_COUNTERS.reset()
    got = eng.device.query_many(queries)
    want = base.device.query_many(queries)
    for q, (a, _), (b, _) in zip(queries, got, want):
        assert np.array_equal(a, b), q
    assert EXEC_COUNTERS["replica_dispatches"] > 0


@multi_device
def test_async_engine_topology_matches_oracle(postings):
    topo = make_topology(2, 2)
    eng = AsyncSearchEngine(postings, seed=3, topology=topo, shard_min_g=4,
                            flush_tier=4, result_cache=0)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 24, seed=5)
    tickets = [eng.submit(q) for q in log]
    eng.drain()
    assert all(t.done for t in tickets)
    for q, t, o in zip(log, tickets, base.query_batch(log)):
        assert np.array_equal(t.value.doc_ids, o.doc_ids), q


@multi_device
def test_mesh2d_warming_zero_traces_at_serve_time(postings):
    topo = make_topology(2, 2)
    eng = AsyncSearchEngine(postings, seed=3, topology=topo, shard_min_g=4,
                            flush_tier=2, result_cache=0)
    sample = zipf_query_log(sorted(eng.index), 48, seed=13)
    clear_exec_jit_cache()
    EXEC_COUNTERS.reset()
    warmed = eng.warm(sample, top_k=32, b_tiers=(1, 2))
    mesh_warmed = [s for s in warmed if s.replicas == 2 and s.shards == 2]
    assert mesh_warmed, "warming saw no mesh-routed signatures"
    assert EXEC_COUNTERS["mesh2d_traces"] >= len(mesh_warmed)
    q = next(q for q in sample if eng.plan(q).sig in mesh_warmed)
    # first serve may trace the (rare) overflow re-run executable; the
    # second serve of the same query must hit only compiled code
    eng.submit(q)
    eng.drain()
    EXEC_COUNTERS.reset()
    ticket = eng.submit(q)
    eng.drain()
    assert ticket.done
    assert EXEC_COUNTERS["mesh2d_calls"] >= 1
    assert EXEC_COUNTERS["mesh2d_traces"] == 0  # compiled at build time
    assert EXEC_COUNTERS["batch_traces"] == 0


# ---------------------------------------------------------------------------
# Subprocess guarantee: runs even when this process is single-device
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# CPU explicitly: with libtpu on the image, a second jax process would
# otherwise block minutes on the parent's /tmp/libtpu_lockfile
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
from repro.core.engine import EXEC_COUNTERS
from repro.exec.topology import make_topology
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.search import SearchEngine, zipf_query_log

docs = zipf_corpus(2000, vocab=300, mean_len=30, seed=3)
postings = inverted_index(docs)
base = SearchEngine(postings, seed=3, use_device=True)
log = zipf_query_log(sorted(base.index), 24, seed=11)
want = base.query_batch(log)

# bit-identity vs query_batch across all three layouts
for layout in [(1, 4), (2, 2), (4, 1)]:
    topo = make_topology(*layout)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=4)
    EXEC_COUNTERS.reset()
    got = eng.query_batch(log)
    for q, a, b in zip(log, got, want):
        assert np.array_equal(a.doc_ids, b.doc_ids), (layout, q)
    assert EXEC_COUNTERS["mesh2d_calls"] > 0, layout
    # every pass dispatches at least one row and at most `replicas`
    # (padding-only rows are skipped entirely)
    assert (EXEC_COUNTERS["mesh2d_calls"]
            <= EXEC_COUNTERS["mesh2d_row_dispatches"]
            <= layout[0] * EXEC_COUNTERS["mesh2d_calls"]), layout

# forced overflow: tiny per-shard capacity still yields exact results
from repro.core.engine import DeviceSet, ReplicatedDeviceSet, \
    intersect_mesh2d_batch
topo = make_topology(2, 2)
idxs = {t: base.index[t] for t in sorted(base.index)}
big = [t for t in sorted(idxs) if idxs[t].t >= 2][:2]
rows = []
for t in big:
    ds = DeviceSet.from_host(idxs[t])
    rows.append(ReplicatedDeviceSet(tuple(
        ds.shard(topo.row_mesh(r), topo.shard_axis) for r in range(2))))
truth = np.intersect1d(postings[big[0]], postings[big[1]])
EXEC_COUNTERS.reset()
(res, stats), = intersect_mesh2d_batch([rows], topo, capacity_per_shard=1,
                                       use_pallas=False)
assert np.array_equal(res, truth), (len(res), len(truth))
assert EXEC_COUNTERS["mesh2d_rerun_calls"] == 1
assert EXEC_COUNTERS["mesh2d_calls"] == 2

# balancer distribution: single-device buckets spread over 4 replicas
topo = make_topology(4, 1)
eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
EXEC_COUNTERS.reset()
got = eng.query_batch(log)
for q, a, b in zip(log, got, want):
    assert np.array_equal(a.doc_ids, b.doc_ids), q
assert EXEC_COUNTERS["replica_dispatches"] > 0
dispatched = [d["dispatched"] for d in topo.load_snapshot()]
assert sum(1 for d in dispatched if d > 0) >= 3, dispatched

# name-keyed query_many resolves lazy mirrors (KeyError regression)
names = [str(t) for t in sorted(base.index)[:4]]
nq = [[names[0], names[1]], [names[2], names[3]]]
got_nm = eng.device.query_many(nq)
want_nm = base.device.query_many(nq)
for q, (a, _), (b, _) in zip(nq, got_nm, want_nm):
    assert np.array_equal(a, b), q

# routing + warming: a warmed mesh signature serves with zero traces
from repro.core.engine import clear_exec_jit_cache
from repro.serve.search import AsyncSearchEngine
topo = make_topology(2, 2)
eng = AsyncSearchEngine(postings, seed=3, topology=topo, shard_min_g=4,
                        flush_tier=2, result_cache=0)
clear_exec_jit_cache()
warmed = eng.warm(log, top_k=32, b_tiers=(1, 2))
mesh_warmed = [s for s in warmed if s.replicas == 2 and s.shards == 2]
assert mesh_warmed
q = next(q for q in log if eng.plan(q).sig in mesh_warmed)
eng.submit(q); eng.drain()          # may trace the overflow re-run variant
EXEC_COUNTERS.reset()
ticket = eng.submit(q); eng.drain()
assert ticket.done
assert EXEC_COUNTERS["mesh2d_traces"] == 0
assert EXEC_COUNTERS["batch_traces"] == 0
print("MESH2D_SUBPROCESS_OK")
"""


def test_mesh2d_oracle_in_forced_multidevice_subprocess():
    """The acceptance guarantee, independent of this process's device
    count: a fresh interpreter with 8 forced host devices must reproduce
    ``query_batch`` bit-identically on 1x4, 2x2, and 4x1 topologies,
    recover exactly from forced per-shard overflow (counter-verified
    single re-run), spread balancer buckets over the replicas, and serve
    warmed mesh signatures without retracing."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MESH2D_SUBPROCESS_OK" in proc.stdout
