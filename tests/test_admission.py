"""Tests for the async admission & micro-batching front-end.

Covers: deadline flush firing on a lone query (fake clock), tier flush at
the power-of-two bucket size, result-cache hits skipping device execution
(counter-verified), compile warming leaving zero traces for the first live
query on a warmed signature, async results matching the synchronous
``query_batch`` oracle, and AdmissionQueue bookkeeping.
"""
import numpy as np
import pytest

from repro.core.engine import (
    EXEC_COUNTERS, clear_exec_jit_cache, warm_executables,
)
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.cache import ResultCache
from repro.exec.plan import plan_query
from repro.serve.admission import AdmissionQueue, Ticket
from repro.serve.search import (
    AsyncSearchEngine, SearchEngine, repeated_query_log, zipf_query_log,
)


class FakeClock:
    """Injectable clock: tests advance time explicitly (seconds)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_us(self, us):
        self.t += us * 1e-6


@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(2500, vocab=500, mean_len=30, seed=3)
    return inverted_index(docs)


def _async_engine(postings, clock, **kw):
    kw.setdefault("deadline_us", 2000.0)
    kw.setdefault("flush_tier", 8)
    return AsyncSearchEngine(postings, clock=clock, seed=3, **kw)


# ---------------------------------------------------------------------------
# AdmissionQueue unit behavior
# ---------------------------------------------------------------------------

def test_admission_queue_deadline_and_tier():
    clk = FakeClock()
    q = AdmissionQueue(flush_tier=4, deadline_us=1000.0, clock=clk)
    t1 = q.submit("sig", "a")
    assert isinstance(t1, Ticket) and not t1.done
    assert q.take_due() == []                  # budget not yet expired
    clk.advance_us(999)
    assert q.take_due() == []
    clk.advance_us(2)
    (key, bucket), = q.take_due()              # oldest deadline expired
    assert key == "sig" and [it for _, it in bucket] == ["a"]
    assert EXEC_COUNTERS["deadline_flushes"] == 1
    assert q.pending() == 0

    for x in range(4):                         # full tier flushes without pump
        q.submit("sig", x)
    (_, bucket), = q.take_full()
    assert len(bucket) == 4
    assert EXEC_COUNTERS["tier_flushes"] == 1


def test_admission_queue_next_deadline():
    clk = FakeClock()
    q = AdmissionQueue(flush_tier=4, deadline_us=500.0, clock=clk)
    assert q.next_deadline_in_us() is None
    q.submit("s1", 1)
    clk.advance_us(100)
    q.submit("s2", 2)                          # younger bucket
    assert q.next_deadline_in_us() == pytest.approx(400.0, abs=1e-6)


def test_tighter_per_query_deadline_binds():
    """A later submission with a smaller budget must drive the flush."""
    clk = FakeClock()
    q = AdmissionQueue(flush_tier=8, deadline_us=2000.0, clock=clk)
    q.submit("sig", "a")                       # due at t=2000us
    clk.advance_us(50)
    q.submit("sig", "b", deadline_us=100.0)    # due at t=150us — binding
    assert q.next_deadline_in_us() == pytest.approx(100.0, abs=1e-6)
    clk.advance_us(99)
    assert q.take_due() == []
    clk.advance_us(2)
    (_, bucket), = q.take_due()                # both flush together
    assert [it for _, it in bucket] == ["a", "b"]


def test_ticket_value_before_resolve_raises():
    t = Ticket(submitted_at=0.0, deadline_us=100.0)
    with pytest.raises(RuntimeError):
        _ = t.value


def test_next_deadline_zero_when_bucket_full():
    """Regression: a bucket at flush_tier is ready NOW — the sleep hint must
    be 0, not the (possibly full) deadline budget, or a sleep-based pump
    loop idles on flushable work."""
    clk = FakeClock()
    q = AdmissionQueue(flush_tier=2, deadline_us=5000.0, clock=clk)
    q.submit("sig", "a")
    assert q.next_deadline_in_us() == pytest.approx(5000.0, abs=1e-6)
    q.submit("sig", "b")                       # tier reached
    assert q.next_deadline_in_us() == 0.0
    # a different, partial bucket doesn't mask the full one
    q.submit("other", "c")
    assert q.next_deadline_in_us() == 0.0
    q.take_full()
    assert q.next_deadline_in_us() == pytest.approx(5000.0, abs=1e-6)


def test_ticket_resolution_is_single_shot_and_event_backed():
    """Regression: ``done`` is Event-backed (cross-thread visibility) and
    resolution is single-shot — a failed-then-retried bucket must raise on
    the second resolve instead of clobbering a delivered result."""
    import threading

    t = Ticket(submitted_at=0.0, deadline_us=100.0)
    assert not t.done and not t.wait(timeout=0.0)
    seen = []
    waiter = threading.Thread(target=lambda: seen.append(
        (t.wait(timeout=5.0), t.value)))
    waiter.start()
    t.resolve("result", wait_us=7.0)
    waiter.join(timeout=5.0)
    assert seen == [(True, "result")]          # waiter observed the payload
    assert t.done and t.value == "result" and t.wait(timeout=0.0)
    with pytest.raises(RuntimeError, match="already resolved"):
        t.resolve("clobber")
    with pytest.raises(RuntimeError, match="already resolved"):
        t.resolve_error(ValueError("late failure"))
    assert t.value == "result"                 # first resolution stands

    t2 = Ticket(submitted_at=0.0, deadline_us=100.0)
    t2.resolve_error(ValueError("boom"), wait_us=1.0)
    assert t2.done
    with pytest.raises(RuntimeError, match="already resolved"):
        t2.resolve("too late")
    with pytest.raises(ValueError, match="boom"):
        _ = t2.value


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------

def test_result_cache_lru_and_counters(postings):
    idx = SearchEngine(postings, seed=3).index
    terms = sorted(idx)
    cache = ResultCache(capacity=2)
    plans = [plan_query(idx, [t], device=False) for t in terms[:3]]
    assert cache.get(plans[0]) is None
    assert EXEC_COUNTERS["result_cache_misses"] == 1
    cache.put(plans[0], "r0")
    cache.put(plans[1], "r1")
    assert cache.get(plans[0]) == "r0"         # refreshes recency
    cache.put(plans[2], "r2")                  # evicts plans[1] (LRU)
    assert cache.get(plans[1]) is None
    assert cache.get(plans[2]) == "r2"
    assert EXEC_COUNTERS["result_cache_hits"] == 2
    # surface-form invariance: [a, b], [b, a], [a, a, b] share one key
    a, b = terms[0], terms[1]
    k = plan_query(idx, [a, b], device=False).cache_key()
    assert plan_query(idx, [b, a], device=False).cache_key() == k
    assert plan_query(idx, [a, a, b], device=False).cache_key() == k


def test_result_cache_generation_invalidates_stale_entries(postings):
    """Regression: the cache key is only (algorithm, terms) — after an index
    mutation, old entries must read as misses, not serve old postings."""
    idx = SearchEngine(postings, seed=3).index
    terms = sorted(idx)
    cache = ResultCache(capacity=8)
    plan = plan_query(idx, [terms[0]], device=False)
    cache.put(plan, "old-postings")
    assert cache.get(plan) == "old-postings"
    cache.bump_generation()
    EXEC_COUNTERS.reset()
    assert cache.get(plan) is None             # stale -> miss + evicted
    assert EXEC_COUNTERS["result_cache_misses"] == 1
    assert len(cache) == 0
    cache.put(plan, "new-postings")            # fresh entry at the new gen
    assert cache.get(plan) == "new-postings"
    cache.invalidate()                         # explicit hook: drop now
    assert len(cache) == 0
    assert cache.get(plan) is None


def test_index_mutation_invalidates_served_results(postings):
    """End-to-end: add_postings after serving must bump the generation (via
    the device engine's mutation hook) so the old result can't be served."""
    eng = SearchEngine(postings, seed=3, use_device=True, result_cache=64)
    term = sorted(eng.index)[0]
    before = eng.query([term])
    assert np.array_equal(np.sort(before.doc_ids),
                          np.sort(eng.index[term].values))
    cached = eng.query([term])
    assert cached.stats.get("cached") is True  # primed
    new_postings = np.array([5, 17, 99], dtype=np.uint32)
    eng.add_postings(term, new_postings)
    after = eng.query([term])
    assert not after.stats.get("cached")
    assert np.array_equal(after.doc_ids, new_postings)
    # and the fresh result re-enters the cache under the new generation
    again = eng.query([term])
    assert again.stats.get("cached") is True
    assert np.array_equal(again.doc_ids, new_postings)
    # host-path engines (no device) bump the generation directly
    host_eng = SearchEngine(postings, seed=3, result_cache=64)
    assert host_eng.query([term]).stats.get("cached") is None
    assert host_eng.query([term]).stats.get("cached") is True
    host_eng.add_postings(term, new_postings)
    refreshed = host_eng.query([term])
    assert not refreshed.stats.get("cached")
    assert np.array_equal(refreshed.doc_ids, new_postings)


def test_put_rejects_results_computed_against_old_generation(postings):
    """Regression: a result computed before a mutation but stored after the
    generation bump must NOT re-enter the cache as fresh."""
    idx = SearchEngine(postings, seed=3).index
    cache = ResultCache(capacity=8)
    plan = plan_query(idx, [sorted(idx)[0]], device=False)
    gen = cache.generation                     # captured before "executing"
    cache.bump_generation()                    # mutation lands mid-flight
    cache.put(plan, "stale-result", generation=gen)
    assert len(cache) == 0
    assert cache.get(plan) is None
    cache.put(plan, "fresh-result")            # computed after the mutation
    assert cache.get(plan) == "fresh-result"


def test_mutation_between_submit_and_flush_does_not_poison_bucket(postings):
    """Regression: add_postings after submit can re-tier a queued term; the
    flush must re-validate plans and serve every ticket a correct result
    instead of failing the whole bucket on the signature assert."""
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=0)
    qs = [q for q in zipf_query_log(sorted(eng.index), 64, seed=7)
          if eng.plan(q).algorithm == "device" and len(q) >= 2]
    query = qs[0]
    ticket = eng.submit(query)
    assert not ticket.done
    # shrink one queued term's postings to a different (t, gmax) tier
    mutated_term = query[0]
    eng.add_postings(mutated_term, np.array([3, 7, 11], dtype=np.uint32))
    clk.advance_us(2001)
    eng.pump()
    assert ticket.done and ticket.error is None
    truth = np.array([3, 7, 11], dtype=np.uint32)
    for t in query[1:]:
        truth = np.intersect1d(truth, np.sort(eng.index[t].values))
    assert np.array_equal(ticket.value.doc_ids, truth)


def test_cache_hit_skips_device_execution(postings):
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=64)
    q = zipf_query_log(sorted(eng.index), 8, seed=9)[0]
    t1 = eng.submit(q)
    eng.drain()
    assert t1.done
    EXEC_COUNTERS.reset()
    t2 = eng.submit(q)                         # repeat: must not touch device
    assert t2.done                             # resolved at submit time
    assert EXEC_COUNTERS["result_cache_hits"] == 1
    assert EXEC_COUNTERS["batch_calls"] == 0
    assert t2.value.stats.get("cached") is True
    assert np.array_equal(t2.value.doc_ids, t1.value.doc_ids)


# ---------------------------------------------------------------------------
# Async engine flush semantics
# ---------------------------------------------------------------------------

def test_deadline_flush_fires_on_lone_query(postings):
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=0)
    q = zipf_query_log(sorted(eng.index), 4, seed=2)[0]
    ticket = eng.submit(q)
    assert not ticket.done and eng.pending() == 1
    assert eng.pump() == 0                     # budget not exhausted yet
    clk.advance_us(2001)
    assert eng.pump() == 1                     # lone query force-flushed
    assert ticket.done
    assert EXEC_COUNTERS["deadline_flushes"] == 1
    assert ticket.wait_us >= 2000.0            # waited out its full budget
    oracle = SearchEngine(postings, use_device=True, seed=3).query(q)
    assert np.array_equal(ticket.value.doc_ids, oracle.doc_ids)


def test_tier_flush_fires_without_pump(postings):
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=0, flush_tier=2)
    # two same-signature queries: second submit fills the tier
    qs = [q for q in zipf_query_log(sorted(eng.index), 64, seed=7)
          if eng.plan(q).algorithm == "device"]
    sig_of = {i: eng.plan(q).sig for i, q in enumerate(qs)}
    pair = None
    for i in range(len(qs)):
        for j in range(i + 1, len(qs)):
            if sig_of[i] == sig_of[j] and qs[i] != qs[j]:
                pair = (qs[i], qs[j])
                break
        if pair:
            break
    assert pair, "log produced no same-signature pair"
    t1 = eng.submit(pair[0])
    assert not t1.done
    t2 = eng.submit(pair[1])                   # tier reached -> inline flush
    assert t1.done and t2.done
    assert EXEC_COUNTERS["tier_flushes"] == 1
    assert EXEC_COUNTERS["deadline_flushes"] == 0
    assert t1.value.stats["batch_size"] == 2


def test_bucket_failure_resolves_tickets_with_error(postings, monkeypatch):
    """A failing bucket must not strand its tickets unresolved."""
    import repro.serve.search as search_mod

    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=0)

    def boom(*a, **k):
        raise RuntimeError("device exploded")

    monkeypatch.setattr(search_mod, "dispatch_bucket", boom)
    q = zipf_query_log(sorted(eng.index), 4, seed=2)[0]
    ticket = eng.submit(q)
    clk.advance_us(2001)
    eng.pump()                                 # flush dispatches and fails
    assert ticket.done and ticket.error is not None
    with pytest.raises(RuntimeError, match="device exploded"):
        _ = ticket.value
    assert eng.pending() == 0                  # nothing stuck in the queue


def test_async_results_match_query_batch_oracle(postings):
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=128, flush_tier=8)
    log = repeated_query_log(sorted(eng.index), 48, n_distinct=12, seed=5)
    tickets = []
    for i, q in enumerate(log):
        tickets.append(eng.submit(q))
        clk.advance_us(300)
        eng.pump()
    eng.drain()
    assert all(t.done for t in tickets)
    oracle = SearchEngine(postings, use_device=True, seed=3).query_batch(log)
    for q, t, o in zip(log, tickets, oracle):
        assert np.array_equal(t.value.doc_ids, o.doc_ids), q
    # repeats existed, so the cache must have fired
    assert EXEC_COUNTERS["result_cache_hits"] > 0


# ---------------------------------------------------------------------------
# Compile warming
# ---------------------------------------------------------------------------

def test_warmed_signature_zero_traces_on_first_query(postings):
    clk = FakeClock()
    eng = _async_engine(postings, clk, result_cache=0)
    sample = zipf_query_log(sorted(eng.index), 64, seed=13)
    clear_exec_jit_cache()                     # deterministic: forget history
    EXEC_COUNTERS.reset()
    warmed = eng.warm(sample, top_k=32, b_tiers=(1,))
    assert warmed and EXEC_COUNTERS["batch_traces"] >= len(warmed)
    assert EXEC_COUNTERS["warm_executions"] == len(warmed)
    # first live query on a warmed signature: executes, but compiles nothing
    q = next(q for q in sample if eng.plan(q).algorithm == "device"
             and eng.plan(q).sig == warmed[0])
    EXEC_COUNTERS.reset()
    ticket = eng.submit(q)
    clk.advance_us(2001)
    eng.pump()
    assert ticket.done
    assert EXEC_COUNTERS["batch_calls"] >= 1   # it did run on the device
    # zero compiles — warming executed a real representative of this
    # signature, so even the overflow re-run variant (if the hot signature
    # overflows, the representative did too) was traced at build time
    assert EXEC_COUNTERS["batch_traces"] == 0


def test_warm_executables_counts():
    # pure counter contract, no engine: empty representative list is a no-op
    EXEC_COUNTERS.reset()
    assert warm_executables([]) == 0
    assert EXEC_COUNTERS["warm_executions"] == 0
