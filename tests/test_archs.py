"""Per-architecture smoke tests: reduced configs, one fwd/train/decode step
on CPU, asserting output shapes and finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.model import build_model

B, S = 2, 32


def make_batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder_seq, cfg.frontend_dim), cfg.activation_dtype)
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            kf, (B, cfg.num_patches, cfg.frontend_dim), cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_and_grad(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")
    logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, S)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    step = jax.jit(model.decode)
    logits, cache = step(params, cache, tokens, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    # a second step at the next position must also be finite (cache reuse)
    logits2, cache = step(params, cache, tokens, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()


def test_param_counts_match_reference():
    """Analytic counts should be in the right ballpark of the public sizes."""
    expected = {
        "qwen3-1.7b": (1.4, 2.1),
        "starcoder2-15b": (13.0, 17.0),
        "gemma3-12b": (10.0, 14.0),
        "starcoder2-3b": (2.5, 4.5),
        "whisper-base": (0.05, 0.11),
        "zamba2-2.7b": (2.0, 3.0),
        "phi-3-vision-4.2b": (3.3, 4.6),
        "deepseek-moe-16b": (14.0, 18.0),
        "kimi-k2-1t-a32b": (950.0, 1100.0),
        "xlstm-350m": (0.2, 0.45),
    }
    for arch, (lo, hi) in expected.items():
        pc = get_config(arch).param_count() / 1e9
        assert lo <= pc <= hi, f"{arch}: {pc:.2f}B outside [{lo}, {hi}]"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count() / 1e9
    assert 25 <= active <= 40  # "a32b"
