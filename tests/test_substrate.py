"""Substrate tests: checkpointing, data determinism, compression, serving,
dedup, elastic restore, train-loop resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data.dedup import Deduplicator, shingles
from repro.data.pipeline import SyntheticLMData, inverted_index, zipf_corpus
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.optim import adamw
from repro.optim.compress import (compress_tree, compression_ratio,
                                  decompress_tree, dequantize, quantize,
                                  zero_residuals)
from repro.serve.constrain import ConstraintSet, apply_mask_to_logits
from repro.serve.engine import DecodeServer, Request
from repro.serve.search import SearchEngine, zipf_query_log
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, train

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32", param_dtype="float32")


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"x": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "b": [np.ones(4), np.zeros((2, 2))]}
    ckpt.save(str(tmp_path), 7, {"state": tree})
    step, out, _ = ckpt.restore(str(tmp_path), {"state": tree})
    assert step == 7
    for got, want in zip(jax.tree_util.tree_leaves(out["state"]),
                         jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(got, want)


def test_checkpoint_atomic_and_gc(tmp_path):
    tree = {"x": np.ones(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"state": tree})
    ckpt.gc_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert len([s for s in steps if s.startswith("step_")]) == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"state": {"x": np.ones(3)}})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"state": {"x": np.ones(4)}})


# ------------------------------------------------------------------ data

def test_data_deterministic_and_stateless():
    d = SyntheticLMData(vocab=100, batch=4, seq=16, seed=3)
    b10 = d.batch_at(10)
    b10_again = d.batch_at(10)
    np.testing.assert_array_equal(b10["tokens"], b10_again["tokens"])
    assert not np.array_equal(d.batch_at(11)["tokens"], b10["tokens"])
    assert b10["tokens"].max() < 100
    # labels are next-token shifted from the same stream
    np.testing.assert_array_equal(b10["tokens"][:, 1:], b10["labels"][:, :-1])


def test_dedup_finds_near_duplicates():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 1000, 400)
    near = base.copy(); near[::10] = rng.integers(0, 1000, len(near[::10]))
    other = rng.integers(0, 1000, 400)
    dd = Deduplicator()
    dd.add(0, base); dd.add(1, near); dd.add(2, other)
    dups = dd.near_dups(threshold=0.3)
    pairs = {(a, b) for a, b, _ in dups}
    assert (0, 1) in pairs
    assert (0, 2) not in pairs and (1, 2) not in pairs


# ------------------------------------------------------------ compression

def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    q, s = quantize(g)
    back = dequantize(q, s)
    err = np.abs(np.asarray(back - g)).max()
    assert err <= float(np.abs(g).max()) / 127 + 1e-6


def test_error_feedback_unbiased_over_steps():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    grads = {"w": g}
    res = zero_residuals(grads)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        qs, ss, res = compress_tree(grads, res)
        acc = acc + decompress_tree(qs, ss)["w"]
    # accumulated transmitted sum ~= 50 * g (error feedback keeps it unbiased)
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 100)
    assert compression_ratio(grads) < 0.3


# ----------------------------------------------------------------- serve

def test_constraint_masks_gate_logits():
    cs = ConstraintSet(100)
    cs.add_allowed("a", np.arange(0, 50))
    cs.add_allowed("b", np.arange(25, 75))
    packed = cs.combined()
    logits = jnp.zeros((1, 100))
    masked = apply_mask_to_logits(logits, packed, 100)
    arr = np.asarray(masked[0])
    assert np.all(np.isfinite(arr[25:50]))
    assert np.all(np.isneginf(arr[:25])) and np.all(np.isneginf(arr[50:]))


def test_decode_server_constrained():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    cs = ConstraintSet(TINY.vocab)
    allowed = np.arange(10, 40)
    cs.add_allowed("only", allowed)
    srv = DecodeServer(model, params, batch_slots=2, max_seq=32)
    r1 = Request(prompt=np.array([1, 2]), max_new=4, constraint=cs.combined())
    r2 = Request(prompt=np.array([3]), max_new=4)
    srv.submit(r1); srv.submit(r2)
    srv.run_until_drained()
    assert len(r1.out) == 4 and all(t in set(allowed.tolist()) for t in r1.out)
    assert len(r2.out) == 4


def test_search_engine_serves_correct_results():
    docs = zipf_corpus(2000, vocab=500, mean_len=40, seed=5)
    postings = inverted_index(docs)
    eng = SearchEngine(postings, w=64, m=2)
    queries = zipf_query_log(sorted(eng.index), 20, seed=6)
    for q in queries:
        res = eng.query(q)
        truth = postings[q[0]]
        for t in q[1:]:
            truth = np.intersect1d(truth, postings[t])
        np.testing.assert_array_equal(res.doc_ids, truth)


# ------------------------------------------------------- train loop + elastic

def test_train_loop_resume_exact(tmp_path):
    model = build_model(TINY)
    mesh = make_local_mesh()
    data = SyntheticLMData(vocab=TINY.vocab, batch=2, seq=16, seed=0)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    d = str(tmp_path / "ck")
    # run 10 straight
    out_full = train(model, mesh, data,
                     LoopConfig(steps=10, ckpt_dir=d + "_full", ckpt_every=100,
                                log_every=100), opt_cfg=opt,
                     log_fn=lambda *_: None)
    # run 5, checkpoint, resume to 10
    train(model, mesh, data,
          LoopConfig(steps=5, ckpt_dir=d, ckpt_every=5, log_every=100),
          opt_cfg=opt, log_fn=lambda *_: None)
    out_b = train(model, mesh, data,
                  LoopConfig(steps=10, ckpt_dir=d, ckpt_every=100,
                             log_every=100), opt_cfg=opt,
                  log_fn=lambda *_: None)
    assert out_b["history"][0]["step"] == 5
    # identical final loss (bit-exact data, same update sequence)
    a = out_full["history"][-1]["loss"]
    b = out_b["history"][-1]["loss"]
    assert abs(a - b) < 1e-5, (a, b)


def test_elastic_remesh_restore(tmp_path):
    from repro.train.elastic import remesh
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(1))
    opt = adamw.AdamWConfig()
    state = adamw.init(opt, params)
    ckpt.save(str(tmp_path), 3, {"params": params, "opt": state})
    step, restored, mesh = remesh(model, str(tmp_path), opt_cfg=opt)
    assert step == 3
    for got, want in zip(jax.tree_util.tree_leaves(restored["params"]),
                         jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
