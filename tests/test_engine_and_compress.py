"""Device engine, baselines, and compression tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.baselines import BASELINES
from repro.core.compress import (
    compress_lowbits, decompress_group, delta_decode, delta_encode,
    gamma_decode, gamma_encode, space_report,
)
from repro.core.engine import BatchedEngine, DeviceSet, intersect_device
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import preprocess_prefix


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(5)
    fam = random_hash_family(2, 256, seed=5)
    perm = default_permutation(5)
    common = rng.choice(1 << 24, 64, replace=False).astype(np.uint32)
    sets = {}
    for name, n in [("alpha", 4000), ("beta", 9000), ("gamma", 2500)]:
        s = np.unique(np.concatenate(
            [rng.choice(1 << 24, n, replace=False).astype(np.uint32), common]))
        sets[name] = s
    idxs = {k: preprocess_prefix(v, w=256, m=2, family=fam, perm=perm)
            for k, v in sets.items()}
    return sets, idxs


def test_device_engine_matches_oracle(corpus):
    sets, idxs = corpus
    truth = np.intersect1d(sets["alpha"], sets["beta"])
    res, stats = intersect_device(
        [DeviceSet.from_host(idxs["alpha"]), DeviceSet.from_host(idxs["beta"])],
        use_pallas=False)
    assert np.array_equal(res, truth)
    assert stats["r"] == len(truth)


def test_device_engine_k3_pallas(corpus):
    sets, idxs = corpus
    truth = np.intersect1d(np.intersect1d(sets["alpha"], sets["beta"]), sets["gamma"])
    res, _ = intersect_device(
        [DeviceSet.from_host(idxs[k]) for k in ("alpha", "beta", "gamma")],
                              use_pallas=True)
    assert np.array_equal(res, truth)


def test_engine_overflow_rerun(corpus):
    sets, idxs = corpus
    truth = np.intersect1d(sets["alpha"], sets["beta"])
    res, stats = intersect_device(
        [DeviceSet.from_host(idxs["alpha"]), DeviceSet.from_host(idxs["beta"])],
        capacity=4, use_pallas=False)
    assert np.array_equal(res, truth)
    assert stats["capacity"] > 4  # re-run once at full capacity


def test_batched_engine_api(corpus):
    sets, idxs = corpus
    eng = BatchedEngine(use_pallas=False)
    for k, v in idxs.items():
        eng.add(k, v)
    res, _ = eng.query(["alpha", "gamma"])
    assert np.array_equal(res, np.intersect1d(sets["alpha"], sets["gamma"]))


@pytest.mark.parametrize("name", list(BASELINES))
def test_baselines_match_oracle(corpus, name):
    sets, _ = corpus
    a, b = sets["alpha"], sets["beta"]
    out, _ = BASELINES[name]([a, b])
    assert np.array_equal(out, np.intersect1d(a, b))


@pytest.mark.parametrize("name", ["Merge", "SvS", "Hash", "BaezaYates"])
def test_baselines_k3(corpus, name):
    sets, _ = corpus
    arrs = [sets["alpha"], sets["beta"], sets["gamma"]]
    truth = np.intersect1d(np.intersect1d(arrs[0], arrs[1]), arrs[2])
    out, _ = BASELINES[name](arrs)
    assert np.array_equal(out, truth)


def test_lowbits_roundtrip(corpus):
    _, idxs = corpus
    idx = idxs["beta"]
    c = compress_lowbits(idx)
    recon = np.concatenate([decompress_group(c, z) for z in range(1 << idx.t)])
    assert np.array_equal(recon, idx.g_keys)
    # appendix-B accounting beats storing raw 32-bit g-keys + images everywhere
    assert c.storage_bits() < idx.n * 32 + (1 << idx.t) * idx.family.m * idx.w + idx.n


@settings(max_examples=25, deadline=None)
@given(vals=st.lists(st.integers(0, 1 << 28), min_size=1, max_size=500, unique=True))
def test_property_elias_roundtrip(vals):
    arr = np.asarray(sorted(vals), dtype=np.uint32)
    for enc, dec in [(gamma_encode, gamma_decode), (delta_encode, delta_decode)]:
        bits, n = enc(arr)
        assert np.array_equal(dec(bits, n), arr)


def test_space_report_paper_regime():
    """Paper §4: uncompressed RanGroupScan ≈ +37% (m=2, w=64) vs posting list."""
    rng = np.random.default_rng(11)
    vals = np.unique(rng.choice(1 << 26, 60000, replace=False).astype(np.uint32))
    idx = preprocess_prefix(vals, w=64, m=2)
    rep = space_report(idx)
    overhead = rep["rangroupscan_uncompressed"] / rep["plain_inverted"] - 1
    assert 0.25 < overhead < 0.55  # paper: 37% for m=2
    assert rep["merge_delta"] < rep["plain_inverted"]
