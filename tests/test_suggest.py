"""Tests for the top-K suggestion subsystem (count-only execution path).

Covers, bottom-up: the ``pair_count`` kernel differentially vs a numpy
oracle (Pallas-interpret vs pure-jnp reference included), the count-only
batch executors (empty intersections, duplicate-free inputs, forced
tie-breaks, padded top-K slots), hashbin candidate pre-filtering (the
no-false-negative property at ``min_shared_bins=1``), ``plan_suggest``
routing and the suggest cache-key arm, the streaming binary ingestion
format (partial-chunk tolerance), and the :class:`SuggestEngine`
end-to-end against an exact numpy top-K oracle — warmed serving must pay
zero fresh traces, and a forced-8-device subprocess re-checks bit-identity
on the 4-shard and 2x2 mesh paths so every tier-1 run exercises the
multi-path acceptance guarantee.
"""
import io
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, default_k_tier, intersect_count_batch,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import preprocess_prefix
from repro.data.ingest import (
    MAGIC, ingest_file, read_records, stream_records, write_records,
)
from repro.exec.candidates import CandidateIndex
from repro.exec.plan import plan_suggest
from repro.kernels.ops import pair_count
from repro.kernels.count import pair_count_pallas, pair_count_ref
from repro.serve.search import SuggestEngine


def _oracle_counts(probe, cands):
    probe = np.unique(np.asarray(probe, np.uint32))
    return [len(np.intersect1d(probe, np.unique(np.asarray(c, np.uint32))))
            for c in cands]


def _oracle_topk(corpus, sid, k):
    pairs = []
    for c in sorted(corpus):
        if c == sid:
            continue
        n = len(np.intersect1d(np.unique(corpus[sid]), np.unique(corpus[c])))
        if n >= 1:
            pairs.append((c, n))
    pairs.sort(key=lambda p: (-p[1], p[0]))
    return pairs[:k]


# ---------------------------------------------------------------------------
# pair_count kernel: differential vs numpy, interpret-Pallas vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,ga,gb", [(1, 1, 1), (3, 4, 8), (8, 16, 4),
                                     (13, 7, 31)])
def test_pair_count_matches_numpy(s, ga, gb):
    """Duplicate-free rows (the count path's input invariant) with a
    random overlap fraction and random sentinel padding in A."""
    rng = np.random.default_rng(s * 100 + ga)
    a = np.empty((s, ga), np.int32)
    b = np.empty((s, gb), np.int32)
    for i in range(s):
        pool = rng.permutation(200).astype(np.int32)
        a[i] = pool[:ga]
        take = int(rng.integers(0, min(ga, gb) + 1))  # forced overlap size
        b[i] = np.concatenate([
            rng.permutation(a[i])[:take], pool[ga:ga + gb - take]])
        n_pad = int(rng.integers(0, ga))              # sentinel-pad A's tail
        if n_pad:
            a[i, ga - n_pad:] = -1
    want = np.array([
        len(np.intersect1d(a[i][a[i] != -1], b[i]))
        for i in range(s)
    ], np.int32)
    got_ref = np.asarray(pair_count_ref(a, b))
    got_pal = np.asarray(pair_count_pallas(a, b, interpret=True))
    assert np.array_equal(got_ref, want)
    assert np.array_equal(got_pal, want)


def test_pair_count_empty_and_disjoint():
    a = np.full((4, 8), -1, np.int32)          # all-sentinel rows
    b = np.arange(4 * 8, dtype=np.int32).reshape(4, 8)
    assert np.array_equal(np.asarray(pair_count(a, b, use_pallas=False)),
                          np.zeros(4, np.int32))
    # fully disjoint live rows
    a2 = np.arange(32, dtype=np.int32).reshape(4, 8)
    b2 = a2 + 1000
    assert np.array_equal(np.asarray(pair_count(a2, b2, use_pallas=False)),
                          np.zeros(4, np.int32))
    # identical rows count every element once (duplicate-free invariant)
    assert np.array_equal(np.asarray(pair_count(a2, a2, use_pallas=False)),
                          np.full(4, 8, np.int32))


def test_pair_count_batched_leading_axes():
    rng = np.random.default_rng(5)
    a = rng.permutation(200)[:96].astype(np.int32).reshape(2, 3, 4, 4)
    b = rng.permutation(200)[:48].astype(np.int32).reshape(2, 3, 4, 2)
    got = np.asarray(pair_count(a, b, use_pallas=False))
    assert got.shape == (2, 3, 4)
    flat = np.asarray(pair_count(a.reshape(-1, 4), b.reshape(-1, 2),
                                 use_pallas=False))
    assert np.array_equal(got.reshape(-1), flat)


# ---------------------------------------------------------------------------
# count-only executor: oracle counts, tie-break, padded slots
# ---------------------------------------------------------------------------

def _build_sets(rng, sizes, universe=1 << 18, t=3, gmax=64):
    # one (t, gmax) class: bucket stacking requires uniform shapes
    fam = random_hash_family(2, 256, seed=1)
    perm = default_permutation(1)
    pool = rng.choice(universe, size=max(sizes) * 8, replace=False)
    vals = [np.sort(rng.choice(pool, size=n, replace=False)).astype(np.uint32)
            for n in sizes]
    idxs = [preprocess_prefix(v, family=fam, perm=perm, t=t, gmax=gmax)
            for v in vals]
    return vals, [DeviceSet.from_host(i) for i in idxs]


def test_intersect_count_batch_oracle_and_tiebreak():
    rng = np.random.default_rng(2)
    vals, sets = _build_sets(rng, [120, 90, 90, 60, 60, 30], gmax=64)
    probe_v, probe = vals[0], sets[0]
    cands = sets[1:] + [sets[1]]            # duplicate candidate: forced tie
    cand_vals = vals[1:] + [vals[1]]
    (pairs, stats), = intersect_count_batch([(probe, cands)], k=8,
                                            use_pallas=False)
    want = _oracle_counts(probe_v, cand_vals)
    got = {int(i): int(c) for i, c in pairs if c >= 0}
    for idx, w in enumerate(want):
        if w >= 1:
            assert got[idx] == w, (idx, got, want)
    # forced tie between candidate 0 and its duplicate at index 5: equal
    # counts order by ascending candidate index (== ascending id under the
    # planner's sorted-terms contract)
    ranked = [int(i) for i, c in pairs if c >= 1]
    if want[0] >= 1:
        assert ranked.index(0) < ranked.index(5)
    assert stats["k_sel"] == min(8, stats["c_tier"])


def test_intersect_count_padded_slots_carry_minus_one():
    rng = np.random.default_rng(9)
    vals, sets = _build_sets(rng, [64, 64, 64, 64], gmax=64)
    (pairs, stats), = intersect_count_batch([(sets[0], sets[1:])], k=8,
                                            use_pallas=False)
    # 3 candidates -> c_tier 4, k_sel 4: the padded 4th slot must rank
    # last with count -1 (candidate-axis padding is masked in-jit)
    assert stats["c_tier"] == 4 and pairs.shape == (4, 2)
    assert int(pairs[-1, 1]) == -1
    want = _oracle_counts(vals[0], vals[1:])
    got = {int(i): int(c) for i, c in pairs if c >= 0}
    for idx, w in enumerate(want):
        assert got.get(idx, 0) == w, (got, want)


def test_default_k_tier():
    assert [default_k_tier(k) for k in (1, 8, 9, 16, 100)] == \
        [8, 8, 16, 16, 128]


# ---------------------------------------------------------------------------
# candidate pre-filter: no false negatives at min_shared_bins=1
# ---------------------------------------------------------------------------

def test_candidate_prefilter_never_drops_true_overlap():
    rng = np.random.default_rng(11)
    fam = random_hash_family(2, 256, seed=4)
    ci = CandidateIndex(fam)
    corpus = {}
    pool = rng.choice(1 << 20, size=5000, replace=False)
    for sid in range(60):
        corpus[sid] = rng.choice(
            pool, size=int(rng.integers(10, 200)), replace=False
        ).astype(np.uint32)
        ci.add(sid, corpus[sid])
    assert len(ci) == 60 and 3 in ci
    for sid in (0, 7, 33):
        kept = set(ci.candidates(corpus[sid], exclude=sid))
        assert sid not in kept
        for c in corpus:
            if c != sid and len(np.intersect1d(corpus[sid], corpus[c])):
                assert c in kept, (sid, c)
    assert EXEC_COUNTERS["suggest_prefilter_in"] == 3 * 60
    assert EXEC_COUNTERS["suggest_prefilter_kept"] > 0


def test_candidate_prefilter_cap_keeps_most_shared_prefix():
    fam = random_hash_family(2, 256, seed=4)
    ci = CandidateIndex(fam)
    base = np.arange(100, dtype=np.uint32)
    ci.add("near", base[:90])
    ci.add("far", np.arange(10**6, 10**6 + 90, dtype=np.uint32))
    kept = ci.candidates(base, max_candidates=1)
    assert kept == ["near"]


# ---------------------------------------------------------------------------
# planner: suggest signatures, routing, cache key
# ---------------------------------------------------------------------------

def test_plan_suggest_signature_and_cache_key():
    rng = np.random.default_rng(21)
    fam = random_hash_family(2, 256, seed=2)
    perm = default_permutation(2)
    index = {
        sid: preprocess_prefix(
            rng.choice(1 << 16, size=80, replace=False).astype(np.uint32),
            family=fam, perm=perm, gmax=64)
        for sid in range(6)
    }
    plan = plan_suggest(index, 0, [3, 1, 2], k=5)
    assert plan.algorithm == "device"
    assert plan.terms == (0, 1, 2, 3)       # candidates sorted ascending
    assert plan.sig.cands == 4              # pow2 tier over 3 candidates
    assert plan.sig.capacity_tier == default_k_tier(5) == 8
    kind, _ = plan.cache_key()
    assert kind == "suggest"
    # k-tier is part of the key: suggest(., 5) never serves suggest(., 100)
    assert plan.cache_key() != plan_suggest(index, 0, [3, 1, 2],
                                            k=100).cache_key()
    # unknown candidate or probe -> empty plan
    assert plan_suggest(index, 0, [99], k=5).algorithm == "empty"
    assert plan_suggest(index, 99, [1], k=5).algorithm == "empty"
    assert plan_suggest(index, 0, [], k=5).algorithm == "empty"
    # host routing
    assert plan_suggest(index, 0, [1], k=5, device=False).algorithm == "host"
    # mixed (t, gmax_tier) classes are a planner contract violation
    index[999] = preprocess_prefix(
        rng.choice(1 << 18, size=3000, replace=False).astype(np.uint32),
        family=fam, perm=perm)
    with pytest.raises(AssertionError):
        plan_suggest(index, 0, [1, 999], k=5)


# ---------------------------------------------------------------------------
# streaming ingestion: roundtrip, chunk boundaries, error paths
# ---------------------------------------------------------------------------

def test_ingest_roundtrip_and_partial_chunks(tmp_path):
    rng = np.random.default_rng(3)
    recs = [(i, rng.integers(0, 1 << 20, size=int(rng.integers(1, 200)),
                             dtype=np.uint32)) for i in range(25)]
    path = tmp_path / "corpus.rsi"
    assert write_records(path, recs) == 25
    back = list(read_records(path))
    assert [i for i, _ in back] == [i for i, _ in recs]
    assert all(np.array_equal(v, w) for (_, v), (_, w) in zip(recs, back))
    raw = path.read_bytes()
    assert raw[:4] == MAGIC
    # worst-case streaming: 1-byte chunks force every boundary straddle
    again = list(stream_records(bytes([b]) for b in raw))
    assert all(np.array_equal(v, w) for (_, v), (_, w) in zip(back, again))
    # stream into an in-memory sink via the write side too
    buf = io.BytesIO()
    write_records(buf, recs[:3])
    assert len(list(stream_records([buf.getvalue()]))) == 3


def test_ingest_rejects_bad_magic_and_truncation(tmp_path):
    path = tmp_path / "c.rsi"
    write_records(path, [(1, np.arange(10, dtype=np.uint32))])
    raw = path.read_bytes()
    with pytest.raises(ValueError, match="magic"):
        list(stream_records([b"XXXX" + raw[4:]]))
    with pytest.raises(ValueError, match="truncated"):
        list(stream_records([raw[:-2]]))


def test_ingest_file_feeds_engine_incrementally(tmp_path):
    rng = np.random.default_rng(8)
    pool = rng.choice(1 << 18, size=3000, replace=False)
    corpus = {sid: rng.choice(pool, size=60, replace=False).astype(np.uint32)
              for sid in range(12)}
    path = tmp_path / "c.rsi"
    write_records(path, [*corpus.items(), (99, np.array([], np.uint32))])
    eng = SuggestEngine({}, use_device=False)
    assert ingest_file(path, eng) == 12      # empty record skipped
    got = eng.suggest(0, 5)
    assert got.suggestions == _oracle_topk(corpus, 0, 5)


# ---------------------------------------------------------------------------
# SuggestEngine end-to-end: oracle, caching, warming, mutation
# ---------------------------------------------------------------------------

def _make_corpus(seed=0, n_sets=30, lo=30, hi=250):
    rng = np.random.default_rng(seed)
    pool = rng.choice(1 << 20, size=4000, replace=False)
    corpus = {
        sid: rng.choice(pool, size=int(rng.integers(lo, hi)),
                        replace=False).astype(np.uint32)
        for sid in range(n_sets)
    }
    corpus[100] = corpus[3].copy()   # forced exact ties (identical sets)
    corpus[101] = corpus[3].copy()
    return corpus


def test_suggest_engine_matches_oracle_device():
    corpus = _make_corpus()
    eng = SuggestEngine(corpus, use_device=True)
    for sid in (0, 3, 100, 17):
        for k in (1, 5, 10):
            got = eng.suggest(sid, k)
            assert got.suggestions == _oracle_topk(corpus, sid, k), (sid, k)
            assert got.algorithm.startswith("suggest/")
    # tie-break visible end-to-end: probing 101 ranks 3 before 100
    top = eng.suggest(101, 3).suggestions
    assert top[0][0] == 3 and top[1][0] == 100
    with pytest.raises(KeyError):
        eng.suggest(999, 5)


def test_suggest_engine_matches_oracle_host():
    corpus = _make_corpus(seed=4, n_sets=15)
    eng = SuggestEngine(corpus, use_device=False)
    for sid in (0, 3, 100):
        got = eng.suggest(sid, 6)
        assert got.suggestions == _oracle_topk(corpus, sid, 6)
        assert got.algorithm == "suggest/host"


def test_suggest_engine_result_cache_and_mutation():
    corpus = _make_corpus(seed=1, n_sets=15)
    eng = SuggestEngine(corpus, use_device=True)
    first = eng.suggest(2, 5)
    h0 = EXEC_COUNTERS["result_cache_hits"]
    c0 = EXEC_COUNTERS["count_calls"]
    hit = eng.suggest(2, 5)
    assert hit.stats.get("cached") and hit.suggestions == first.suggestions
    assert EXEC_COUNTERS["result_cache_hits"] == h0 + 1
    assert EXEC_COUNTERS["count_calls"] == c0
    # a different k is a different cache entry
    assert not eng.suggest(2, 4).stats.get("cached")
    # index mutation invalidates: the new overlap must show up
    eng.add_set(2, np.concatenate([corpus[2], corpus[7][:10]]))
    corpus[2] = np.unique(np.concatenate([corpus[2], corpus[7][:10]]))
    got = eng.suggest(2, 5)
    assert not got.stats.get("cached")
    assert got.suggestions == _oracle_topk(corpus, 2, 5)


def test_suggest_engine_warm_zero_serve_traces():
    corpus = _make_corpus(seed=2, n_sets=20)
    eng = SuggestEngine(corpus, use_device=True)
    warmed = eng.warm([5, 6, 7], k=8)
    assert warmed and all(s.cands > 0 for s in warmed)
    t0 = EXEC_COUNTERS["count_traces"]
    got = eng.suggest(5, 8)
    assert got.suggestions == _oracle_topk(corpus, 5, 8)
    assert EXEC_COUNTERS["count_traces"] == t0, "warmed serve retraced"


def test_suggest_batch_shares_buckets():
    corpus = _make_corpus(seed=3, n_sets=20)
    eng = SuggestEngine(corpus, use_device=True)
    c0 = EXEC_COUNTERS["count_calls"]
    got = eng.suggest_batch([(0, 5), (1, 5), (2, 5), (3, 5)])
    for (sid, k), r in zip([(0, 5), (1, 5), (2, 5), (3, 5)], got):
        assert r.suggestions == _oracle_topk(corpus, sid, k)
    # same-signature classes across the 4 probes share jit executions:
    # far fewer device passes than probes x classes
    n_classes = sum(r.stats["classes"] for r in got)
    assert EXEC_COUNTERS["count_calls"] - c0 < n_classes


# ---------------------------------------------------------------------------
# acceptance: bit-identity on 4-shard and 2x2 paths (fresh interpreter,
# 8 forced host devices — runs on every tier-1 invocation)
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core.engine import EXEC_COUNTERS, SHARD_AXIS
from repro.exec.topology import make_topology
from repro.serve.search import SuggestEngine

rng = np.random.default_rng(7)
pool = rng.choice(1 << 20, size=30000, replace=False)
corpus = {sid: rng.choice(pool, size=int(rng.integers(800, 2000)),
                          replace=False).astype(np.uint32)
          for sid in range(16)}
corpus[50] = corpus[2].copy()        # forced tie

def oracle(sid, k):
    pairs = []
    for c in sorted(corpus):
        if c == sid: continue
        n = len(np.intersect1d(np.unique(corpus[sid]),
                               np.unique(corpus[c])))
        if n >= 1: pairs.append((c, n))
    pairs.sort(key=lambda p: (-p[1], p[0]))
    return pairs[:k]

probes = [(s, k) for s in (0, 2, 50, 9) for k in (5, 12)]
want = {p: oracle(*p) for p in probes}

plain = SuggestEngine(corpus, use_device=True)
for p, e in want.items():
    assert plain.suggest(*p).suggestions == e, ("plain", p)

mesh = Mesh(np.array(jax.devices()[:4]), (SHARD_AXIS,))
sh = SuggestEngine(corpus, mesh=mesh, shard_min_g=1)
assert sh.suggest(0, 5).algorithm == "suggest/sharded"
for p, e in want.items():
    assert sh.suggest(*p).suggestions == e, ("sharded", p)

topo = make_topology(replicas=2, shards=2)
m2 = SuggestEngine(corpus, topology=topo, shard_min_g=1)
assert m2.suggest(0, 5).algorithm == "suggest/mesh2d"
for p, e in want.items():
    assert m2.suggest(*p).suggestions == e, ("mesh2d", p)

# warmed 2-D serving pays zero fresh traces
m2.warm([9], 12)
t0 = EXEC_COUNTERS["count_traces"]
assert m2.suggest(9, 12).suggestions == want[(9, 12)]
assert EXEC_COUNTERS["count_traces"] == t0
print("SUGGEST_SUBPROCESS_OK")
"""


def test_suggest_oracle_in_forced_multidevice_subprocess():
    """The acceptance guarantee, independent of this process's device
    count: a fresh interpreter with 8 forced host devices must produce
    bit-identical top-K (deterministic tie-break included) on the plain,
    4-shard, and 2x2 mesh paths, and warmed 2-D serving must not
    retrace."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SUGGEST_SUBPROCESS_OK" in proc.stdout


# ---------------------------------------------------------------------------
# multi-device in-process variants (skip on single-device runs)
# ---------------------------------------------------------------------------

N_DEVICES = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@multi_device
def test_suggest_sharded_in_process_oracle():
    corpus = _make_corpus(seed=6, n_sets=12, lo=300, hi=900)
    from jax.sharding import Mesh
    from repro.core.engine import SHARD_AXIS
    mesh = Mesh(np.array(jax.devices()[:4]), (SHARD_AXIS,))
    plain = SuggestEngine(corpus, use_device=True)
    sh = SuggestEngine(corpus, mesh=mesh, shard_min_g=1)
    for sid in (0, 3, 100):
        assert (sh.suggest(sid, 6).suggestions
                == plain.suggest(sid, 6).suggestions
                == _oracle_topk(corpus, sid, 6))


@multi_device
def test_suggest_mesh2d_in_process_oracle():
    corpus = _make_corpus(seed=7, n_sets=12, lo=300, hi=900)
    from repro.exec.topology import make_topology
    topo = make_topology(replicas=2, shards=2)
    eng = SuggestEngine(corpus, topology=topo, shard_min_g=1)
    for sid in (0, 3, 100):
        assert eng.suggest(sid, 6).suggestions == _oracle_topk(
            corpus, sid, 6)
