"""Tests for the asynchronous dispatch pipeline (dispatch / collect split).

Covers: the overlap telemetry (``inflight_dispatches`` / ``collect_us`` /
``overlap_high_water``), bit-identity of overlapped execution vs the
synchronous path, balancer in-flight visibility during overlapping
dispatch (release moved to collect time), the flusher's event-driven
wait (no flat idle-timer reliance — the busy-poll regression), and a
race regression hammering ``submit`` while buckets are in flight.

Balancer-visibility tests need >= 4 devices; everything else runs on one.
The subprocess oracle test always runs: a fresh interpreter with 8
forced host devices serves the overlapped ``AsyncSearchEngine`` flusher
across the 1x4 / 2x2 / 4x1 layouts and must reproduce the synchronous
``query_batch`` bit-identically with a nonzero overlap high-water mark.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest
import jax

from repro.core.engine import EXEC_COUNTERS, PendingBatch
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.batch import bucket_plans, dispatch_bucket, execute_plan_buckets
from repro.exec.topology import make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine, zipf_query_log

N_DEVICES = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(3000, vocab=400, mean_len=40, seed=3)
    return inverted_index(docs)


# ---------------------------------------------------------------------------
# PendingBatch basics
# ---------------------------------------------------------------------------

def test_pending_batch_empty_and_memoized():
    pb = PendingBatch(n_queries=0, _collect=lambda: [])
    assert pb.is_ready()
    first = pb.collect()
    assert first == []
    assert pb.collect() is first  # memoized — the closure ran exactly once


# ---------------------------------------------------------------------------
# Overlapped vs synchronous bit-identity + overlap counters (single device)
# ---------------------------------------------------------------------------

def _engine_lambdas(eng):
    return dict(
        use_pallas=eng.device.use_pallas,
        mesh=eng.device.mesh,
        shard_axis=eng.device.shard_axis,
        get_sharded_set=lambda term: eng.device.get_mesh_set(str(term)),
        capacity_model=eng.capacity_model,
        topology=eng.device.topology,
        get_replica_set=lambda r, term: eng.device.get_replica_set(
            r, str(term)),
    )


def test_execute_plan_buckets_overlapped_matches_sequential(postings):
    """The pipelined window (max_inflight > 1) must be bit-identical to
    strictly sequential dispatch-then-collect execution."""
    eng = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 24, seed=11)
    plans = [(i, eng.plan(q)) for i, q in enumerate(log)]
    device_plans = [(i, p) for i, p in plans if p.algorithm == "device"]
    assert len(bucket_plans(device_plans)) >= 2, "need >= 2 signatures"
    get_set = lambda term: eng.device.sets[str(term)]  # noqa: E731
    seq = execute_plan_buckets(get_set, device_plans, max_inflight=1,
                               **_engine_lambdas(eng))
    EXEC_COUNTERS.reset()
    ovl = execute_plan_buckets(get_set, device_plans, max_inflight=4,
                               **_engine_lambdas(eng))
    assert seq.keys() == ovl.keys()
    for i in seq:
        assert np.array_equal(seq[i][0], ovl[i][0]), log[i]
    n_buckets = len(bucket_plans(device_plans))
    assert EXEC_COUNTERS["inflight_dispatches"] == n_buckets
    assert EXEC_COUNTERS["collect_us"] > 0
    assert EXEC_COUNTERS["overlap_high_water"] >= min(2, n_buckets)


def test_drain_overlaps_buckets_and_counts(postings):
    """A manual-mode drain dispatches every queued bucket back-to-back
    into the window before collecting: the high-water mark must show
    real overlap and every ticket must match the synchronous oracle."""
    base = SearchEngine(postings, seed=3, use_device=True)
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            result_cache=0, max_inflight=8)
    log = zipf_query_log(sorted(base.index), 24, seed=11)
    want = base.query_batch(log)  # oracle first: it bumps counters too
    tickets = [eng.submit(q) for q in log]  # below tier: nothing flushes
    EXEC_COUNTERS.reset()
    n_buckets = eng.drain()
    assert n_buckets >= 2
    for q, t, b in zip(log, tickets, want):
        assert t.done
        assert np.array_equal(t.value.doc_ids, b.doc_ids), q
    assert EXEC_COUNTERS["inflight_dispatches"] == n_buckets
    assert EXEC_COUNTERS["overlap_high_water"] >= 2
    assert EXEC_COUNTERS["collect_us"] > 0
    assert eng._inflight_count() == 0  # window fully reaped


def test_window_bound_respected(postings):
    """max_inflight=1 degenerates to the synchronous flush: the high-water
    mark never exceeds the window bound."""
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            result_cache=0, max_inflight=1)
    log = zipf_query_log(sorted(eng.index), 24, seed=11)
    tickets = [eng.submit(q) for q in log]
    EXEC_COUNTERS.reset()
    eng.drain()
    assert all(t.done for t in tickets)
    assert EXEC_COUNTERS["overlap_high_water"] <= 1


# ---------------------------------------------------------------------------
# Balancer: release moved to collect time (needs replica rows)
# ---------------------------------------------------------------------------

@multi_device
def test_balancer_inflight_visible_during_overlapping_dispatch(postings):
    """Satellite 1 acceptance: while two buckets are dispatched but not
    yet collected, the balancer must account nonzero in-flight weight on
    two different replica rows (release happens at collect, not at
    dispatch) — and return to zero once both collect."""
    topo = make_topology(2, 1)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 32, seed=11)
    plans = [(i, eng.plan(q)) for i, q in enumerate(log)]
    buckets = bucket_plans([(i, p) for i, p in plans
                            if p.algorithm == "device"])
    sigs = list(buckets)
    assert len(sigs) >= 2
    get_set = lambda term: eng.device.sets[str(term)]  # noqa: E731
    EXEC_COUNTERS.reset()
    a = dispatch_bucket(get_set, sigs[0], buckets[sigs[0]],
                        **_engine_lambdas(eng))
    b = dispatch_bucket(get_set, sigs[1], buckets[sigs[1]],
                        **_engine_lambdas(eng))
    busy = [d["in_flight"] for d in topo.load_snapshot()]
    assert sum(1 for x in busy if x > 0) == 2, busy
    by_index = dict(a.collect())
    by_index.update(b.collect())
    after = [d["in_flight"] for d in topo.load_snapshot()]
    assert all(x == 0 for x in after), after
    assert EXEC_COUNTERS["overlap_high_water"] >= 2
    assert EXEC_COUNTERS["inflight_dispatches"] == 2
    want = base.query_batch(log)  # oracle last: it bumps counters too
    for i, (res, _stats) in by_index.items():
        assert np.array_equal(res, want[i].doc_ids), log[i]
    # collect is idempotent and the release fired exactly once
    a.collect()
    assert all(d["in_flight"] == 0 for d in topo.load_snapshot())


@multi_device
def test_balancer_release_on_dispatch_failure(postings):
    """A dispatch that raises must give its balancer slot back immediately
    (nothing will ever collect it)."""
    topo = make_topology(2, 1)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
    log = zipf_query_log(sorted(eng.index), 8, seed=11)
    plans = [(i, eng.plan(q)) for i, q in enumerate(log)]
    buckets = bucket_plans([(i, p) for i, p in plans
                            if p.algorithm == "device"])
    sig = next(iter(buckets))
    kw = _engine_lambdas(eng)
    kw["get_replica_set"] = lambda r, term: (_ for _ in ()).throw(
        RuntimeError("mirror build failed"))
    with pytest.raises(RuntimeError, match="mirror build failed"):
        dispatch_bucket(lambda term: eng.device.sets[str(term)],
                        sig, buckets[sig], **kw)
    assert all(d["in_flight"] == 0 for d in topo.load_snapshot())


# ---------------------------------------------------------------------------
# Flusher: event-driven waits (busy-poll regression) + submit race
# ---------------------------------------------------------------------------

def test_flusher_resolves_before_idle_timer(postings):
    """Satellite 2 regression: with a pathologically large idle re-check
    cadence the flusher must still resolve a deadline-flushed ticket
    promptly — it wakes on the submit event and sleeps exactly until the
    admission deadline, never the flat idle timer."""
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            deadline_us=1000.0, result_cache=0)
    eng._flusher_idle_s = 60.0  # a busy-poll loop would hang 60s here
    with eng:
        q = zipf_query_log(sorted(eng.index), 1, seed=11)[0]
        t0 = time.perf_counter()
        ticket = eng.submit(q)
        assert ticket.wait(timeout=10.0)
        assert time.perf_counter() - t0 < 10.0
    assert eng._flusher_error is None


def test_submit_race_two_buckets_in_flight(postings):
    """Race regression: many threads hammer ``submit`` while the flusher
    overlaps dispatch and collect (tiny flush tier forces constant
    flushes, two signatures keep two buckets in flight).  Every ticket
    must resolve to the synchronous oracle's exact result."""
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(base.index), 48, seed=11)
    want = {tuple(q): r for q, r in zip(log, base.query_batch(log))}
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=4,
                            deadline_us=500.0, result_cache=0,
                            max_inflight=4)
    tickets = []
    tlock = threading.Lock()

    def hammer(span):
        for q in span:
            t = eng.submit(q)
            with tlock:
                tickets.append((q, t))

    with eng:
        threads = [threading.Thread(target=hammer, args=(log[i::4],))
                   for i in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        eng.drain()
    assert eng._flusher_error is None
    assert len(tickets) == len(log)
    for q, t in tickets:
        assert t.done
        assert np.array_equal(t.value.doc_ids, want[tuple(q)].doc_ids), q


# ---------------------------------------------------------------------------
# Forced-8-device subprocess oracle (always runs)
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# CPU explicitly: with libtpu on the image, a second jax process would
# otherwise block minutes on the parent's /tmp/libtpu_lockfile
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
from repro.core.engine import EXEC_COUNTERS
from repro.exec.topology import make_topology
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.search import AsyncSearchEngine, SearchEngine, zipf_query_log

docs = zipf_corpus(2000, vocab=300, mean_len=30, seed=3)
postings = inverted_index(docs)
base = SearchEngine(postings, seed=3, use_device=True)
log = zipf_query_log(sorted(base.index), 24, seed=11)
want = base.query_batch(log)

# overlapped flusher bit-identity vs synchronous query_batch, all layouts
for layout in [(1, 4), (2, 2), (4, 1)]:
    topo = make_topology(*layout)
    eng = AsyncSearchEngine(postings, seed=3, topology=topo, shard_min_g=4,
                            flush_tier=4, deadline_us=500.0, result_cache=0,
                            max_inflight=8)
    EXEC_COUNTERS.reset()
    eng.start()
    tickets = [eng.submit(q) for q in log]
    eng.stop(drain=True)
    assert eng._flusher_error is None, (layout, eng._flusher_error)
    for q, t, b in zip(log, tickets, want):
        assert t.done, (layout, q)
        assert np.array_equal(t.value.doc_ids, b.doc_ids), (layout, q)
    assert EXEC_COUNTERS["inflight_dispatches"] > 0, layout
    assert EXEC_COUNTERS["collect_us"] > 0, layout
    # balancer fully drained: release fired at collect for every bucket
    assert all(d["in_flight"] == 0 for d in topo.load_snapshot()), layout

# deterministic overlap: manual drain dispatches all queued buckets
# back-to-back before collecting — high-water mark must show it, and the
# replica balancer must end the run fully released
topo = make_topology(4, 1)
eng = AsyncSearchEngine(postings, seed=3, topology=topo,
                        shard_min_g=1 << 20, flush_tier=64,
                        result_cache=0, max_inflight=8)
tickets = [eng.submit(q) for q in log]
EXEC_COUNTERS.reset()
n_buckets = eng.drain()
assert n_buckets >= 2
for t, b in zip(tickets, want):
    assert t.done
    assert np.array_equal(t.value.doc_ids, b.doc_ids)
assert EXEC_COUNTERS["overlap_high_water"] >= 2
assert EXEC_COUNTERS["inflight_dispatches"] == n_buckets
assert all(d["in_flight"] == 0 for d in topo.load_snapshot())
print("ASYNC_DISPATCH_SUBPROCESS_OK")
"""


def test_overlapped_serving_in_forced_multidevice_subprocess():
    """The acceptance guarantee, independent of this process's device
    count: a fresh interpreter with 8 forced host devices must serve the
    overlapped ``AsyncSearchEngine`` flusher bit-identically to
    synchronous ``query_batch`` on 1x4, 2x2, and 4x1 topologies, leave
    the replica balancer fully released after collect, and record a
    nonzero overlap high-water mark on a manual drain."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ASYNC_DISPATCH_SUBPROCESS_OK" in proc.stdout
