"""Boolean expression engine: normalizer units + end-to-end differentials.

Three layers of coverage for the ∪/∩/∖ expression DAG:

- **Normalizer algebra** (pure, no device): flattening, dedup,
  absorption, difference push-down/cascade, ∅ propagation, parser
  precedence, canonical-form idempotence — asserted via ``expr_key``
  equality of differently-written equivalent expressions.
- **Flat regression**: an expression that normalizes to a bare
  conjunction must produce a plan *equal* to the term-list plan — same
  terms, signature (``eshape is None``), and cache key — so the existing
  flat workload is byte-identical under the refactor.
- **Differential properties**: random expressions through the full
  serving pipeline (plan → bucket → execute → scatter, sync and async
  flusher) must be bit-identical to the ``eval_host`` numpy oracle on the
  plain device engine, the 4-shard mesh, and the 2x2 topology; forced
  tiny capacities at union/difference nodes must re-run enlarged and stay
  exact; shared subtrees must resolve from the subexpression cache with
  the advertised counters.

Seeded variants always run; hypothesis ``@given`` twins explore fresh
seeds where hypothesis is installed (``_hypothesis_compat`` shim).
"""
import numpy as np
import pytest
import jax
from _hypothesis_compat import given, settings, st

from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, intersect_expr_batch,
    intersect_expr_sharded_batch, make_shard_mesh,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import preprocess_prefix
from repro.exec.adaptive import adaptive_key
from repro.exec.cache import ResultCache
from repro.exec.expr import (
    EMPTY, And, Diff, Or, Term, canonicalize, eval_host, expr_key,
    expr_shape, flat_terms, leaf_terms, parse, subexpr_keys,
)
from repro.exec.plan import plan_query
from repro.exec.topology import make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine

N_DEVICES = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

SEED_MAX = (1 << 31) - 1


# ---------------------------------------------------------------------------
# normalizer algebra (metadata-only index: .t/.n/.gmax is all it reads)
# ---------------------------------------------------------------------------

class _Meta:
    def __init__(self, t, n, gmax=4):
        self.t, self.n, self.gmax = t, n, gmax


IDX = {name: _Meta(t=i % 3 + 1, n=10 + 7 * i)
       for i, name in enumerate("abcdef")}


def _key(s):
    return expr_key(canonicalize(parse(s), IDX))


def test_flatten_sort_dedup():
    assert _key("a&(b&c)") == _key("(c&a)&b") == _key("b&c&a&b")
    assert _key("a|(b|c)") == _key("(c|a)|b") == _key("b|c|a|b")


def test_absorb_and_singletons():
    assert _key("a&a") == _key("a") == ("t", "a")
    assert _key("a|a") == ("t", "a")
    assert canonicalize(parse("a-a"), IDX) is EMPTY


def test_difference_pushdown_and_cascade():
    # (a∪b)∖c = (a∖c)∪(b∖c); (a∖b)∖c = a∖(b∪c)
    assert _key("(a|b)-c") == _key("(a-c)|(b-c)")
    assert _key("(a-b)-c") == _key("a-(b|c)")
    # subtrahends of an And's Diff children hoist: (a∖d)&b = (a&b)∖d
    assert _key("(a-d)&b") == _key("(a&b)-d")
    # a∖(anything ∪ a) is empty
    assert canonicalize(parse("a-(b|a)"), IDX) is EMPTY


def test_empty_propagation_unknown_terms():
    # unknown term -> ∅: annihilates ∩, drops from ∪, empties ∖ left
    assert canonicalize(parse("a&zz"), IDX) is EMPTY
    assert _key("a|zz") == ("t", "a")
    assert canonicalize(parse("zz-a"), IDX) is EMPTY
    assert _key("a-zz") == ("t", "a")


def test_parser_precedence_and_ints():
    # '&' binds tighter than '|' binds tighter than '-'
    e = parse("a&b|c-d")
    assert isinstance(e, Diff)
    assert isinstance(e.left, Or)
    assert isinstance(e.left.children[0], And)
    assert parse("1&2") == And((Term(1), Term(2)))
    assert parse("a ∩ b ∪ c ∖ d") == parse("a&b|c-d")
    with pytest.raises(ValueError):
        parse("a &")
    with pytest.raises(ValueError):
        parse("(a|b")


def _random_expr(rng, terms, depth=0, max_depth=2):
    if depth >= max_depth or rng.random() < 0.35:
        return Term(terms[int(rng.integers(0, len(terms)))])
    op = int(rng.integers(0, 3))
    if op == 2:
        return Diff(_random_expr(rng, terms, depth + 1, max_depth),
                    _random_expr(rng, terms, depth + 1, max_depth))
    kids = tuple(_random_expr(rng, terms, depth + 1, max_depth)
                 for _ in range(int(rng.integers(2, 4))))
    return And(kids) if op == 0 else Or(kids)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_canonicalize_idempotent(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        can = canonicalize(_random_expr(rng, list("abcdef"), max_depth=3),
                           IDX)
        if can is EMPTY:
            continue
        again = canonicalize(can, IDX)
        assert expr_key(again) == expr_key(can)
        # leaf bookkeeping is consistent with the erased shape: one "T"
        # per leaf, in the same traversal order
        shape = expr_shape(can)
        n_shape_leaves = 1 if shape == "T" else repr(shape).count("'T'")
        assert len(leaf_terms(can)) == n_shape_leaves


def test_flat_terms_detection():
    assert flat_terms(canonicalize(parse("a&b&a"), IDX)) is not None
    assert flat_terms(canonicalize(parse("a"), IDX)) == ("a",)
    assert flat_terms(canonicalize(parse("a|b"), IDX)) is None
    assert flat_terms(canonicalize(parse("(a&b)-c"), IDX)) is None


def test_eval_host_oracle():
    vals = {"a": np.array([1, 2, 3, 4], np.uint32),
            "b": np.array([3, 4, 5], np.uint32),
            "c": np.array([4, 6], np.uint32)}
    resolve = lambda t: vals[t]
    assert eval_host(parse("a&b"), resolve).tolist() == [3, 4]
    assert eval_host(parse("a|c"), resolve).tolist() == [1, 2, 3, 4, 6]
    assert eval_host(parse("a-b"), resolve).tolist() == [1, 2]
    assert eval_host(parse("(a|c)&b-c"), resolve).tolist() == [3]


# ---------------------------------------------------------------------------
# flat-conjunction regression: expressions that normalize flat plan
# byte-identically to term lists
# ---------------------------------------------------------------------------

def _small_index(seed=0, n_terms=6):
    rng = np.random.default_rng(seed)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 20, 60, replace=False).astype(np.uint32)
    idx = {}
    for t in range(n_terms):
        own = rng.choice(1 << 20, int(rng.integers(40, 600)),
                         replace=False).astype(np.uint32)
        idx[t] = preprocess_prefix(np.unique(np.concatenate([own, common])),
                                   w=256, m=2, family=fam, perm=perm)
    return idx


def test_flat_plan_identity():
    idx = _small_index()
    for q, s in [([1, 2], "1&2"), ([0, 1, 2], "2&(0&1)"),
                 ([3], "3|3"), ([4, 5], "4&5&4")]:
        p_list = plan_query(idx, q)
        p_expr = plan_query(idx, parse(s))
        assert p_expr == p_list
        assert p_expr.expr is None
        assert p_expr.sig is None or p_expr.sig.eshape is None
        assert p_expr.cache_key() == p_list.cache_key()
        # host routing too
        assert (plan_query(idx, parse(s), device=False)
                == plan_query(idx, q, device=False))


def test_expr_plan_shapes():
    idx = _small_index()
    p = plan_query(idx, parse("(0|1)&(2|3)-4"))
    assert p.algorithm == "device" and p.expr is not None
    assert p.sig.eshape == expr_shape(p.expr)
    assert p.sig.k == len(p.terms) == 5
    # ts/gmaxes are per-leaf in traversal order, not sorted
    assert p.terms == leaf_terms(p.expr)
    assert p.sig.ts == tuple(idx[t].t for t in p.terms)
    # algebraically equal expressions share plan and cache key
    q = plan_query(idx, parse("((3|2)&(1|0))-4"))
    assert q == p and q.cache_key() == p.cache_key()


def test_adaptive_key_includes_eshape():
    idx = _small_index()
    p_flat = plan_query(idx, [0, 1])
    p_expr = plan_query(idx, parse("0|1"))
    assert adaptive_key(p_flat.sig)[-1] is None
    assert adaptive_key(p_expr.sig)[-1] == p_expr.sig.eshape
    assert adaptive_key(p_flat.sig) != adaptive_key(p_expr.sig)


def test_routing_change_cannot_serve_stale_entry():
    """Satellite: device attach/detach between identical queries re-keys
    the cache entry (algorithm is part of the key), so expression-
    canonical keys can never alias a host result onto a device plan."""
    idx = _small_index()
    cache = ResultCache(8)
    e = parse("(0|1)&2")
    p_dev = plan_query(idx, e, device=True)
    p_host = plan_query(idx, e, device=False)
    assert p_dev.cache_key() != p_host.cache_key()
    cache.put(p_host, (np.arange(3, dtype=np.uint32), "expr/host"))
    assert cache.get(p_dev) is None          # miss, never a stale hit
    assert cache.get(p_host) is not None     # same routing still hits
    # flat plans carry the same guarantee
    f_dev = plan_query(idx, [0, 1], device=True)
    f_host = plan_query(idx, [0, 1], device=False)
    cache.put(f_dev, (np.arange(2, dtype=np.uint32), "rangroupscan/device"))
    assert cache.get(f_host) is None


# ---------------------------------------------------------------------------
# full-pipeline differential vs the numpy oracle
# ---------------------------------------------------------------------------

def _random_postings(rng, n_terms=8, max_len=400, universe=1 << 18):
    common = rng.choice(universe, 40, replace=False).astype(np.uint32)
    postings = {}
    for t in range(n_terms):
        n = int(rng.integers(5, max_len))
        own = rng.choice(universe, n, replace=False).astype(np.uint32)
        postings[t] = np.unique(np.concatenate([own, common]))
    return postings


def _check_expr_differential(seed, n_exprs=8, **engine_kw):
    rng = np.random.default_rng(seed)
    postings = _random_postings(rng)
    terms = list(postings)
    exprs = [_random_expr(rng, terms) for _ in range(n_exprs)]
    exprs.append(parse("(0|1)&(2|3)-4"))  # the acceptance-class shape
    truths = [eval_host(e, lambda t: postings[t]) for e in exprs]
    eng = SearchEngine(postings, seed=3, use_device=True, **engine_kw)
    # mixed batch: expressions and flat conjunctions share one pipeline
    flat = [[0, 1], [2, 3, 4]]
    results = eng.query_batch(list(exprs) + flat)
    for e, truth, r in zip(exprs, truths, results):
        assert np.array_equal(r.doc_ids, truth), (seed, e)
    for q, r in zip(flat, results[len(exprs):]):
        out = postings[q[0]]
        for t in q[1:]:
            out = np.intersect1d(out, postings[t])
        assert np.array_equal(r.doc_ids, out.astype(np.uint32)), (seed, q)
    # async front-end: submit -> background-flusher-less drain
    aeng = AsyncSearchEngine(postings, seed=3, flush_tier=8,
                             result_cache=0, **engine_kw)
    tickets = [aeng.submit(e) for e in exprs]
    aeng.drain()
    for e, truth, t in zip(exprs, truths, tickets):
        assert t.done and t.error is None, (seed, e)
        assert np.array_equal(t.value.doc_ids, truth), (seed, e)


@pytest.mark.parametrize("seed", [0, 1])
def test_expr_differential_seeded(seed):
    _check_expr_differential(seed)


@settings(max_examples=2, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_expr_differential_property(seed):
    _check_expr_differential(seed, n_exprs=4)


@multi_device
@pytest.mark.parametrize("seed", [0])
def test_expr_sharded_differential_seeded(seed):
    _check_expr_differential(seed, mesh=make_shard_mesh(N_DEVICES),
                             shard_min_g=4)


@multi_device
@settings(max_examples=1, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_expr_sharded_differential_property(seed):
    _check_expr_differential(seed, n_exprs=4,
                             mesh=make_shard_mesh(N_DEVICES), shard_min_g=4)


@multi_device
@pytest.mark.parametrize("seed", [0])
def test_expr_mesh2d_differential_seeded(seed):
    _check_expr_differential(seed, topology=make_topology(2, 2),
                             shard_min_g=4)


@multi_device
@settings(max_examples=1, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX))
def test_expr_mesh2d_differential_property(seed):
    _check_expr_differential(seed, n_exprs=4, topology=make_topology(2, 2),
                             shard_min_g=4)


# ---------------------------------------------------------------------------
# forced overflow at union/difference nodes: enlarged re-run stays exact
# ---------------------------------------------------------------------------

def _overlapping_leaf_rows(rng, n_leaves=3, n=400, overlap=250):
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 22, overlap, replace=False).astype(np.uint32)
    sets = []
    for _ in range(n_leaves):
        own = rng.choice(1 << 22, n, replace=False).astype(np.uint32)
        sets.append(np.unique(np.concatenate([own, common])))
    idxs = [preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
            for s in sets]
    return sets, [DeviceSet.from_host(i) for i in idxs]


def _check_expr_forced_overflow(seed, cap):
    rng = np.random.default_rng(seed)
    sets, row = _overlapping_leaf_rows(rng)
    # (a ∪ b) ∖ c — the union node alone carries >> cap values
    eshape = ("-", ("|", "T", "T"), "T")
    truth = np.setdiff1d(np.union1d(sets[0], sets[1]),
                         sets[2]).astype(np.uint32)
    assert len(np.union1d(sets[0], sets[1])) > cap
    EXEC_COUNTERS.reset()
    out = intersect_expr_batch([row, row], eshape, capacity=cap)
    for res, stats in out:
        assert np.array_equal(res, truth), (seed, cap)
        assert stats["r"] == len(truth)
    assert EXEC_COUNTERS["expr_rerun_calls"] >= 1


@pytest.mark.parametrize("seed,cap", [(0, 2), (1, 16)])
def test_expr_forced_overflow_seeded(seed, cap):
    _check_expr_forced_overflow(seed, cap)


@settings(max_examples=2, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX),
       cap=st.sampled_from([2, 16]))
def test_expr_forced_overflow_property(seed, cap):
    _check_expr_forced_overflow(seed, cap)


@multi_device
@pytest.mark.parametrize("seed", [0])
def test_expr_forced_overflow_sharded(seed):
    rng = np.random.default_rng(seed)
    mesh = make_shard_mesh(N_DEVICES)
    sets, row = _overlapping_leaf_rows(rng, n=1500, overlap=300)
    row = [ds.shard(mesh) for ds in row]
    eshape = ("-", ("|", "T", "T"), "T")
    truth = np.setdiff1d(np.union1d(sets[0], sets[1]),
                         sets[2]).astype(np.uint32)
    EXEC_COUNTERS.reset()
    out = intersect_expr_sharded_batch([row, row], eshape, mesh,
                                       capacity_per_shard=2)
    for res, stats in out:
        assert np.array_equal(res, truth)
        assert stats["r"] == len(truth)
    assert EXEC_COUNTERS["expr_rerun_calls"] >= 1


# ---------------------------------------------------------------------------
# subexpression cache: shared subtrees resolve without device work
# ---------------------------------------------------------------------------

def test_subexpr_cache_host_merge_and_counters():
    rng = np.random.default_rng(2)
    postings = _random_postings(rng)
    oracle = lambda s: eval_host(parse(s), lambda t: postings[t])
    eng = SearchEngine(postings, seed=3, use_device=True, result_cache=64)
    r0 = eng.query(parse("(0|1)&(2|3)-4"))
    assert np.array_equal(r0.doc_ids, oracle("(0|1)&(2|3)-4"))
    # intermediate DAG nodes were stored under their canonical keys
    assert EXEC_COUNTERS["subexpr_cache_stores"] >= len(
        subexpr_keys(eng.plan(parse("(0|1)&(2|3)-4")).expr))
    h0 = EXEC_COUNTERS["subexpr_cache_hits"]
    m0 = EXEC_COUNTERS["subexpr_host_merges"]
    r = eng.query(parse("(0|1)&5"))  # shares the 0|1 subtree
    assert np.array_equal(r.doc_ids, oracle("(0|1)&5"))
    assert r.algorithm == "expr/subcache"
    assert EXEC_COUNTERS["subexpr_cache_hits"] - h0 >= 1
    assert EXEC_COUNTERS["subexpr_host_merges"] - m0 == 1
    # merged roots are stored: the algebraic twin is now a root cache hit
    r2 = eng.query(parse("5&(1|0)"))
    assert r2.stats.get("cached") and np.array_equal(r2.doc_ids, r.doc_ids)
    # a finished FLAT conjunction seeds the sub-cache too
    eng.query([4, 5])
    m1 = EXEC_COUNTERS["subexpr_host_merges"]
    rx = eng.query(parse("(4&5)|6"))
    assert np.array_equal(rx.doc_ids, oracle("(4&5)|6"))
    assert EXEC_COUNTERS["subexpr_host_merges"] - m1 == 1


def test_subexpr_cache_through_async_flusher():
    rng = np.random.default_rng(3)
    postings = _random_postings(rng)
    oracle = lambda s: eval_host(parse(s), lambda t: postings[t])
    with AsyncSearchEngine(postings, seed=3, flush_tier=8,
                           result_cache=64) as aeng:
        t = aeng.submit(parse("(0|1)&(2|3)"))
        t.wait()
        assert np.array_equal(t.value.doc_ids, oracle("(0|1)&(2|3)"))
        h0 = EXEC_COUNTERS["subexpr_cache_hits"]
        t2 = aeng.submit(parse("(2|3)&7"))  # shares 2|3 -> submit-time merge
        assert t2.done
        assert np.array_equal(t2.value.doc_ids, oracle("(2|3)&7"))
        assert EXEC_COUNTERS["subexpr_cache_hits"] - h0 >= 1
    assert EXEC_COUNTERS["subexpr_host_merges"] >= 1


def test_subexpr_cache_respects_generation():
    rng = np.random.default_rng(4)
    postings = _random_postings(rng)
    eng = SearchEngine(postings, seed=3, use_device=True, result_cache=64)
    eng.query(parse("(0|1)&(2|3)"))
    # index mutation stales every sub entry: the shared-subtree probe must
    # MISS (and the merged answer reflect the new postings)
    eng.add_postings(1, np.arange(10, dtype=np.uint32))
    h0 = EXEC_COUNTERS["subexpr_cache_hits"]
    r = eng.query(parse("(0|1)&5"))
    assert EXEC_COUNTERS["subexpr_cache_hits"] == h0
    assert np.array_equal(
        r.doc_ids,
        eval_host(parse("(0|1)&5"),
                  lambda t: (np.arange(10, dtype=np.uint32) if t == 1
                             else postings[t])))
