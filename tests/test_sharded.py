"""Tests for the z-sharded batched execution subsystem.

Covers: sharded-vs-host oracle equivalence on a multi-device mesh, the
per-(query, shard) overflow flags + single enlarged re-run (the headline
bugfix — the old ``intersect_sharded`` silently truncated survivors past
``capacity_per_shard``), the shared ``(t, n)`` set-ordering key, planner
shard routing, engine/async end-to-end equivalence, and sharded compile
warming.

Mesh tests need >= 4 devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` exported before jax initializes — the CI multi-device job
does this).  On a single-device run those skip, but the subprocess oracle
test always runs: it re-executes the core equivalence + forced-overflow
property in a fresh interpreter with the flag set, so the acceptance
guarantee is exercised by every tier-1 run.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, clear_exec_jit_cache, default_capacity_per_shard,
    intersect_device_batch, intersect_sharded, intersect_sharded_batch,
    make_shard_mesh, set_sort_key,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.plan import plan_query
from repro.serve.search import AsyncSearchEngine, SearchEngine, zipf_query_log

N_SHARDS = 4
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_SHARDS,
    reason=f"needs >= {N_SHARDS} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def corpus():
    """Three overlapping sets big enough to split over 4 shards
    (t = 8/9/10 -> 256/512/1024 z-groups)."""
    rng = np.random.default_rng(0)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 24, 60, replace=False).astype(np.uint32)
    raw, idxs = {}, {}
    for name, n in [("a", 3000), ("b", 5000), ("c", 9000)]:
        s = np.unique(np.concatenate(
            [rng.choice(1 << 24, n, replace=False).astype(np.uint32), common]))
        raw[name] = s
        idxs[name] = preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
    return raw, idxs


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < N_SHARDS:
        pytest.skip(f"needs >= {N_SHARDS} devices")
    return make_shard_mesh(N_SHARDS)


@pytest.fixture(scope="module")
def sharded_sets(corpus, mesh):
    _, idxs = corpus
    return {k: DeviceSet.from_host(v).shard(mesh) for k, v in idxs.items()}


def truth_of(raw, names):
    out = raw[names[0]]
    for n in names[1:]:
        out = np.intersect1d(out, raw[n])
    return out


# ---------------------------------------------------------------------------
# Oracle equivalence
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_batch_matches_host_and_device_oracles(corpus, mesh, sharded_sets):
    raw, idxs = corpus
    for names in [["a", "b"], ["b", "c"], ["a", "c"], ["a", "b", "c"]]:
        truth = truth_of(raw, names)
        host, _ = rangroupscan([idxs[n] for n in names])
        row = [sharded_sets[n] for n in names]
        # batch of two (same signature, different arg order) + batch of one
        sharded = intersect_sharded_batch([row, row[::-1]], mesh,
                                          use_pallas=False)
        single, st = intersect_sharded(row, mesh, use_pallas=False)
        unsharded = intersect_device_batch(
            [[DeviceSet.from_host(idxs[n]) for n in names]], use_pallas=False)
        assert np.array_equal(host, truth)
        assert np.array_equal(single, truth)
        assert np.array_equal(unsharded[0][0], truth)
        for res, stats in sharded:
            assert np.array_equal(res, truth), names
            assert stats["r"] == len(truth)
            assert stats["n_shards"] == N_SHARDS
        assert st["tuples_survived"] == unsharded[0][1]["tuples_survived"]


@multi_device
def test_sharded_mixed_signature_rejected(mesh, sharded_sets):
    with pytest.raises(AssertionError):
        intersect_sharded_batch(
            [[sharded_sets["a"], sharded_sets["b"]],
             [sharded_sets["a"], sharded_sets["c"]]],
            mesh, use_pallas=False)


# ---------------------------------------------------------------------------
# Overflow: the headline bugfix — never silently truncate
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("cap", [1, 2, 7])
def test_sharded_forced_overflow_rerun_is_exact(corpus, mesh, sharded_sets, cap):
    """Per-shard survivors >> capacity_per_shard: the overflow flags must
    trigger ONE enlarged re-run and the results must still be bit-identical
    to the host oracle (the pre-fix code dropped survivors silently)."""
    raw, _ = corpus
    truth = truth_of(raw, ["a", "b"])
    row = [sharded_sets["a"], sharded_sets["b"]]
    EXEC_COUNTERS.reset()
    out = intersect_sharded_batch([row, row], mesh, capacity_per_shard=cap,
                                  use_pallas=False)
    for res, stats in out:
        assert np.array_equal(res, truth)
        assert stats["r"] == len(truth)
        # the re-run ran at the (larger) local group count, not at cap
        assert stats["capacity_per_shard"] > cap
    assert EXEC_COUNTERS["sharded_rerun_calls"] == 1
    assert EXEC_COUNTERS["sharded_calls"] == 2


@multi_device
def test_sharded_overflow_flags_are_per_query(corpus, mesh, sharded_sets):
    """Only overflowing queries re-run: a bucket mixing an overflowing and a
    non-overflowing query of the same signature re-runs a subset of one."""
    raw, idxs = corpus
    # same signature, different selectivity: [a, b] overflows at cap just
    # below its per-shard survivor count while a disjoint same-shape query
    # stays under it.  Build a disjoint twin of "a" (same t/gmax tiers).
    rng = np.random.default_rng(99)
    fam, perm = idxs["a"].family, idxs["a"].perm
    twin_vals = np.unique(
        rng.choice(1 << 24, len(raw["a"]), replace=False).astype(np.uint32))
    twin = preprocess_prefix(twin_vals, w=256, m=2, family=fam, perm=perm,
                             t=idxs["a"].t)
    dtwin = DeviceSet.from_host(twin).shard(mesh)
    if (dtwin.t, dtwin.gmax) != (sharded_sets["a"].t, sharded_sets["a"].gmax):
        pytest.skip("twin landed on a different shape tier")
    q_dense = [sharded_sets["a"], sharded_sets["b"]]
    q_sparse = [dtwin, sharded_sets["b"]]
    # pick a capacity strictly between the two queries' worst shards
    probe = intersect_sharded_batch([q_dense, q_sparse], mesh,
                                    use_pallas=False)
    dense_max = probe[0][1]["max_shard_survivors"]
    sparse_max = probe[1][1]["max_shard_survivors"]
    if not sparse_max < dense_max - 1:
        pytest.skip("twin selectivity too close to separate")
    cap = sparse_max + 1
    EXEC_COUNTERS.reset()
    out = intersect_sharded_batch([q_dense, q_sparse], mesh,
                                  capacity_per_shard=cap, use_pallas=False)
    assert np.array_equal(out[0][0], truth_of(raw, ["a", "b"]))
    assert np.array_equal(out[1][0],
                          np.intersect1d(twin_vals, raw["b"]).astype(np.uint32))
    assert EXEC_COUNTERS["sharded_rerun_calls"] == 1
    # sparse resolved on the first (2-query) pass; dense alone in the re-run
    assert out[1][1]["batch_size"] == 2
    assert out[0][1]["batch_size"] == 1


# ---------------------------------------------------------------------------
# Shared set ordering (bugfix: sharded path sorted by t only)
# ---------------------------------------------------------------------------

def test_set_sort_key_breaks_t_ties_by_n(corpus):
    _, idxs = corpus
    fam, perm = idxs["a"].family, idxs["a"].perm
    t = idxs["b"].t
    small = preprocess_prefix(np.arange(1, 400, dtype=np.uint32) * 13,
                              w=256, m=2, family=fam, perm=perm, t=t)
    big = preprocess_prefix(np.arange(1, 900, dtype=np.uint32) * 17,
                            w=256, m=2, family=fam, perm=perm, t=t)
    ds_small, ds_big = DeviceSet.from_host(small), DeviceSet.from_host(big)
    assert ds_small.t == ds_big.t and ds_small.n < ds_big.n
    # equal t: n must break the tie, in ANY input order — the old sharded
    # sort (t only, stable) kept equal-t sets in caller order
    for pair in ([ds_big, ds_small], [ds_small, ds_big]):
        assert [s.n for s in sorted(pair, key=set_sort_key)] \
            == [ds_small.n, ds_big.n]
    # and the planner agrees: smaller-n term first for equal-t sets
    plan = plan_query({"big": big, "small": small}, ["big", "small"])
    assert plan.terms == ("small", "big")


@multi_device
def test_sharded_order_invariant_and_stats_match(corpus, mesh, sharded_sets):
    """Same query, both arg orders: identical values AND identical stats —
    only true when the sharded path picks the same base set as the planner
    (the (t, n) key), not whatever equal-t order the caller passed."""
    raw, _ = corpus
    row = [sharded_sets["a"], sharded_sets["b"], sharded_sets["c"]]
    r1, s1 = intersect_sharded(row, mesh, use_pallas=False)
    r2, s2 = intersect_sharded(row[::-1], mesh, use_pallas=False)
    assert np.array_equal(r1, r2)
    assert s1 == s2
    assert np.array_equal(r1, truth_of(raw, ["a", "b", "c"]))


# ---------------------------------------------------------------------------
# Planner shard routing
# ---------------------------------------------------------------------------

def test_plan_shard_routing(corpus):
    _, idxs = corpus
    # big-G query + mesh + low threshold -> sharded
    sig = plan_query(idxs, ["a", "b"], mesh_shards=4, shard_min_g=64).sig
    assert sig.shards == 4
    # threshold above the largest set's G -> single-device
    sig = plan_query(idxs, ["a", "b"], mesh_shards=4,
                     shard_min_g=1 << 20).sig
    assert sig.shards == 1
    # no mesh (default) -> single-device
    assert plan_query(idxs, ["a", "b"]).sig.shards == 1
    # smallest set that can't split over the mesh -> single-device even
    # though the largest clears the threshold
    fam, perm = idxs["a"].family, idxs["a"].perm
    tiny = preprocess_prefix(np.arange(1, 9, dtype=np.uint32), w=256, m=2,
                             family=fam, perm=perm, t=1)
    mixed = dict(idxs, tiny=tiny)
    sig = plan_query(mixed, ["tiny", "c"], hashbin_ratio=float("inf"),
                     mesh_shards=4, shard_min_g=64).sig
    assert sig.shards == 1
    # sharded and unsharded signatures never share a bucket
    s4 = plan_query(idxs, ["a", "b"], mesh_shards=4, shard_min_g=64).sig
    s1 = plan_query(idxs, ["a", "b"]).sig
    assert s4 != s1


def test_default_capacity_per_shard_is_deterministic_and_bounded():
    ts = (8, 10)
    for n_shards in (1, 2, 4, 8):
        cap = default_capacity_per_shard(ts, n_shards)
        assert cap == default_capacity_per_shard(ts, n_shards)
        assert cap <= (1 << ts[-1]) // n_shards
        assert cap >= min(16, (1 << ts[-1]) // n_shards)


# ---------------------------------------------------------------------------
# Engine end-to-end over a mesh
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(3000, vocab=400, mean_len=40, seed=3)
    return inverted_index(docs)


@multi_device
def test_search_engine_sharded_matches_unsharded(postings, mesh):
    eng = SearchEngine(postings, seed=3, mesh=mesh, shard_min_g=4)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 48, seed=11)
    plans = [eng.plan(q) for q in log]
    assert any(p.algorithm == "device" and p.sig.shards == N_SHARDS
               for p in plans), "threshold routed nothing sharded"
    EXEC_COUNTERS.reset()
    got = eng.query_batch(log)
    sharded_calls = EXEC_COUNTERS["sharded_calls"]
    want = base.query_batch(log)
    for q, a, b in zip(log, got, want):
        assert np.array_equal(a.doc_ids, b.doc_ids), q
    sharded_sigs = {p.sig for p in plans
                    if p.algorithm == "device" and p.sig.shards > 1}
    assert sharded_calls <= len(sharded_sigs) + EXEC_COUNTERS["sharded_rerun_calls"]
    assert any(r.algorithm == "rangroupscan/sharded" for r in got)


@multi_device
def test_async_engine_sharded_matches_oracle(postings, mesh):
    eng = AsyncSearchEngine(postings, seed=3, mesh=mesh, shard_min_g=4,
                            flush_tier=4, result_cache=0)
    base = SearchEngine(postings, seed=3, use_device=True)
    log = zipf_query_log(sorted(eng.index), 24, seed=5)
    tickets = [eng.submit(q) for q in log]
    eng.drain()
    assert all(t.done for t in tickets)
    for q, t, o in zip(log, tickets, base.query_batch(log)):
        assert np.array_equal(t.value.doc_ids, o.doc_ids), q


@multi_device
def test_sharded_warming_zero_traces_at_serve_time(postings, mesh):
    eng = AsyncSearchEngine(postings, seed=3, mesh=mesh, shard_min_g=4,
                            flush_tier=2, result_cache=0)
    sample = zipf_query_log(sorted(eng.index), 48, seed=13)
    clear_exec_jit_cache()
    EXEC_COUNTERS.reset()
    warmed = eng.warm(sample, top_k=32, b_tiers=(1, 2))
    sharded_warmed = [s for s in warmed if s.shards == N_SHARDS]
    assert sharded_warmed, "warming saw no sharded signatures"
    assert EXEC_COUNTERS["sharded_traces"] >= len(sharded_warmed)
    q = next(q for q in sample if eng.plan(q).sig in sharded_warmed)
    EXEC_COUNTERS.reset()
    ticket = eng.submit(q)
    eng.drain()
    assert ticket.done
    assert EXEC_COUNTERS["sharded_calls"] >= 1
    assert EXEC_COUNTERS["sharded_traces"] == 0  # compiled at build time
    assert EXEC_COUNTERS["batch_traces"] == 0


# ---------------------------------------------------------------------------
# Subprocess guarantee: runs even when this process is single-device
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# CPU explicitly: with libtpu on the image, a second jax process would
# otherwise block minutes on the parent's /tmp/libtpu_lockfile
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
from repro.core.engine import (
    EXEC_COUNTERS, DeviceSet, intersect_sharded_batch, make_shard_mesh,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix

rng = np.random.default_rng(1)
fam = random_hash_family(2, 256, seed=7)
perm = default_permutation(7)
common = rng.choice(1 << 24, 40, replace=False).astype(np.uint32)
raw, idxs = {}, {}
for name, n in [("a", 2000), ("b", 3500)]:
    s = np.unique(np.concatenate(
        [rng.choice(1 << 24, n, replace=False).astype(np.uint32), common]))
    raw[name] = s
    idxs[name] = preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
mesh = make_shard_mesh(4)
row = [DeviceSet.from_host(idxs[n]).shard(mesh) for n in ("a", "b")]
truth = np.intersect1d(raw["a"], raw["b"])
host, _ = rangroupscan([idxs["a"], idxs["b"]])
assert np.array_equal(host, truth)
# oracle equivalence on a 4-shard mesh
(res, stats), = intersect_sharded_batch([row], mesh, use_pallas=False)
assert np.array_equal(res, truth), (len(res), len(truth))
assert stats["n_shards"] == 4 and stats["r"] == len(truth)
# forced overflow: tiny per-shard capacity still yields exact results
EXEC_COUNTERS.reset()
(res, stats), = intersect_sharded_batch([row], mesh, capacity_per_shard=2,
                                        use_pallas=False)
assert np.array_equal(res, truth), (len(res), len(truth))
assert EXEC_COUNTERS["sharded_rerun_calls"] == 1
assert EXEC_COUNTERS["sharded_calls"] == 2
print("SHARDED_SUBPROCESS_OK")
"""


def test_sharded_oracle_in_forced_multidevice_subprocess():
    """The acceptance guarantee, independent of this process's device count:
    a fresh interpreter with 8 forced host devices must reproduce the
    host oracle bit-identically on a 4-shard mesh, including under forced
    overflow (counter-verified single re-run)."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED_SUBPROCESS_OK" in proc.stdout
