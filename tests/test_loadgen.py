"""Tests for the open-loop load harness (``serve/loadgen.py``).

Covers: deterministic traffic synthesis (Lewis–Shedler arrivals, bursts,
pinned query pools), the burn accounting in ``_make_report`` (epsilon,
per-ticket budgets, windowed curve, queued-subset percentiles), the
virtual-time driver (exact deadline-flush waits, tier flushes at zero
wait, overload burning, run-to-run determinism, bit-identity to the host
oracle, engine-state restoration), the clock-attach guards, and the
wall-clock soak: 4 submitter threads against the real background flusher
with exactly-once resolution, no leaked threads, and balanced
dispatch/collect counters.
"""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.engine import EXEC_COUNTERS
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.admission import Ticket
from repro.serve.loadgen import (
    BURN_EPS_US, CostModel, QueryMix, TrafficShape, ArrivalSchedule,
    attach_virtual_clock, attach_wall_clock, build_schedule, run_virtual,
    run_wallclock, _make_report,
)
from repro.serve.search import AsyncSearchEngine, SearchEngine


@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(3000, vocab=400, mean_len=40, seed=3)
    return inverted_index(docs)


@pytest.fixture(scope="module")
def index_terms(postings):
    return sorted(t for t, p in postings.items() if len(p))


def _query_pool(index_terms, n=12, seed=5):
    rng = np.random.default_rng(seed)
    return QueryMix().sample(index_terms, n, rng)


# ---------------------------------------------------------------------------
# traffic synthesis
# ---------------------------------------------------------------------------

def test_schedule_deterministic_sorted_and_bounded(index_terms):
    shape = TrafficShape(base_qps=800.0, duration_s=1.0)
    a = build_schedule(shape, index_terms, seed=7)
    b = build_schedule(shape, index_terms, seed=7)
    assert np.array_equal(a.times, b.times)
    assert a.queries == b.queries
    assert np.all(np.diff(a.times) >= 0)
    assert len(a) and a.times[0] >= 0.0 and a.times[-1] < shape.duration_s
    c = build_schedule(shape, index_terms, seed=8)
    assert not np.array_equal(a.times, c.times)


def test_schedule_mean_rate_tracks_base_qps(index_terms):
    shape = TrafficShape(base_qps=3000.0, duration_s=2.0,
                         diurnal_amplitude=0.5, burst_rate_hz=0.0)
    sched = build_schedule(shape, index_terms, seed=1)
    # thinning recovers the mean of the sinusoid: base_qps (amplitude
    # integrates to zero); 6000 expected arrivals, Poisson noise ~1.3%
    assert sched.offered_qps == pytest.approx(3000.0, rel=0.10)


def test_bursts_add_clumps(index_terms):
    smooth = TrafficShape(base_qps=200.0, duration_s=2.0, burst_rate_hz=0.0)
    bursty = TrafficShape(base_qps=200.0, duration_s=2.0, burst_rate_hz=5.0,
                          burst_size=30.0)
    n_smooth = len(build_schedule(smooth, index_terms, seed=2))
    n_bursty = len(build_schedule(bursty, index_terms, seed=2))
    # ~10 burst events x ~30 queries on top of ~400 smooth arrivals
    assert n_bursty > n_smooth + 100


def test_rate_at_sinusoid_and_scaled():
    shape = TrafficShape(base_qps=100.0, diurnal_amplitude=0.5,
                         diurnal_period_s=4.0)
    assert shape.rate_at(0.0) == pytest.approx(100.0)
    assert shape.rate_at(1.0) == pytest.approx(150.0)   # sin peak
    assert shape.rate_at(3.0) == pytest.approx(50.0)    # sin trough
    deep = TrafficShape(base_qps=100.0, diurnal_amplitude=2.0,
                        diurnal_period_s=4.0)
    assert deep.rate_at(3.0) == 0.0                     # clipped, not negative
    doubled = shape.scaled(2.0)
    assert doubled.base_qps == 200.0 and doubled.burst_rate_hz == 2.0
    assert doubled.diurnal_amplitude == shape.diurnal_amplitude


def test_query_mix_distinct_pool_and_k_mix(index_terms):
    rng = np.random.default_rng(0)
    mix = QueryMix(distinct_pool=8)
    qs = mix.sample(index_terms, 200, rng)
    assert len({tuple(q) for q in qs}) <= 8
    ks = {len(q) for q in qs}
    assert ks <= {1, 2, 3, 4}          # dedup can shrink below the drawn k
    term_set = set(index_terms)
    assert all(set(q) <= term_set for q in qs)


def test_pinned_pool_draws_only_from_pool(index_terms):
    pool = _query_pool(index_terms, n=6)
    shape = TrafficShape(base_qps=500.0, duration_s=1.0)
    sched = build_schedule(shape, index_terms, seed=3, pool=pool)
    allowed = {tuple(q) for q in pool}
    assert {q for q in sched.queries} <= allowed


def test_cost_model_math():
    cost = CostModel(per_bucket_us=200.0, per_query_us=50.0)
    assert cost.flush_cost_us(1, 1) == 250.0
    assert cost.flush_cost_us(2, 10) == 900.0
    # tier-8 flush costs 600 us for 8 queries -> 8/600us sustained
    assert cost.capacity_qps(8) == pytest.approx(8 / 600e-6)


# ---------------------------------------------------------------------------
# burn accounting (_make_report on synthetic tickets)
# ---------------------------------------------------------------------------

def _fake_ticket(wait_us, deadline_us=1000.0, cached=False, error=None):
    t = Ticket(submitted_at=0.0, deadline_us=deadline_us)
    if error is not None:
        t.resolve_error(error, wait_us=wait_us)
        return t
    stats = {"cached": True} if cached else {"batch_size": 4}
    t.resolve(SimpleNamespace(latency_us=5.0, stats=stats), wait_us=wait_us)
    return t


def test_report_burn_epsilon_and_budgets():
    entries = [
        (0.05, _fake_ticket(999.0)),                   # within budget
        (0.15, _fake_ticket(1000.0 + BURN_EPS_US)),    # at the epsilon edge
        (0.25, _fake_ticket(1001.0)),                  # burned
        (0.35, _fake_ticket(400.0, deadline_us=0.0, cached=True)),  # default
        (0.45, _fake_ticket(1500.0, deadline_us=0.0, cached=True)),  # burned
    ]
    rep = _make_report("virtual", entries, deadline_us=1000.0,
                       duration_s=0.5, windows=5)
    assert rep.arrivals == rep.completed == 5 and rep.errors == 0
    # only the strict epsilon-exceeding waits burn; zero-deadline tickets
    # (resolved-at-submit paths) are judged against the run default
    assert rep.burned == 2 and rep.burn_rate == pytest.approx(0.4)
    assert [w["burned"] for w in rep.burn_curve] == [0, 0, 1, 0, 1]
    assert sum(w["completed"] for w in rep.burn_curve) == 5
    # queued percentiles exclude the cached (resolved-at-submit) tickets
    assert rep.queued_queries == 3


def test_report_errors_and_tail_window():
    boom = RuntimeError("bucket failed")
    entries = [
        (0.1, _fake_ticket(100.0)),
        (0.2, _fake_ticket(100.0, error=boom)),
        (0.99, _fake_ticket(2000.0)),   # lands in (and burns) the last window
    ]
    rep = _make_report("virtual", entries, deadline_us=1000.0,
                       duration_s=0.5, windows=2)   # arrivals past duration
    assert rep.completed == 2 and rep.errors == 1
    assert rep.burn_curve[-1]["burned"] == 1        # clamped into tail window
    assert rep.burned == 1


# ---------------------------------------------------------------------------
# virtual-time driver
# ---------------------------------------------------------------------------

def _fresh_engine(postings, pool, flush_tier=4, deadline_us=2000.0):
    return AsyncSearchEngine(postings, seed=3, flush_tier=flush_tier,
                             deadline_us=deadline_us, result_cache=0,
                             warm_queries=pool)


def _device_query(eng, pool):
    """First pool query the engine routes to the device path (host-routed
    queries resolve at submit and never exercise the flush policy)."""
    return next(tuple(q) for q in pool
                if eng.plan(list(q)).algorithm == "device")


def test_virtual_single_arrival_waits_exactly_deadline(postings, index_terms):
    pool = _query_pool(index_terms, n=4)
    eng = _fresh_engine(postings, pool, deadline_us=2000.0)
    sched = ArrivalSchedule(times=np.asarray([0.1]),
                            queries=(_device_query(eng, pool),),
                            duration_s=0.2)
    rep, entries = run_virtual(eng, sched, CostModel(200.0, 50.0))
    [(t_arr, ticket)] = entries
    # an idle server deadline-flushes at exactly submitted_at + budget:
    # the wait IS the budget, and the epsilon keeps it from burning
    assert ticket.wait_us == pytest.approx(2000.0, abs=BURN_EPS_US)
    assert rep.burned == 0 and rep.completed == 1
    assert EXEC_COUNTERS["deadline_flushes"] == 1
    assert EXEC_COUNTERS["deadline_violations"] == 0


def test_virtual_full_tier_flushes_at_zero_wait(postings, index_terms):
    pool = _query_pool(index_terms, n=4)
    eng = _fresh_engine(postings, pool, flush_tier=4)
    q = _device_query(eng, pool)
    sched = ArrivalSchedule(times=np.zeros(4), queries=(q, q, q, q),
                            duration_s=0.1)
    rep, entries = run_virtual(eng, sched, CostModel(200.0, 50.0))
    assert EXEC_COUNTERS["tier_flushes"] == 1
    assert EXEC_COUNTERS["deadline_flushes"] == 0
    assert all(t.wait_us == pytest.approx(0.0, abs=BURN_EPS_US)
               for _, t in entries)
    assert rep.burned == 0


def test_virtual_deterministic_and_identical_to_oracle(postings, index_terms):
    pool = _query_pool(index_terms, n=8)
    shape = TrafficShape(base_qps=300.0, duration_s=0.5, burst_rate_hz=2.0,
                         burst_size=6.0)
    sched = build_schedule(shape, index_terms, seed=11, pool=pool)
    assert len(sched) > 50
    cost = CostModel(per_bucket_us=500.0, per_query_us=100.0)

    runs = []
    for _ in range(2):
        eng = _fresh_engine(postings, pool)
        rep, entries = run_virtual(eng, sched, cost)
        runs.append((rep, entries))
        # engine state restored: manual mode back on, nothing pending
        assert eng.inline_tier_flush and eng.pending() == 0
        assert rep.counters["inflight_dispatches"] == \
            rep.counters["inflight_collects"]
        assert rep.counters["tickets_resolved"] == rep.completed

    (rep_a, ent_a), (rep_b, ent_b) = runs
    # byte-equal waits run to run: the DES is deterministic
    assert [t.wait_us for _, t in ent_a] == [t.wait_us for _, t in ent_b]
    assert rep_a.burn_rate == rep_b.burn_rate
    assert rep_a.counters == rep_b.counters

    oracle = SearchEngine(postings, seed=3, use_device=True)
    memo = {tuple(q): oracle.query(list(q)).doc_ids for q in pool}
    for (t_arr, ticket), q in zip(ent_a, sched.queries):
        assert ticket.error is None
        assert np.array_equal(ticket.value.doc_ids, memo[q]), q


def test_virtual_overload_burns_low_load_does_not(postings, index_terms):
    pool = _query_pool(index_terms, n=8)
    # synthetic slow server: ~360 qps singleton capacity
    cost = CostModel(per_bucket_us=2000.0, per_query_us=750.0)
    shape = TrafficShape(base_qps=30.0, duration_s=0.5, burst_rate_hz=0.0)
    low = build_schedule(shape, index_terms, seed=4, pool=pool)
    high = build_schedule(shape.scaled(25.0), index_terms, seed=4, pool=pool)
    rep_low, _ = run_virtual(_fresh_engine(postings, pool), low, cost)
    rep_high, _ = run_virtual(_fresh_engine(postings, pool), high, cost)
    assert rep_low.burn_rate < 0.2
    assert rep_high.burn_rate > max(0.3, 2 * rep_low.burn_rate)
    # overload stretches the tail past the budget
    assert rep_high.p99_wait_us > rep_high.deadline_us


def test_attach_clock_guards(postings, index_terms):
    pool = _query_pool(index_terms, n=4)
    eng = _fresh_engine(postings, pool)
    eng.start()
    try:
        with pytest.raises(AssertionError, match="stop the background"):
            attach_virtual_clock(eng)
    finally:
        eng.stop()
    clk = attach_virtual_clock(eng)
    eng.inline_tier_flush = False
    try:
        eng.submit(list(_device_query(eng, pool)))
        assert eng.pending() == 1
        with pytest.raises(AssertionError, match="work in flight"):
            attach_wall_clock(eng)
        clk.t += 1.0
        eng.pump()
    finally:
        eng.inline_tier_flush = True
    attach_wall_clock(eng)
    assert eng.clock is time.perf_counter


# ---------------------------------------------------------------------------
# wall-clock soak: 4 submitters + the real background flusher
# ---------------------------------------------------------------------------

def test_wallclock_soak_exactly_once_no_leaks(postings, index_terms):
    """Satellite stress test: four submitter threads replay an open-loop
    schedule against the running background flusher.  Every ticket must
    resolve exactly once (single-shot resolution + counter identity), the
    dispatch/collect pipeline must balance, every result must match the
    host oracle bit-exactly, and every thread the run started must be
    gone afterwards."""
    pool = _query_pool(index_terms, n=8)
    eng = _fresh_engine(postings, pool, flush_tier=4, deadline_us=500.0)
    shape = TrafficShape(base_qps=150.0, duration_s=0.8, burst_rate_hz=2.0,
                         burst_size=8.0)
    sched = build_schedule(shape, index_terms, seed=6, pool=pool)
    assert len(sched) > 60
    before = set(threading.enumerate())
    rep, entries = run_wallclock(eng, sched, submitters=4, windows=4)
    assert eng._flusher_error is None
    assert rep.thread_leak == 0
    assert set(threading.enumerate()) <= before
    assert rep.arrivals == len(sched)
    assert rep.completed == len(sched) and rep.errors == 0
    # exactly-once: every resolution bumped the counter exactly once, and
    # every dispatched bucket was collected exactly once
    assert rep.counters["tickets_resolved"] == rep.completed
    assert rep.counters["inflight_dispatches"] == \
        rep.counters["inflight_collects"]
    assert rep.counters["tier_flushes"] + rep.counters["deadline_flushes"] \
        == rep.counters["inflight_dispatches"]
    oracle = SearchEngine(postings, seed=3, use_device=True)
    memo = {tuple(q): oracle.query(list(q)).doc_ids for q in pool}
    for (t_arr, ticket), q in zip(entries, sched.queries):
        assert np.array_equal(ticket.value.doc_ids, memo[q]), q
    # double-resolution must raise, not clobber (the exactly-once backstop)
    with pytest.raises(RuntimeError, match="single-shot"):
        entries[0][1].resolve(None)
