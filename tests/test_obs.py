"""Observability layer tests: typed registry, tracer, exporters, profile
store, and their wiring through the serving stack.

Satellite coverage (ISSUE 10):

1. ``EXEC_COUNTERS`` snapshot tearing — threads hammering ``bump_many``
   while a reader snapshots must never observe a torn multi-key update.
2. Balancer failure telemetry — a mid-collect flight failure returns the
   row's in-flight weight, records a per-row failure, and bumps the
   ``dispatch_failures`` counter (typed and legacy) exactly once.
3. Span lifecycle invariants — exactly one closed ``request`` root span
   per ticket (cache-hit, device, and error paths), genuinely overlapping
   bucket spans under the overlapped window, zero spans in disabled mode.
"""
import threading
import time

import numpy as np
import pytest
import jax

from repro.core.engine import EXEC_COUNTERS, PendingBatch
from repro.exec.plan import ShapeSig
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.adaptive import AdaptiveDeadline, CapacityModel, adaptive_key
from repro.exec.batch import bucket_plans, dispatch_bucket
from repro.exec.topology import ReplicaBalancer, make_topology
from repro.obs import (Obs, get_obs, parse_json, parse_prometheus,
                      set_obs, sig_label, to_json, to_prometheus)
from repro.obs.export import SnapshotRing
from repro.obs.profile import ProfileStore
from repro.obs.registry import (MetricsRegistry, default_latency_buckets,
                                pow2_buckets)
from repro.obs.trace import NULL_SPAN, Tracer, format_trace
from repro.serve.loadgen import CostModel, calibrate_from_profile
from repro.serve.search import AsyncSearchEngine, SearchEngine, zipf_query_log

N_DEVICES = 2
multi_device = pytest.mark.skipif(
    len(jax.devices()) < N_DEVICES,
    reason=f"needs >= {N_DEVICES} devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(3000, vocab=400, mean_len=40, seed=3)
    return inverted_index(docs)


def _sig(cap=256, shards=1, replicas=1):
    return ShapeSig(k=2, ts=(4, 5), gmaxes=(16, 32), capacity_tier=cap,
                    shards=shards, replicas=replicas)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_types_and_snapshot():
    r = MetricsRegistry()
    c = r.counter("reqs", "requests")
    g = r.gauge("depth", "queue depth")
    hw = r.gauge("high", "high water", track_max=True)
    h = r.histogram("lat_us", "latency", buckets=[1.0, 10.0, 100.0])
    c.inc()
    c.inc(2)
    g.set(5)
    g.dec(2)
    hw.set(4)
    hw.set(2)  # track_max keeps 4
    for v in (0.5, 3.0, 50.0, 1e6):
        h.observe(v)
    snap = r.snapshot()
    assert snap["counters"]["reqs"] == 3
    assert snap["gauges"]["depth"] == 3
    assert snap["gauges"]["high"] == 4
    hs = snap["histograms"]["lat_us"]
    assert hs["count"] == 4 and sum(hs["counts"]) == 4
    assert hs["counts"] == [1, 1, 1, 1]  # one per bucket + one +Inf
    assert hs["sum"] == pytest.approx(0.5 + 3.0 + 50.0 + 1e6)
    assert h.quantile(0.5) <= h.quantile(1.0)
    r.reset()
    assert r.snapshot()["counters"]["reqs"] == 0


def test_registry_get_or_create_and_kind_clash():
    r = MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")


def test_counter_is_monotonic():
    r = MetricsRegistry()
    with pytest.raises(ValueError):
        r.counter("c").inc(-1)


def test_bucket_lattices():
    lat = default_latency_buckets(1.0, 100.0)
    assert lat == [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0]
    assert pow2_buckets(1, 8) == [1.0, 2.0, 4.0, 8.0]


def test_collector_appears_in_snapshot():
    r = MetricsRegistry()
    r.register_collector(lambda: {"ext_thing": 7.0})
    assert r.snapshot()["collected"]["ext_thing"] == 7.0


# ---------------------------------------------------------------------------
# satellite 1: EXEC_COUNTERS snapshot tearing
# ---------------------------------------------------------------------------

def test_exec_counters_snapshot_never_tears():
    """Writers bump two keys atomically via ``bump_many``; every reader
    snapshot must observe the pair in lockstep (the pre-fix failure mode:
    ``dict(EXEC_COUNTERS)`` copied mid-update)."""
    stop = threading.Event()
    N = 4000

    def writer():
        for _ in range(N):
            EXEC_COUNTERS.bump_many(
                {"tickets_resolved": 1, "queue_wait_us": 7})

    torn = []

    def reader():
        while not stop.is_set():
            s = EXEC_COUNTERS.snapshot()
            if s["queue_wait_us"] != 7 * s["tickets_resolved"]:
                torn.append(s)
                return

    writers = [threading.Thread(target=writer) for _ in range(3)]
    r = threading.Thread(target=reader)
    r.start()
    for w in writers:
        w.start()
    for w in writers:
        w.join()
    stop.set()
    r.join()
    assert not torn, torn[:1]
    assert EXEC_COUNTERS["tickets_resolved"] == 3 * N
    assert EXEC_COUNTERS["queue_wait_us"] == 21 * N


def test_exec_counters_snapshot_during_dispatch(postings):
    """Snapshots (typed registry + legacy) stay consistent and exportable
    while the engine dispatches device buckets from another thread."""
    obs = Obs()
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            result_cache=0, max_inflight=8, obs=obs)
    log = zipf_query_log(sorted(eng.index), 16, seed=11)
    done = threading.Event()

    def serve():
        for q in log:
            eng.submit(q)
        eng.drain()
        done.set()

    t = threading.Thread(target=serve)
    t.start()
    while not done.is_set():
        snap = obs.registry.snapshot()
        parse_prometheus(to_prometheus(snap))  # raises on malformed output
        s = EXEC_COUNTERS.snapshot()
        assert set(s) == set(EXEC_COUNTERS._KEYS)
    t.join()
    assert EXEC_COUNTERS["tickets_resolved"] == len(log)


# ---------------------------------------------------------------------------
# satellite 2: balancer failure telemetry
# ---------------------------------------------------------------------------

def test_balancer_queued_weight_histogram_and_failures():
    bal = ReplicaBalancer(2)
    r0 = bal.acquire(weight=1.0)
    r1 = bal.acquire(weight=1024.0)
    assert {r0, r1} == {0, 1}  # least-loaded spreads the two buckets
    bal.release(r0, weight=1.0)
    bal.release(r1, weight=1024.0, failed=True)
    loads = bal.loads()
    assert all(d["in_flight"] == 0 for d in loads)
    assert sum(d["failures"] for d in loads) == 1
    for d in loads:
        qw = d["queued_weight"]
        assert len(qw["counts"]) == len(qw["buckets"]) + 1
        assert qw["counts"] == sorted(qw["counts"])  # cumulative
        assert qw["counts"][-1] == d["dispatched"]
    bal.reset()
    loads = bal.loads()
    assert all(d["failures"] == 0 and d["queued_weight"]["counts"][-1] == 0
               for d in loads)


@multi_device
def test_mid_collect_failure_resets_balancer_and_counts_once(postings):
    """A flight whose *collect* raises must return its row's in-flight
    weight, mark one per-row failure, and count exactly one
    ``dispatch_failures`` in both the legacy and typed surfaces."""
    obs = Obs()
    topo = make_topology(2, 1)
    eng = SearchEngine(postings, seed=3, topology=topo, shard_min_g=1 << 20)
    log = zipf_query_log(sorted(eng.index), 8, seed=11)
    plans = [(i, eng.plan(q)) for i, q in enumerate(log)]
    buckets = bucket_plans([(i, p) for i, p in plans
                            if p.algorithm == "device"])
    sig = next(iter(buckets))
    bucket = dispatch_bucket(
        lambda term: eng.device.sets[str(term)], sig, buckets[sig],
        use_pallas=eng.device.use_pallas, mesh=eng.device.mesh,
        shard_axis=eng.device.shard_axis,
        get_sharded_set=lambda term: eng.device.get_mesh_set(str(term)),
        topology=topo,
        get_replica_set=lambda r, term: eng.device.get_replica_set(
            r, str(term)),
        obs=obs)
    assert any(d["in_flight"] > 0 for d in topo.load_snapshot())
    assert obs.inflight.value == 1

    def boom():
        raise RuntimeError("device fell over mid-collect")

    bucket.pending = PendingBatch(n_queries=len(buckets[sig]),
                                  _collect=boom)
    with pytest.raises(RuntimeError, match="mid-collect"):
        bucket.collect()
    loads = topo.load_snapshot()
    assert all(d["in_flight"] == 0 for d in loads), loads
    assert sum(d["failures"] for d in loads) == 1
    assert EXEC_COUNTERS["dispatch_failures"] == 1
    assert obs.dispatch_failures.value == 1
    assert obs.inflight.value == 0
    # _finish is one-shot: a second collect attempt cannot double-count
    with pytest.raises(RuntimeError):
        bucket.collect()
    assert sum(d["failures"] for d in topo.load_snapshot()) == 1
    assert EXEC_COUNTERS["dispatch_failures"] == 1


# ---------------------------------------------------------------------------
# tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_disabled_returns_shared_sentinel():
    t = Tracer(enabled=False)
    s = t.start("request")
    assert s is NULL_SPAN and s is s.child("plan")
    s.set(x=1)
    s.end()
    assert s.attrs == {} and not s.enabled
    assert t.open_count() == 0 and t.finished() == []


def test_tracer_span_tree_and_ring():
    t = Tracer(enabled=True, max_finished=4)
    root = t.start("request", route="device")
    with root.child("plan"):
        pass
    t.span_at("device", 10.0, 20.0, parent=root)
    root.end()
    root.end()  # idempotent
    assert t.open_count() == 0
    names = [s.name for s in t.finished()]
    assert sorted(names) == ["device", "plan", "request"]
    text = format_trace(t.finished())
    assert "request" in text and "plan" in text
    for i in range(10):
        t.span_at(f"s{i}", 0.0, 1.0)
    assert len(t.finished()) == 4 and t.dropped > 0


def test_tracer_backdated_start():
    fake = [100.0]
    t = Tracer(enabled=True, clock=lambda: fake[0])
    s = t.start("bucket", start_us=50.0 * 1e6)
    fake[0] = 101.0
    s.end()
    assert s.start_us == pytest.approx(50e6)
    assert s.duration_us == pytest.approx(51e6)


def test_context_manager_records_error():
    t = Tracer(enabled=True)
    with pytest.raises(ValueError):
        with t.start("request") as s:
            raise ValueError("nope")
    assert "error" in s.attrs and t.open_count() == 0


# ---------------------------------------------------------------------------
# satellite 3: span lifecycle invariants through the serving stack
# ---------------------------------------------------------------------------

def test_exactly_one_root_span_per_ticket_all_routes(postings):
    """Every submit — device-executed, cache-hit, or error-resolved —
    closes exactly one ``request`` root span."""
    obs = Obs(trace=True)
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            max_inflight=8, obs=obs)
    log = zipf_query_log(sorted(eng.index), 12, seed=11)
    tickets = [eng.submit(q) for q in log]
    eng.drain()
    repeats = [eng.submit(q) for q in log[:4]]  # result-cache hits
    eng.drain()
    assert all(t.done for t in tickets + repeats)
    roots = obs.tracer.finished("request")
    assert len(roots) == len(log) + 4
    assert obs.tracer.open_count() == 0
    routes = {s.attrs.get("route") for s in roots}
    assert "cache" in routes and "device" in routes
    device_roots = [s for s in roots if s.attrs.get("route") == "device"]
    assert all("bucket_span" in s.attrs for s in device_roots)
    assert all(s.attrs.get("error") is None for s in roots)
    # typed queue-wait histogram saw every resolution
    assert obs.queue_wait.count == len(roots)


def test_error_path_closes_root_span(postings, monkeypatch):
    obs = Obs(trace=True)
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            result_cache=0, max_inflight=8, obs=obs)
    log = zipf_query_log(sorted(eng.index), 6, seed=11)
    monkeypatch.setattr(
        "repro.serve.search.dispatch_bucket",
        lambda *a, **kw: (_ for _ in ()).throw(RuntimeError("boom")))
    tickets = [eng.submit(q) for q in log]
    eng.drain()
    assert all(t.done for t in tickets)
    for t in tickets:
        with pytest.raises(RuntimeError, match="boom"):
            _ = t.value
    roots = obs.tracer.finished("request")
    assert len(roots) == len(log)
    assert obs.tracer.open_count() == 0
    assert all(s.attrs.get("error") == "RuntimeError" for s in roots)


def test_bucket_spans_overlap_in_window(postings):
    """With the overlapped window the drain dispatches buckets
    back-to-back before collecting: their spans must genuinely overlap,
    and each carries dispatch/device/collect children plus the member
    request trace ids."""
    obs = Obs(trace=True)
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64,
                            result_cache=0, max_inflight=8, obs=obs)
    log = zipf_query_log(sorted(eng.index), 24, seed=11)
    for q in log:
        eng.submit(q)
    n_buckets = eng.drain()
    assert n_buckets >= 2
    bspans = sorted(obs.tracer.finished("bucket"),
                    key=lambda s: s.start_us)
    assert len(bspans) == n_buckets
    assert any(b.start_us < a.end_us
               for a, b in zip(bspans, bspans[1:])), (
        "no overlapping bucket spans in an overlapped drain")
    for s in bspans:
        assert s.attrs["traces"], "bucket span lost its member traces"
        assert s.attrs["batch"] >= 1
    for name in ("dispatch", "device", "collect"):
        stage = obs.tracer.finished(name)
        assert len(stage) == n_buckets
        by_parent = {s.parent_id for s in stage}
        assert by_parent == {s.span_id for s in bspans}
    assert obs.tracer.open_count() == 0
    # profile store attributed every executed signature
    assert len(obs.profile.signatures()) >= 1
    assert obs.collect_latency.count == n_buckets
    assert obs.batch_size.count == n_buckets


def test_disabled_mode_adds_zero_spans_and_low_overhead(postings):
    """Metrics-only mode (the default) must record no spans at all; the
    submit path with tracing enabled stays within a loose factor of
    disabled mode on pure cache-hit traffic (the strict <=5% QPS gate
    runs on warmed device traffic in ``benchmarks/fig_observability.py``
    — this is the catastrophic-regression guard)."""
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=64, max_inflight=8)
    assert not eng.obs.tracer.enabled  # global default: metrics only
    log = zipf_query_log(sorted(eng.index), 8, seed=11)
    for q in log:
        eng.submit(q)
    eng.drain()
    assert eng.obs.tracer.finished() == []
    assert eng.obs.tracer.open_count() == 0
    assert eng.obs.queue_wait.count == len(log)  # metrics still flow

    def wall(obs_mode):
        eng.obs = obs_mode
        t0 = time.perf_counter()
        for q in log:
            eng.submit(q)  # all cache hits: no device work
        eng.drain()
        return time.perf_counter() - t0

    disabled, enabled = Obs(), Obs(trace=True)
    base = [wall(disabled) for _ in range(5)]
    traced = [wall(enabled) for _ in range(5)]
    assert float(np.median(traced)) < 3.0 * max(1e-9,
                                                float(np.median(base)))
    eng.obs = disabled


def test_flusher_fills_snapshot_ring(postings):
    obs = Obs()
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=4,
                            deadline_us=500.0, max_inflight=8,
                            snapshot_every_s=0.01, obs=obs)
    log = zipf_query_log(sorted(eng.index), 6, seed=11)
    def resolved_in_latest():
        latest = obs.ring.latest()
        if latest is None:
            return 0
        return latest[1]["collected"]["exec_tickets_resolved"]

    with eng:
        tickets = [eng.submit(q) for q in log]
        for t in tickets:
            assert t.wait(timeout=60.0)
        # the flusher pushes a cut at most every snapshot_every_s — wait
        # for one taken AFTER the resolutions landed
        deadline = time.time() + 10.0
        while resolved_in_latest() < len(log) and time.time() < deadline:
            time.sleep(0.01)
    assert len(obs.ring) >= 1
    assert resolved_in_latest() >= len(log)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_prometheus_round_trip():
    obs = Obs()
    obs.queue_wait.observe(42.0)
    obs.queue_wait.observe(4200.0)
    obs.dispatch_failures.inc(3)
    obs.inflight.set(2)
    EXEC_COUNTERS.bump("batch_calls", 5)
    text = to_prometheus(obs.snapshot())
    parsed = parse_prometheus(text)
    h = parsed["repro_queue_wait_us"]
    assert h["type"] == "histogram" and h["count"] == 2
    assert h["sum"] == pytest.approx(4242.0)
    assert h["buckets"][-1][0] == float("inf")
    assert h["buckets"][-1][1] == 2  # +Inf cumulative == count
    assert parsed["repro_dispatch_failures"]["value"] == 3
    assert parsed["repro_inflight_buckets"]["value"] == 2
    assert parsed["repro_exec_batch_calls"]["value"] == 5


def test_prometheus_parser_rejects_garbage():
    with pytest.raises(ValueError, match="unparseable"):
        parse_prometheus("this is { not an exposition\n")
    bad = ('# TYPE h histogram\nh_bucket{le="1"} 5\n'
           'h_bucket{le="2"} 3\nh_sum 1\nh_count 5\n')
    with pytest.raises(ValueError, match="not cumulative"):
        parse_prometheus(bad)


def test_json_round_trip_and_validation():
    obs = Obs()
    obs.batch_size.observe(8)
    snap = parse_json(to_json(obs.snapshot()))
    assert snap["histograms"]["bucket_batch_size"]["count"] == 1
    with pytest.raises(ValueError, match="missing section"):
        parse_json("{}")
    broken = obs.snapshot()
    broken["histograms"]["bucket_batch_size"]["count"] = 99
    with pytest.raises(ValueError, match="count"):
        parse_json(to_json(broken))


def test_snapshot_ring_bounded():
    ring = SnapshotRing(maxlen=3)
    for i in range(5):
        ring.push(float(i), {"i": i})
    assert len(ring) == 3
    assert ring.latest() == (4.0, {"i": 4})
    assert [t for t, _ in ring.entries()] == [2.0, 3.0, 4.0]
    ring.clear()
    assert ring.latest() is None


# ---------------------------------------------------------------------------
# profile store + calibration loop
# ---------------------------------------------------------------------------

def test_profile_residual_attribution():
    model = CostModel(per_bucket_us=100.0, per_query_us=5.0)
    store = ProfileStore(cost_model=model)
    sig = _sig()
    store.observe(sig, 4, 100.0 + 5.0 * 4)   # exactly on-model
    store.observe(sig, 8, 100.0 + 5.0 * 8 + 30.0)  # +30us residual
    res = store.residuals()[sig_label(sig)]
    assert res["buckets"] == 2 and res["queries"] == 12
    assert res["residual_us"] == pytest.approx(30.0)
    assert res["mean_residual_us"] == pytest.approx(15.0)


def test_profile_fit_closes_calibration_loop():
    store = ProfileStore()
    for b in (1, 2, 4, 8, 16):
        store.observe(_sig(), b, 200.0 + 7.0 * b)
        store.observe(_sig(cap=512), b, 200.0 + 7.0 * b)
    fit = calibrate_from_profile(store)
    assert fit is not None
    assert fit.per_bucket_us == pytest.approx(200.0, rel=1e-6)
    assert fit.per_query_us == pytest.approx(7.0, rel=1e-6)
    assert fit.capacity_qps(64) > 0


def test_profile_fit_needs_two_operating_points():
    store = ProfileStore()
    for _ in range(10):
        store.observe(_sig(), 4, 120.0)
    assert store.fit_cost() is None
    assert calibrate_from_profile(store) is None


def test_profile_window_is_bounded():
    store = ProfileStore(max_samples=8)
    for i in range(50):
        store.observe(_sig(), 1 + i % 3, 10.0)
    res = store.residuals()[sig_label(_sig())]
    assert res["buckets"] == 50  # totals keep counting
    assert len(store._sigs[_sig()].samples) == 8  # window slides


def test_sig_label_variants():
    assert sig_label(_sig()) == "k2/t4x5/cap256"
    assert sig_label(_sig(shards=4)) == "k2/t4x5/cap256/s4"
    assert sig_label(_sig(replicas=2)) == "k2/t4x5/cap256/r2"


# ---------------------------------------------------------------------------
# adaptive controllers: telemetry snapshots
# ---------------------------------------------------------------------------

def test_capacity_model_telemetry():
    m = CapacityModel(min_observations=4, decay_s=None)
    # G = 1 << ts[-1] = 4096 — roomy enough for the learned tier to land
    # above the 500-survivor observations instead of clamping at G
    sig = ShapeSig(k=2, ts=(4, 12), gmaxes=(16, 4096), capacity_tier=64)
    m.observe_bucket(sig, [{"tuples_survived": 500}] * 4)
    tel = m.telemetry()
    entry = tel[str(adaptive_key(sig))]
    assert entry["observations"] == 4
    assert entry["window_max"] == 500
    assert entry["learned_tier"] == m.capacity_for(adaptive_key(sig), 0)
    assert entry["learned_tier"] >= 512  # >= quantile * margin, pow2


def test_adaptive_deadline_telemetry():
    d = AdaptiveDeadline(min_observations=2)
    for i in range(4):
        d.observe("k", i * 0.01)
    tel = d.telemetry()["k"]
    assert tel["gaps"] == 3 and tel["warm"]
    assert tel["gap_ewma_us"] == pytest.approx(10_000.0, rel=0.01)


# ---------------------------------------------------------------------------
# global obs plumbing
# ---------------------------------------------------------------------------

def test_global_obs_reset_discards_override():
    mine = set_obs(Obs(trace=True))
    assert get_obs() is mine
    from repro.obs import reset_obs

    reset_obs()
    fresh = get_obs()
    assert fresh is not mine and not fresh.tracer.enabled


def test_obs_reset_leaves_exec_counters_alone():
    obs = Obs()
    obs.dispatch_failures.inc()
    EXEC_COUNTERS.bump("batch_calls", 3)
    obs.reset()
    assert obs.dispatch_failures.value == 0
    assert EXEC_COUNTERS["batch_calls"] == 3
