"""Validate the trip-count-aware HLO walker against analytic ground truth."""
import numpy as np
import pytest


def test_walker_square_scan_exact():
    import jax, jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo

    L, d = 11, 128
    w = jnp.ones((d, d), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, ()
        out, _ = jax.lax.scan(body, x, None, length=L)
        return out

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((32, d), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text(), default_group=1)
    analytic = 2 * 32 * d * d * L
    assert abs(res["flops_per_device"] - analytic) / analytic < 0.05


def test_walker_collectives_inside_scan():
    import os
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze_hlo

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under forced host device count)")

    mesh = jax.make_mesh((len(jax.devices()),), ("model",))
    d = 64
    w_spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((8, d), jnp.float32)

    def f(w, x):
        def body(c, _):
            h = c @ w  # w col-sharded -> psum per step
            h = jax.lax.with_sharding_constraint(h, P(None, None))
            return h, ()
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    with mesh:
        compiled = jax.jit(
            f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                             NamedSharding(mesh, P())),
        ).lower(w_spec, x_spec).compile()
    res = analyze_hlo(compiled.as_text(), default_group=len(jax.devices()))
    # some collective must be counted with the x5 loop multiplier
    assert res["wire_bytes_per_device"] > 0
    counts = res["collective_count_by_type"]
    assert any(v >= 5 for v in counts.values()), counts


def test_walker_dus_counts_slice_not_buffer():
    """dynamic-update-slice traffic = the update, not the whole buffer."""
    import jax, jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo

    big = 1 << 20

    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0,))

    compiled = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((big,), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text(), default_group=1)
    # must be orders of magnitude below the 4MiB buffer size
    assert res["hbm_bytes_per_device"] < big  # < 1 byte/elem
