"""Tests for the CI bench-regression gate (``tools/check_bench.py``)."""
import importlib.util
import json
import pathlib
import sys

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    pathlib.Path(__file__).resolve().parent.parent / "tools"
    / "check_bench.py")
check_bench = importlib.util.module_from_spec(_SPEC)
sys.modules["check_bench"] = check_bench   # dataclasses needs it registered
_SPEC.loader.exec_module(check_bench)


def _batched(qps: float, queries: int = 256):
    return {"queries": queries, "n_docs": 20000, "batched_qps": qps,
            "speedup": 4.0}


def _admission(p99: float, qps: float = 4000.0, queries: int = 512):
    return {"queries": queries, "n_docs": 12000,
            "runs": [{"deadline_us": 2000.0, "served_qps": qps,
                      "p99_wait_us": p99, "p99_wait_within_deadline": True}]}


def _write(tmp_path, sub: str, name: str, payload: dict) -> pathlib.Path:
    d = tmp_path / sub
    d.mkdir(exist_ok=True)
    (d / name).write_text(json.dumps(payload))
    return d


def test_identical_runs_pass(tmp_path):
    base = _write(tmp_path, "base", "BENCH_batched_qps.json", _batched(5000))
    cur = _write(tmp_path, "cur", "BENCH_batched_qps.json", _batched(5000))
    assert check_bench.check_dirs(base, cur) == []


def test_qps_drop_over_30pct_fails(tmp_path):
    base = _write(tmp_path, "base", "BENCH_batched_qps.json", _batched(5000))
    cur = _write(tmp_path, "cur", "BENCH_batched_qps.json", _batched(3000))
    failures = check_bench.check_dirs(base, cur)
    assert len(failures) == 1 and "batched_qps" in failures[0]
    # a 25% drop stays within the 30% budget
    cur2 = _write(tmp_path, "cur2", "BENCH_batched_qps.json", _batched(3750))
    assert check_bench.check_dirs(base, cur2) == []


def test_p99_wait_2x_regression_fails(tmp_path):
    base = _write(tmp_path, "base", "BENCH_admission_latency.json",
                  _admission(p99=1900.0))
    cur = _write(tmp_path, "cur", "BENCH_admission_latency.json",
                 _admission(p99=4200.0))
    failures = check_bench.check_dirs(base, cur)
    assert len(failures) == 1 and "p99_wait_us" in failures[0]
    cur2 = _write(tmp_path, "cur2", "BENCH_admission_latency.json",
                  _admission(p99=3500.0))
    assert check_bench.check_dirs(base, cur2) == []


def test_scale_mismatch_skips_relative_but_keeps_absolute(tmp_path):
    # seed baseline: full-size run; current: smoke run — QPS must not gate,
    # but the absolute invariants still do
    base = _write(tmp_path, "base", "BENCH_admission_latency.json",
                  _admission(p99=1900.0, qps=4000.0, queries=512))
    bad = _admission(p99=1900.0, qps=10.0, queries=128)
    bad["runs"][0]["p99_wait_within_deadline"] = False
    cur = _write(tmp_path, "cur", "BENCH_admission_latency.json", bad)
    failures = check_bench.check_dirs(base, cur)
    assert len(failures) == 1
    assert "p99_wait_within_deadline" in failures[0]


def test_missing_baseline_uses_absolute_rules_only(tmp_path):
    base = tmp_path / "empty"
    base.mkdir()
    cur = _write(tmp_path, "cur", "BENCH_batched_qps.json", _batched(5000))
    assert check_bench.check_dirs(base, cur) == []
    slow = _batched(5000)
    slow["speedup"] = 0.5                      # batching slower than loop
    cur2 = _write(tmp_path, "cur2", "BENCH_batched_qps.json", slow)
    failures = check_bench.check_dirs(base, cur2)
    assert len(failures) == 1 and "speedup" in failures[0]


def test_absolute_list_rule_works_without_baseline(tmp_path):
    """Regression: absolute invariants on aligned-list paths (runs[...])
    must evaluate against the current run alone — no baseline file may
    neither fail them as 'metric missing' nor skip them."""
    base = tmp_path / "empty"
    base.mkdir()
    cur = _write(tmp_path, "cur", "BENCH_admission_latency.json",
                 _admission(p99=1900.0))
    assert check_bench.check_dirs(base, cur) == []
    bad = _admission(p99=1900.0)
    bad["runs"][0]["p99_wait_within_deadline"] = False
    cur2 = _write(tmp_path, "cur2", "BENCH_admission_latency.json", bad)
    failures = check_bench.check_dirs(base, cur2)
    assert len(failures) == 1 and "p99_wait_within_deadline" in failures[0]


def test_changed_sweep_skips_instead_of_failing(tmp_path):
    """Regression: a current run whose sweep points no longer align with
    the baseline (e.g. new deadline values) is a config change — relative
    rules skip, they don't report 'metric missing'."""
    base_payload = _admission(p99=1900.0)
    base_payload["runs"][0]["deadline_us"] = 9999.0    # old sweep point
    base = _write(tmp_path, "base", "BENCH_admission_latency.json",
                  base_payload)
    cur = _write(tmp_path, "cur", "BENCH_admission_latency.json",
                 _admission(p99=1900.0))               # new sweep point
    assert check_bench.check_dirs(base, cur) == []
    # but a metric genuinely absent from the current run still fails
    broken = _admission(p99=1900.0)
    del broken["runs"]
    cur2 = _write(tmp_path, "cur2", "BENCH_admission_latency.json", broken)
    failures = check_bench.check_dirs(
        _write(tmp_path, "base2", "BENCH_admission_latency.json",
               _admission(p99=1900.0)), cur2)
    assert failures and all("metric missing" in f for f in failures)


def test_empty_current_dir_fails(tmp_path):
    base = tmp_path / "b"
    cur = tmp_path / "c"
    base.mkdir(), cur.mkdir()
    failures = check_bench.check_dirs(base, cur)
    assert failures and "no BENCH_" in failures[0]


def _mesh2d(speedup: float, identical: int = 1, qps_1x4: float = 70.0,
            qps_2x2: float = 250.0, queries: int = 256):
    return {
        "queries": queries, "set_size": 50000, "n_terms": 12, "overlap": 400,
        "identical_to_baseline": identical,
        "baseline": {"qps": 220.0},
        "layouts": [
            {"layout": "1x4", "qps": qps_1x4},
            {"layout": "2x2", "qps": qps_2x2},
            {"layout": "4x1", "qps": 250.0},
        ],
        "speedup_2x2_vs_1x4": speedup,
    }


def test_mesh2d_identity_and_speedup_floor_gate(tmp_path):
    base = _write(tmp_path, "base", "BENCH_mesh2d_qps.json", _mesh2d(3.7))
    cur = _write(tmp_path, "cur", "BENCH_mesh2d_qps.json", _mesh2d(3.5))
    assert check_bench.check_dirs(base, cur) == []
    # equality breakage is an absolute failure at any scale
    cur2 = _write(tmp_path, "cur2", "BENCH_mesh2d_qps.json",
                  _mesh2d(3.5, identical=0, queries=64))
    failures = check_bench.check_dirs(base, cur2)
    assert any("identical_to_baseline" in f for f in failures)
    # 2x2 losing to the pure z-shard layout fails even without a baseline
    cur3 = _write(tmp_path, "cur3", "BENCH_mesh2d_qps.json", _mesh2d(0.9))
    failures = check_bench.check_dirs(base, cur3)
    assert any("speedup_2x2_vs_1x4" in f for f in failures)


def _slo(cal_burn: float = 0.02, over_burn: float = 0.35, leak: int = 0,
         identical: int = 1, balanced: int = 1, errors: int = 0,
         over_qps: float = 2500.0, queries: int = 5000):
    return {
        "queries": queries, "n_docs": 12000, "vocab_kept": 900,
        "distinct_pool": 96,
        "identical_to_oracle": identical,
        "dispatch_collect_balanced": balanced,
        "thread_leak": leak, "errors_total": errors,
        "calibrated_burn_rate": cal_burn,
        "overload_burn_rate": over_burn,
        "virtual_runs": [
            {"rate_x": 0.04, "served_qps": 140.0},
            {"rate_x": 0.75, "served_qps": over_qps},
        ],
    }


def test_slo_burn_absolute_invariants_gate(tmp_path):
    base = _write(tmp_path, "base", "BENCH_slo_burn.json", _slo())
    cur = _write(tmp_path, "cur", "BENCH_slo_burn.json", _slo())
    assert check_bench.check_dirs(base, cur) == []
    # each absolute invariant fails on its own, at any scale
    for broken, needle in [
        (_slo(identical=0, queries=64), "identical_to_oracle"),
        (_slo(balanced=0, queries=64), "dispatch_collect_balanced"),
        (_slo(leak=1, queries=64), "thread_leak"),
        (_slo(errors=3, queries=64), "errors_total"),
        (_slo(cal_burn=0.2, queries=64), "calibrated_burn_rate"),
        (_slo(over_burn=0.05, queries=64), "overload_burn_rate"),
    ]:
        cur_d = _write(tmp_path, f"cur_{needle}", "BENCH_slo_burn.json",
                       broken)
        failures = check_bench.check_dirs(base, cur_d)
        assert any(needle in f for f in failures), (needle, failures)


def test_slo_burn_served_qps_relative_same_scale_only(tmp_path):
    base = _write(tmp_path, "base", "BENCH_slo_burn.json", _slo())
    # 60% throughput drop at the same workload scale -> relative rule fires
    cur = _write(tmp_path, "cur", "BENCH_slo_burn.json", _slo(over_qps=1000.0))
    failures = check_bench.check_dirs(base, cur)
    assert any("virtual_runs[rate_x=0.75].served_qps" in f for f in failures)
    # same drop at smoke scale (different queries) -> skipped
    cur2 = _write(tmp_path, "cur2", "BENCH_slo_burn.json",
                  _slo(over_qps=1000.0, queries=64))
    assert check_bench.check_dirs(base, cur2) == []


def _boolean(qps: float = 900.0, identical: int = 1, hits: int = 40,
             merges: int = 10, queries: int = 256):
    return {
        "queries": queries, "n_docs": 20000, "n_terms": 12,
        "identical_to_oracle": identical,
        "subexpr_cache_hits": hits,
        "subexpr_host_merges": merges,
        "served_qps": qps,
    }


def test_boolean_qps_invariants_gate(tmp_path):
    base = _write(tmp_path, "base", "BENCH_boolean_qps.json", _boolean())
    cur = _write(tmp_path, "cur", "BENCH_boolean_qps.json", _boolean(870.0))
    assert check_bench.check_dirs(base, cur) == []
    # absolute invariants fail on their own, at any workload scale
    for broken, needle in [
        (_boolean(identical=0, queries=64), "identical_to_oracle"),
        (_boolean(hits=0, queries=64), "subexpr_cache_hits"),
        (_boolean(merges=0, queries=64), "subexpr_host_merges"),
    ]:
        cur_d = _write(tmp_path, f"cur_{needle}", "BENCH_boolean_qps.json",
                       broken)
        failures = check_bench.check_dirs(base, cur_d)
        assert any(needle in f for f in failures), (needle, failures)


def test_boolean_qps_relative_same_scale_only(tmp_path):
    base = _write(tmp_path, "base", "BENCH_boolean_qps.json", _boolean())
    # 50% throughput drop at the same workload scale -> relative rule fires
    cur = _write(tmp_path, "cur", "BENCH_boolean_qps.json", _boolean(450.0))
    failures = check_bench.check_dirs(base, cur)
    assert any("served_qps" in f for f in failures)
    # same drop at smoke scale -> skipped (absolute invariants still hold)
    cur2 = _write(tmp_path, "cur2", "BENCH_boolean_qps.json",
                  _boolean(450.0, queries=64))
    assert check_bench.check_dirs(base, cur2) == []


def test_mesh2d_layout_qps_regression_fails_same_scale_only(tmp_path):
    base = _write(tmp_path, "base", "BENCH_mesh2d_qps.json", _mesh2d(3.7))
    # 2x2 QPS drops 60% at the same workload scale -> relative rule fires
    cur = _write(tmp_path, "cur", "BENCH_mesh2d_qps.json",
                 _mesh2d(3.7, qps_2x2=100.0))
    failures = check_bench.check_dirs(base, cur)
    assert any("layouts[layout=2x2].qps" in f for f in failures)
    # same drop against a differently-sized baseline (CI smoke) -> skipped
    cur2 = _write(tmp_path, "cur2", "BENCH_mesh2d_qps.json",
                  _mesh2d(3.7, qps_2x2=100.0, queries=64))
    assert check_bench.check_dirs(base, cur2) == []
