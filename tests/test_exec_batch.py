"""Tests for the batched multi-query execution subsystem (repro.exec).

Covers: planner normalization (dedup, routing, shape signatures), the
bucketed batch executor against per-query and host oracles over mixed-shape
batches, the overflow -> single full-capacity re-run path, and the
acceptance bound that a 256-query zipf log issues O(#signatures) jit
executions, not O(#queries).
"""
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core.engine import (
    BatchedEngine, DeviceSet, intersect_device, intersect_device_batch,
    EXEC_COUNTERS,
)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.plan import plan_query
from repro.serve.search import SearchEngine, zipf_query_log


@pytest.fixture(scope="module")
def corpus():
    """Sets of assorted sizes -> assorted (t, gmax) shapes."""
    rng = np.random.default_rng(7)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    common = rng.choice(1 << 24, 80, replace=False).astype(np.uint32)
    raw, idxs = {}, {}
    for name, n in [("a", 900), ("b", 1100), ("c", 4000),
                    ("d", 4300), ("e", 9000)]:
        s = np.unique(np.concatenate(
            [rng.choice(1 << 24, n, replace=False).astype(np.uint32), common]))
        raw[name] = s
        idxs[name] = preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
    return raw, idxs


def truth_of(sets):
    out = sets[0]
    for s in sets[1:]:
        out = np.intersect1d(out, s)
    return out


MIXED_QUERIES = [
    ["a", "b"], ["c", "d"], ["a", "e"], ["a", "b", "c"],
    ["c", "d", "e"], ["b", "a"], ["a", "b", "c", "d"], ["e", "c", "d"],
    ["a"], ["a", "a", "b"],
]


def test_query_many_matches_per_query_and_host(corpus):
    raw, idxs = corpus
    eng = BatchedEngine(use_pallas=False)
    for k, v in idxs.items():
        eng.add(k, v)
    batched = eng.query_many(MIXED_QUERIES)
    assert len(batched) == len(MIXED_QUERIES)
    for q, (res, stats) in zip(MIXED_QUERIES, batched):
        names = sorted(set(q))
        truth = truth_of([raw[n] for n in names])
        # host oracle (Alg. 5 reference)
        host, _ = rangroupscan([idxs[n] for n in names])
        # per-query device path (batch of one)
        single, _ = intersect_device([eng.sets[n] for n in names],
                                     use_pallas=False)
        assert np.array_equal(res, truth), f"batched wrong for {q}"
        assert np.array_equal(host, truth)
        assert np.array_equal(single, truth)
        assert stats["r"] == len(truth)


def test_query_many_pallas_path(corpus):
    raw, idxs = corpus
    eng = BatchedEngine(use_pallas=True)
    for k in ("a", "b", "c"):
        eng.add(k, idxs[k])
    out = eng.query_many([["a", "b"], ["a", "c"], ["a", "b", "c"]])
    assert np.array_equal(out[0][0], truth_of([raw["a"], raw["b"]]))
    assert np.array_equal(out[1][0], truth_of([raw["a"], raw["c"]]))
    assert np.array_equal(out[2][0], truth_of([raw["a"], raw["b"], raw["c"]]))


def test_batched_overflow_rerun(corpus):
    raw, idxs = corpus
    dsets = {k: DeviceSet.from_host(v) for k, v in idxs.items()}
    queries = [[dsets["a"], dsets["b"]], [dsets["b"], dsets["a"]]]
    EXEC_COUNTERS.reset()
    out = intersect_device_batch(queries, capacity=4, use_pallas=False)
    truth = truth_of([raw["a"], raw["b"]])
    for res, stats in out:
        assert np.array_equal(res, truth)
        assert stats["capacity"] > 4  # re-run at full capacity G
    # overflow triggers exactly ONE re-run pass (straight to capacity G)
    assert EXEC_COUNTERS["rerun_calls"] == 1
    assert EXEC_COUNTERS["batch_calls"] == 2


def test_batch_mixed_signature_rejected(corpus):
    _, idxs = corpus
    dsets = {k: DeviceSet.from_host(v) for k, v in idxs.items()}
    with pytest.raises(AssertionError):
        intersect_device_batch(
            [[dsets["a"], dsets["b"]], [dsets["a"], dsets["e"]]],
            use_pallas=False)


def test_planner_dedup_and_routing(corpus):
    _, idxs = corpus
    plan = plan_query(idxs, ["a", "a", "b", "a"])
    assert plan.terms == ("a", "b") or set(plan.terms) == {"a", "b"}
    assert len(plan.terms) == 2
    assert plan.algorithm == "device"
    assert plan.sig.k == 2
    # same signature regardless of request order -> same bucket
    assert plan.sig == plan_query(idxs, ["b", "a"]).sig
    # missing term -> empty
    assert plan_query(idxs, ["a", "zz"]).algorithm == "empty"
    # k == 1 after dedup still plans
    assert plan_query(idxs, ["a", "a"]).terms == ("a",)
    # host routing when no device
    assert plan_query(idxs, ["a", "b"], device=False).algorithm == "host"
    # extreme ratio -> hashbin
    assert plan_query(idxs, ["a", "e"], hashbin_ratio=2.0).algorithm == "hashbin"


def _small_search_engine(n_docs=3000, vocab=600, use_device=True):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=40, seed=3)
    postings = inverted_index(docs)
    return SearchEngine(postings, w=256, m=2, seed=3, use_device=use_device)


def test_search_engine_query_dedup():
    eng = _small_search_engine(use_device=False)
    term = sorted(eng.index)[0]
    single = eng.query([term])
    doubled = eng.query([term, term])
    assert np.array_equal(single.doc_ids, doubled.doc_ids)
    assert np.array_equal(np.sort(doubled.doc_ids), np.sort(eng.index[term].values))


def test_query_batch_zipf_jit_executions_bounded():
    """Acceptance: a 256-query zipf log issues <= (#distinct device shape
    signatures + overflow re-runs) jit executions — not 256."""
    eng = _small_search_engine(use_device=True)
    log = zipf_query_log(sorted(eng.index), 256, seed=11)
    plans = [eng.plan(q) for q in log]
    device_sigs = {p.sig for p in plans if p.algorithm == "device"}
    assert device_sigs, "zipf log produced no device-routed queries"
    EXEC_COUNTERS.reset()
    results = eng.query_batch(log)
    assert EXEC_COUNTERS["batch_calls"] <= \
        len(device_sigs) + EXEC_COUNTERS["rerun_calls"]
    assert EXEC_COUNTERS["batch_calls"] < len(log)
    # and the batch is correct: spot-check every 8th query vs the host truth
    for q, r in list(zip(log, results))[::8]:
        truth = truth_of([eng.index[t].values for t in dict.fromkeys(q)])
        assert np.array_equal(r.doc_ids, np.sort(truth).astype(np.uint32)), q


def test_query_batch_matches_per_query_results():
    eng = _small_search_engine(use_device=True)
    log = zipf_query_log(sorted(eng.index), 48, seed=5)
    batched = eng.query_batch(log)
    for q, br in zip(log, batched):
        single = eng.query(q)
        assert np.array_equal(br.doc_ids, single.doc_ids), q
        assert br.algorithm == single.algorithm
