"""Shared test fixtures.

The autouse counter reset keeps every counter-asserting test
order-independent: ``EXEC_COUNTERS`` is process-global telemetry, so
without this a test that executes device buckets would leak counts into
the next test's assertions (the pre-PR-2 failure mode was exactly that —
tests had to remember to call ``reset_exec_counters()`` inline).
"""
import pytest

from repro.core.engine import EXEC_COUNTERS


@pytest.fixture(autouse=True)
def _reset_exec_counters():
    EXEC_COUNTERS.reset()
    yield
