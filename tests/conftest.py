"""Shared test fixtures.

The autouse counter reset keeps every counter-asserting test
order-independent: ``EXEC_COUNTERS`` is process-global telemetry, so
without this a test that executes device buckets would leak counts into
the next test's assertions (the pre-PR-2 failure mode was exactly that —
tests had to remember to call ``reset_exec_counters()`` inline).

The obs reset is the same hygiene for the observability layer: engines
fall back to the process-global ``Obs`` (``repro.obs.get_obs``), so a
test that installs a tracing-enabled ``Obs`` via ``set_obs`` — or just
executes buckets, which feed the global profile store and histograms —
must not leak that state into the next test.
"""
import pytest

from repro.core.engine import EXEC_COUNTERS
from repro.obs import reset_obs


@pytest.fixture(autouse=True)
def _reset_exec_counters():
    EXEC_COUNTERS.reset()
    reset_obs()
    yield
