"""Tests for the autonomous serving runtime: the telemetry-driven adaptive
capacity/deadline controllers (``exec/adaptive.py``) and the background
flusher thread (``AsyncSearchEngine.start/stop``).

Covers the adaptive contract end to end: cold-start falls back to the
static G/4 rule, a hot signature's learned tier converges from survivor
telemetry, a replayed overflow workload stops paying re-runs, tier
promotion invalidates the result cache and re-warms the promoted
executable; and the flusher contract: no manual ``pump`` needed, clean
start/stop with no dangling threads, results bit-identical to the
synchronous ``query_batch`` oracle, and race-freedom under submitter
threads hammering during flushes with concurrent (idempotent) drains.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.engine import EXEC_COUNTERS, default_capacity
from repro.exec.adaptive import AdaptiveDeadline, CapacityModel, adaptive_key
from repro.exec.plan import ShapeSig
from repro.serve.search import (
    AsyncSearchEngine, SearchEngine, zipf_query_log,
)
from repro.data.pipeline import inverted_index, zipf_corpus


@pytest.fixture(scope="module")
def postings():
    docs = zipf_corpus(2500, vocab=500, mean_len=30, seed=3)
    return inverted_index(docs)


@pytest.fixture(scope="module")
def overflow_postings():
    """Two near-identical dense terms: every group tuple of [1, 2] survives
    phase 1 (the sets share all elements), so survivors ≈ G > G/4 and the
    static capacity rule is guaranteed to overflow."""
    rng = np.random.default_rng(0)
    dense = rng.choice(100_000, size=2048, replace=False).astype(np.uint32)
    sparse = rng.choice(100_000, size=300, replace=False).astype(np.uint32)
    return {1: dense, 2: dense.copy(), 3: sparse}


# ---------------------------------------------------------------------------
# CapacityModel unit behavior
# ---------------------------------------------------------------------------

def _sig(ts=(9, 9), shards=1, capacity=None):
    return ShapeSig(k=len(ts), ts=tuple(ts), gmaxes=(8,) * len(ts),
                    capacity_tier=capacity or default_capacity(ts),
                    shards=shards)


def test_cold_start_falls_back_to_static_rule():
    model = CapacityModel(min_observations=8)
    sig = _sig()
    key = adaptive_key(sig)
    assert model.capacity_for(key, default_capacity(sig.ts)) == \
        default_capacity(sig.ts)
    # fewer than min_observations samples: still cold
    model.observe_bucket(sig, [{"tuples_survived": 400}] * 7)
    assert model.capacity_for(key, default_capacity(sig.ts)) == \
        default_capacity(sig.ts)
    assert EXEC_COUNTERS["adaptive_promotions"] == 0


def test_learned_tier_converges_for_hot_sig():
    model = CapacityModel(min_observations=8, quantile=0.99, margin=1.25)
    sig = _sig(ts=(9, 9))                       # G = 512, static tier 128
    key = adaptive_key(sig)
    model.observe_bucket(sig, [{"tuples_survived": 200}] * 8)
    # 200 * 1.25 = 250 -> pow2 ceiling 256, within [64, 512]
    assert model.capacity_for(key, 128) == 256
    assert EXEC_COUNTERS["adaptive_promotions"] == 1
    # more of the same: tier is stable, no flapping promotions
    model.observe_bucket(sig, [{"tuples_survived": 200}] * 8)
    assert model.capacity_for(key, 128) == 256
    assert EXEC_COUNTERS["adaptive_promotions"] == 1
    # learned tiers clamp to G even under an extreme quantile observation
    model.observe_bucket(sig, [{"tuples_survived": 512}] * 32)
    assert model.capacity_for(key, 128) <= 512


def test_learned_tier_can_shrink_below_static_rule():
    model = CapacityModel(min_observations=8)
    sig = _sig(ts=(9, 9))                       # static tier G/4 = 128
    key = adaptive_key(sig)
    model.observe_bucket(sig, [{"tuples_survived": 10}] * 8)
    # 10 * 1.25 -> pow2 16, floored at 64: less phase-2 work than G/4
    assert model.capacity_for(key, 128) == 64
    # a shrink below the static rule is a DEMOTION, counted separately
    assert EXEC_COUNTERS["adaptive_demotions"] == 1
    assert EXEC_COUNTERS["adaptive_promotions"] == 0


def test_decayed_window_demotes_after_workload_drift():
    """The drift contract: a tier inflated by a survivor burst shrinks once
    the burst ages past the decay horizon and fresh traffic shows smaller
    survivors — with the demotion counted and the change hook fired
    (symmetric to promotion, so the serving layer invalidates its cache
    and re-warms)."""
    now = [0.0]
    model = CapacityModel(min_observations=8, decay_s=10.0,
                          clock=lambda: now[0])
    sig = _sig(ts=(9, 9))                       # G = 512, static tier 128
    key = adaptive_key(sig)
    changes = []
    model.on_promotion(lambda *a: changes.append(a))
    model.observe_bucket(sig, [{"tuples_survived": 400}] * 8)
    assert model.capacity_for(key, 128) == 512  # 400 * 1.25 -> 512
    assert EXEC_COUNTERS["adaptive_promotions"] == 1
    assert changes == [(key, 128, 512)]
    # drift: the burst ages out, fresh traffic has tiny survivor counts
    now[0] = 11.0
    model.observe_bucket(sig, [{"tuples_survived": 10}] * 8)
    assert model.capacity_for(key, 128) == 64
    assert EXEC_COUNTERS["adaptive_demotions"] == 1
    assert EXEC_COUNTERS["adaptive_promotions"] == 1
    assert changes[-1] == (key, 512, 64)
    assert model.observations(key) == 8         # burst samples pruned


def test_pruned_window_below_min_observations_keeps_tier():
    """A traffic lull must not flap a learned tier back to the static
    rule: when pruning leaves fewer than min_observations fresh samples,
    the current tier stands until enough new evidence accumulates."""
    now = [0.0]
    model = CapacityModel(min_observations=8, decay_s=10.0,
                          clock=lambda: now[0])
    sig = _sig(ts=(9, 9))
    key = adaptive_key(sig)
    model.observe_bucket(sig, [{"tuples_survived": 400}] * 8)
    assert model.capacity_for(key, 128) == 512
    now[0] = 20.0                               # everything decayed
    model.observe_bucket(sig, [{"tuples_survived": 10}] * 2)
    assert model.observations(key) == 2         # old window gone
    assert model.capacity_for(key, 128) == 512  # tier kept, no flap
    assert EXEC_COUNTERS["adaptive_demotions"] == 0
    # once min_observations fresh samples accumulate, the tier moves
    model.observe_bucket(sig, [{"tuples_survived": 10}] * 6)
    assert model.capacity_for(key, 128) == 64
    assert EXEC_COUNTERS["adaptive_demotions"] == 1


def test_adaptive_key_separates_replica_widths():
    """Mesh-routed (replicas > 1) and single-device executions of the same
    shapes are different executables: their survivor histories must not
    share a learning key."""
    ts = (9, 9)
    flat = ShapeSig(k=2, ts=ts, gmaxes=(8, 8), capacity_tier=128)
    wide = ShapeSig(k=2, ts=ts, gmaxes=(8, 8), capacity_tier=128,
                    shards=2, replicas=2)
    assert adaptive_key(flat) != adaptive_key(wide)
    model = CapacityModel(min_observations=4)
    model.observe_bucket(wide, [{"tuples_survived": 400}] * 4)
    assert model.capacity_for(adaptive_key(flat), 128) == 128  # untouched
    assert model.capacity_for(adaptive_key(wide), 128) == 512


def test_sharded_stats_observe_per_shard_survivors():
    model = CapacityModel(min_observations=4)
    sig = _sig(ts=(9, 9), shards=4)
    key = adaptive_key(sig)
    stats = [{"n_shards": 4, "max_shard_survivors": 50,
              "tuples_survived": 120}] * 4
    model.observe_bucket(sig, stats)
    # effective requirement is max_shard * shards = 200 (the per-shard
    # buffer binds), not the whole-query 120
    assert model.capacity_for(key, 128) == 256


def test_overflow_saved_counter():
    model = CapacityModel(min_observations=64)  # stay cold: isolate counter
    learned = _sig(ts=(9, 9), capacity=256)     # pretend tier already learned
    model.observe_bucket(learned, [{"tuples_survived": 200}])
    # 200 > static 128 but fit the learned 256: one saved re-run
    assert EXEC_COUNTERS["adaptive_overflow_saved"] == 1
    static = _sig(ts=(9, 9))                    # static tier: nothing saved
    model.observe_bucket(static, [{"tuples_survived": 200}])
    assert EXEC_COUNTERS["adaptive_overflow_saved"] == 1


# ---------------------------------------------------------------------------
# Adaptive capacity through the serving stack
# ---------------------------------------------------------------------------

def test_plan_consults_model_and_replay_has_zero_reruns(overflow_postings):
    model = CapacityModel(min_observations=4)
    eng = SearchEngine(overflow_postings, use_device=True,
                       adaptive_capacity=model, result_cache=0)
    static_sig = eng.plan([1, 2]).sig
    assert static_sig.capacity_tier == default_capacity(static_sig.ts)

    EXEC_COUNTERS.reset()
    eng.query_batch([[1, 2]] * 6)               # static tier overflows
    assert EXEC_COUNTERS["rerun_calls"] >= 1
    assert EXEC_COUNTERS["adaptive_promotions"] >= 1
    learned_sig = eng.plan([1, 2]).sig
    assert learned_sig.capacity_tier > static_sig.capacity_tier

    EXEC_COUNTERS.reset()
    results = eng.query_batch([[1, 2]] * 6)     # replay: learned tier holds
    assert EXEC_COUNTERS["rerun_calls"] == 0
    assert EXEC_COUNTERS["adaptive_overflow_saved"] == 6
    oracle = np.sort(np.intersect1d(overflow_postings[1],
                                    overflow_postings[2]))
    for r in results:
        assert np.array_equal(r.doc_ids, oracle)


def test_tier_promotion_invalidates_stale_cache_entries(overflow_postings):
    model = CapacityModel(min_observations=4)
    eng = SearchEngine(overflow_postings, use_device=True,
                       adaptive_capacity=model, result_cache=64)
    first = eng.query([1, 3])
    assert not first.stats.get("cached")
    assert eng.query([1, 3]).stats.get("cached") is True   # primed
    # drive the dense sig past min_observations -> promotion fires and
    # invalidates the cache (cache disabled for the driver queries? no —
    # repeats would hit the cache, so vary nothing: the cache returns hits
    # for [1,2] repeats, but misses still execute once per generation)
    EXEC_COUNTERS.reset()
    eng.cache.clear()                          # force executions to observe
    eng.query_batch([[1, 2]] * 6)
    assert EXEC_COUNTERS["adaptive_promotions"] >= 1
    refreshed = eng.query([1, 3])
    assert not refreshed.stats.get("cached")   # promotion invalidated it
    assert np.array_equal(refreshed.doc_ids, first.doc_ids)


def test_promotion_rewarm_traces_promoted_executable(overflow_postings):
    from repro.core.engine import clear_exec_jit_cache

    model = CapacityModel(min_observations=4)
    eng = SearchEngine(overflow_postings, use_device=True,
                       adaptive_capacity=model, result_cache=0)
    clear_exec_jit_cache()
    eng.warm([[1, 2]], top_k=1, b_tiers=(1,))
    EXEC_COUNTERS.reset()
    eng.query_batch([[1, 2]] * 6)              # overflow -> learn -> promote
    assert EXEC_COUNTERS["adaptive_promotions"] >= 1
    # the promotion hook re-warmed the promoted signature at the warmed
    # tiers, so a live single-query bucket compiles nothing now
    assert EXEC_COUNTERS["warm_executions"] >= 1
    EXEC_COUNTERS.reset()
    eng.query([1, 2])
    assert EXEC_COUNTERS["batch_calls"] >= 1
    assert EXEC_COUNTERS["batch_traces"] == 0


def test_promotion_rewarm_traces_the_learned_tier_executable():
    """Regression: the re-warm must execute at the PROMOTED capacity tier.
    Warming the static tier would trace an executable no live bucket ever
    runs — here the learned tier (256) sits strictly between the static
    rule (128) and G (512), so the static-tier trace can't mask the miss.
    """
    from repro.core.engine import clear_exec_jit_cache

    rng = np.random.default_rng(7)
    pool = rng.choice(1 << 20, size=2 * 8192, replace=False).astype(np.uint32)
    a, b = pool[:8192], pool[8192:]
    b[:64] = a[:64]                            # small real overlap
    model = CapacityModel(min_observations=4)
    eng = SearchEngine({1: a, 2: b}, use_device=True,
                       adaptive_capacity=model, result_cache=0)
    sig = eng.plan([1, 2]).sig
    assert sig.ts[-1] == 9 and sig.capacity_tier == 128   # static G/4 rule
    clear_exec_jit_cache()
    eng.warm([[1, 2]], top_k=1, b_tiers=(1,))
    EXEC_COUNTERS.reset()
    # force a promotion to a mid tier: quantile 150 * 1.25 -> pow2 256
    model.observe_bucket(sig, [{"tuples_survived": 150}] * 4)
    assert EXEC_COUNTERS["adaptive_promotions"] == 1
    assert eng.plan([1, 2]).sig.capacity_tier == 256
    assert EXEC_COUNTERS["warm_executions"] >= 1          # hook re-warmed
    EXEC_COUNTERS.reset()
    eng.query([1, 2])                          # first live query, tier 256
    assert EXEC_COUNTERS["batch_calls"] >= 1
    assert EXEC_COUNTERS["rerun_calls"] == 0   # real survivors << 256
    assert EXEC_COUNTERS["batch_traces"] == 0  # promoted tier pre-traced


# ---------------------------------------------------------------------------
# AdaptiveDeadline
# ---------------------------------------------------------------------------

def test_adaptive_deadline_budget_policy():
    ctl = AdaptiveDeadline(min_observations=4, alpha=1.0, min_fraction=0.125)
    key = ("sig",)
    assert ctl.budget_for(key, 2000.0) == 2000.0          # cold: default
    for i in range(6):
        ctl.observe(key, i * 0.000_100)                   # 100 us gaps: hot
    assert ctl.budget_for(key, 2000.0) == 2000.0          # tier fires anyway
    slow = ("slow",)
    for i in range(6):
        ctl.observe(slow, i * 0.100)                      # 100 ms gaps
    budget = ctl.budget_for(slow, 2000.0)
    assert budget == pytest.approx(250.0)                 # clamped floor
    mid = ("mid",)
    for i in range(6):
        ctl.observe(mid, i * 0.004)                       # 4 ms gaps
    assert ctl.budget_for(mid, 2000.0) == pytest.approx(1000.0)


def test_adaptive_deadline_shrinks_ticket_budget(postings):
    class FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    clk = FakeClock()
    eng = AsyncSearchEngine(postings, clock=clk, seed=3, deadline_us=2000.0,
                            flush_tier=8, result_cache=0,
                            adaptive_deadline=AdaptiveDeadline(
                                min_observations=3, alpha=1.0))
    q = next(q for q in zipf_query_log(sorted(eng.index), 32, seed=2)
             if eng.plan(q).algorithm == "device")
    tickets = []
    for _ in range(6):
        tickets.append(eng.submit(q if not tickets else q))
        clk.t += 0.050                                    # 50 ms gaps: cold sig
        eng.drain()
    # after warm-up the learned budget is far below the 2 ms default
    assert tickets[-1].deadline_us < 2000.0
    assert tickets[0].deadline_us == 2000.0               # cold start: default


# ---------------------------------------------------------------------------
# Background flusher
# ---------------------------------------------------------------------------

def _flusher_threads():
    return [t for t in threading.enumerate() if t.name == "repro-flusher"]


def test_flusher_start_stop_leaves_no_dangling_threads(postings):
    assert _flusher_threads() == []
    eng = AsyncSearchEngine(postings, seed=3, flush_tier=8, result_cache=0)
    eng.start()
    eng.start()                                # idempotent
    assert len(_flusher_threads()) == 1 and eng.running
    eng.stop()
    assert _flusher_threads() == [] and not eng.running
    # restartable, and the context manager form cleans up too
    with eng:
        assert len(_flusher_threads()) == 1
    assert _flusher_threads() == []


def test_flusher_resolves_tickets_without_manual_pump(postings):
    eng = AsyncSearchEngine(postings, seed=3, deadline_us=2000.0,
                            flush_tier=8, result_cache=0)
    q = next(q for q in zipf_query_log(sorted(eng.index), 8, seed=2)
             if eng.plan(q).algorithm == "device")
    with eng:
        ticket = eng.submit(q)
        assert ticket.wait(timeout=30.0), "flusher never flushed the bucket"
    assert ticket.error is None
    assert EXEC_COUNTERS["flusher_wakeups"] >= 1
    oracle = SearchEngine(postings, use_device=True, seed=3).query(q)
    assert np.array_equal(ticket.value.doc_ids, oracle.doc_ids)


def test_flusher_bit_identical_to_query_batch_on_zipf_workload(postings):
    """Acceptance: flusher on, zero manual pump() calls, 256-query zipf
    workload — every async result bit-identical to the synchronous
    query_batch oracle."""
    log = zipf_query_log(sorted(SearchEngine(postings, seed=3).index),
                         256, seed=11)
    eng = AsyncSearchEngine(postings, seed=3, deadline_us=2000.0,
                            flush_tier=8, result_cache=1024)
    with eng:
        tickets = [eng.submit(q) for q in log]
        for t in tickets:
            assert t.wait(timeout=60.0)
    assert all(t.error is None for t in tickets)
    oracle = SearchEngine(postings, use_device=True, seed=3).query_batch(log)
    for q, t, o in zip(log, tickets, oracle):
        assert np.array_equal(t.value.doc_ids, o.doc_ids), q


def test_submit_hammering_during_flush_and_idempotent_drain(postings):
    """Regression (lock-scope audit): submitter threads hammering while the
    flusher executes buckets, with concurrent drain() calls racing it —
    every ticket resolves exactly once (single-shot resolution would raise
    inside the flusher otherwise) with a correct result."""
    eng = AsyncSearchEngine(postings, seed=3, deadline_us=500.0,
                            flush_tier=4, result_cache=0)
    log = [q for q in zipf_query_log(sorted(eng.index), 48, seed=5)
           if eng.plan(q).algorithm == "device"][:32]
    eng.query_batch(log)                       # pre-compile outside the race
    results: dict = {}
    errors = []

    def submitter(worker: int):
        try:
            for i, q in enumerate(log):
                ticket = eng.submit(q)
                assert ticket.wait(timeout=30.0)
                results[(worker, i)] = (q, ticket)
                time.sleep(0.0005)
        except Exception as exc:               # pragma: no cover - fail path
            errors.append(exc)

    with eng:
        workers = [threading.Thread(target=submitter, args=(w,))
                   for w in range(4)]
        for w in workers:
            w.start()
        # hammer drain concurrently with the flusher's own pumps
        for _ in range(20):
            eng.drain()
            time.sleep(0.002)
        for w in workers:
            w.join(timeout=60.0)
        assert not any(w.is_alive() for w in workers)
    assert not errors
    assert eng.pending() == 0
    oracle = {tuple(q): r.doc_ids
              for q, r in zip(log, SearchEngine(postings, use_device=True,
                                                seed=3).query_batch(log))}
    assert len(results) == 4 * len(log)
    for q, ticket in results.values():
        assert ticket.error is None
        assert np.array_equal(ticket.value.doc_ids, oracle[tuple(q)])
