"""Structural invariants of ``core/partition.py`` and differential checks
of every Section-4 baseline in ``core/baselines.py``.

Partition invariants: a preprocessed structure must be a lossless
reordering of its input (groups partition the set, offsets monotone and
exhaustive, g-keys ascending and consistent with the z-prefix rule,
sentinel padding exactly complements the mask) and its storage accounting
must match the paper's formulas.  Baselines: on random sets of every
supported arity, each competitor must produce the numpy-oracle
intersection — and agree with the paper's ``rangroupscan`` over the same
inputs, so timing charts compare algorithms, never correctness bugs.
"""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.baselines import BASELINES
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import (
    SENTINEL, choose_t, preprocess_fixed, preprocess_multiresolution,
    preprocess_prefix,
)

SEED_MAX = (1 << 31) - 1


def _random_sets(rng, k=2, n=400, overlap=60, universe=1 << 22):
    common = rng.choice(universe, overlap, replace=False).astype(np.uint32)
    out = []
    for _ in range(k):
        own = rng.choice(universe, n, replace=False).astype(np.uint32)
        out.append(np.unique(np.concatenate([own, common])))
    return out


def _truth(sets):
    out = sets[0]
    for s in sets[1:]:
        out = np.intersect1d(out, s)
    return out


# ---------------------------------------------------------------------------
# partition invariants
# ---------------------------------------------------------------------------

def _check_prefix_invariants(values, w=256, m=2, seed=5):
    idx = preprocess_prefix(values, w=w, m=m, seed=seed)
    uniq = np.unique(np.asarray(values, dtype=np.uint32))
    # lossless: the groups partition exactly the input set
    assert np.array_equal(np.sort(idx.values), uniq)
    assert len(idx.g_keys) == len(idx.values) == idx.n
    # g-ordering: keys ascending, values are the perm-preimage of the keys
    assert np.all(np.diff(idx.g_keys.astype(np.int64)) >= 0)
    assert np.array_equal(np.asarray(idx.perm.forward(idx.values)),
                          idx.g_keys)
    # offsets: monotone, exhaustive, one slot per z-prefix group
    assert idx.offsets[0] == 0 and idx.offsets[-1] == idx.n
    assert np.all(np.diff(idx.offsets) >= 0)
    assert len(idx.offsets) == idx.G + 1 == (1 << idx.t) + 1
    # the prefix rule: group z holds exactly the keys whose top t bits == z
    if idx.t > 0:
        z = (idx.g_keys >> np.uint32(32 - idx.t)).astype(np.int64)
        assert np.array_equal(np.bincount(z, minlength=idx.G),
                              np.diff(idx.offsets))
    # padding: mask marks real entries; everything else is the sentinel
    counts = np.diff(idx.offsets)
    assert idx.padded_keys.shape == (idx.G, idx.gmax)
    assert np.array_equal(idx.mask.sum(axis=1), counts)
    assert np.all(idx.padded_keys[~idx.mask] == SENTINEL)
    assert np.array_equal(idx.padded_keys[idx.mask], idx.g_keys)
    assert np.array_equal(idx.padded_vals[idx.mask], idx.values)
    # filter images: one packed w-bit word row per (group, hash)
    assert idx.images.shape == (idx.G, m, w // 32)
    # storage accounting (Section 3.3.1): n + G*(m+1) words
    assert idx.storage_words() == idx.n + idx.G * (m + 1)
    return idx


@pytest.mark.parametrize("n", [1, 2, 17, 300, 5000])
def test_prefix_invariants_sized(n):
    rng = np.random.default_rng(n)
    vals = rng.choice(1 << 24, n, replace=False).astype(np.uint32)
    _check_prefix_invariants(vals)


def test_prefix_invariants_adversarial_values():
    # duplicates collapse; extremes (0, 2^32-1) survive the sentinel pad
    vals = np.array([0, 0, 1, SENTINEL, 7, 7, 1 << 31], dtype=np.uint32)
    idx = _check_prefix_invariants(vals)
    assert idx.n == 5


@settings(max_examples=25, deadline=None, derandomize=True)
@given(vals=st.lists(st.integers(min_value=0, max_value=(1 << 32) - 1),
                     min_size=1, max_size=400),
       w=st.sampled_from([64, 256]), m=st.integers(1, 3))
def test_prefix_invariants_property(vals, w, m):
    _check_prefix_invariants(np.asarray(vals, dtype=np.uint32), w=w, m=m)


def test_choose_t_bounds():
    assert choose_t(0, 256) == 0 and choose_t(1, 256) == 0
    for n in [2, 10, 100, 1000, 10**6]:
        for w in [64, 256, 512]:
            t = choose_t(n, w)
            assert t == math.ceil(math.log2(max(1.0, n / math.sqrt(w))))
            assert (1 << t) >= n / math.sqrt(w)          # enough groups
            if t > 0:
                assert (1 << (t - 1)) < n / math.sqrt(w)  # but no excess tier
    # monotone in n for fixed w
    ts = [choose_t(n, 256) for n in range(1, 2000, 37)]
    assert ts == sorted(ts)


def test_fixed_width_invariants():
    rng = np.random.default_rng(4)
    vals = rng.choice(1 << 24, 500, replace=False).astype(np.uint32)
    idx = preprocess_fixed(vals, w=64)
    uniq = np.unique(vals)
    assert np.array_equal(idx.values, uniq)          # rank partition: sorted
    s = idx.group_size
    assert idx.G == math.ceil(idx.n / s)
    assert np.array_equal(idx.offsets,
                          np.minimum(np.arange(idx.G + 1) * s, idx.n))
    # lo/hi really bound each group
    for z in range(idx.G):
        grp = idx.values[idx.offsets[z]:idx.offsets[z + 1]]
        assert idx.lo[z] == grp[0] and idx.hi[z] == grp[-1]
    assert np.all(idx.padded_vals[~idx.mask] == SENTINEL)


def test_multiresolution_consistency():
    rng = np.random.default_rng(6)
    vals = rng.choice(1 << 24, 1200, replace=False).astype(np.uint32)
    multi = preprocess_multiresolution(vals)
    fam, perm = multi.base.family, multi.base.perm
    for t in range(multi.T + 1):
        view = multi.at(t)
        # each resolution is itself a valid prefix partition of the SAME
        # g-ordered arrays, and matches a direct build at that resolution
        direct = preprocess_prefix(vals, t=t, family=fam, perm=perm)
        assert np.array_equal(view.offsets, direct.offsets)
        assert np.array_equal(view.g_keys, direct.g_keys)
        assert np.array_equal(view.images, direct.images)
    # O(n) storage: n elements + sum_t 2^t * (m+1) bookkeeping words
    m = multi.base.family.m
    want = multi.base.n + sum((1 << t) * (m + 1) for t in range(multi.T + 1))
    assert multi.storage_words() == want


# ---------------------------------------------------------------------------
# baselines vs oracle (and vs the paper's own algorithm)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(BASELINES))
@pytest.mark.parametrize("k", [2, 3, 4])
def test_baseline_matches_oracle(name, k):
    rng = np.random.default_rng(17 * k)
    sets = _random_sets(rng, k=k, n=400, overlap=60)
    truth = _truth(sets)
    res, stats = BASELINES[name](sets)
    assert np.array_equal(np.asarray(res, dtype=np.uint32), truth), name
    assert isinstance(stats, dict)


@pytest.mark.parametrize("k", [2, 3])
def test_baselines_agree_with_rangroupscan(k):
    rng = np.random.default_rng(23 * k)
    sets = _random_sets(rng, k=k, n=600, overlap=120)
    fam = random_hash_family(2, 256, seed=9)
    perm = default_permutation(9)
    idxs = [preprocess_prefix(s, w=256, m=2, family=fam, perm=perm)
            for s in sets]
    paper, _ = rangroupscan(idxs)
    for name, fn in BASELINES.items():
        res, _ = fn(sets)
        assert np.array_equal(np.asarray(res, dtype=np.uint32), paper), name


def test_baselines_edge_cases():
    empty_overlap = [np.array([1, 3, 5], np.uint32),
                     np.array([2, 4, 6], np.uint32)]
    identical = [np.arange(10, dtype=np.uint32)] * 2
    single = [np.array([7], np.uint32), np.array([7], np.uint32)]
    for name, fn in BASELINES.items():
        res, _ = fn(empty_overlap)
        assert len(res) == 0, name
        res, _ = fn(identical)
        assert np.array_equal(np.asarray(res, np.uint32),
                              identical[0]), name
        res, _ = fn(single)
        assert np.array_equal(np.asarray(res, np.uint32), single[0]), name


@settings(max_examples=20, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=SEED_MAX),
       k=st.integers(2, 4))
def test_baselines_oracle_property(seed, k):
    rng = np.random.default_rng(seed)
    sets = _random_sets(rng, k=k, n=150, overlap=25)
    truth = _truth(sets)
    for name, fn in BASELINES.items():
        res, _ = fn(sets)
        assert np.array_equal(np.asarray(res, dtype=np.uint32), truth), \
            (name, seed)
