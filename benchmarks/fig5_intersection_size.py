"""Paper Fig. 5: two 2^20 sets (10M in paper), vary r from 0.05% to ~90%.

Claims: RanGroupScan/IntGroup best for r < ~70% of n; Merge takes over for
larger r with RanGroupScan staying within a few % of Merge.
"""
from __future__ import annotations
import numpy as np
from .common import baseline_algos, check_and_time, gen_pair, paper_algos, truth_of


def run(quick: bool = True):
    n = 1 << 18 if quick else 1 << 21
    fracs = [0.0005, 0.01, 0.1, 0.5, 0.7, 0.9]
    rows = []
    for f in fracs:
        a, b = gen_pair(n, n, max(1, int(n * f)), seed=int(f * 1e4))
        truth = truth_of([a, b])
        algos = paper_algos([a, b], w=256, m=2,
                            include=("RanGroupScan", "RanGroup", "IntGroup"))
        algos.update(baseline_algos([a, b], include=["Merge", "SvS", "Lookup"]))
        times = check_and_time(algos, truth, reps=2)
        for name, us in times.items():
            rows.append({"figure": "fig5", "n": n, "r_frac": f, "r": len(truth),
                         "algorithm": name, "us": round(us, 1),
                         "speedup_vs_merge": round(times["Merge"] / us, 3)})
    return rows
