"""Benchmark driver: one module per paper table/figure + the roofline.

``python -m benchmarks.run [--full] [--only fig4,fig7]`` prints CSV rows
(name,us_per_call,derived) and writes benchmarks/artifacts/results.json.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

MODULES = [
    "fig4_set_size", "fig5_intersection_size", "fig_size_ratio",
    "fig6_num_keywords", "fig7_real_workload", "fig8_compression",
    "fig9_filtering_prob", "fig10_preprocessing", "fig_space", "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    all_rows = []
    print("name,us_per_call,derived")
    for name in MODULES:
        if only and name not in only and name.replace("_", "") not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except FileNotFoundError as e:
            print(f"# {name}: skipped ({e})", file=sys.stderr)
            continue
        dt = time.time() - t0
        for r in rows:
            us = r.get("us", r.get("compute_ms"))
            key_bits = [f"{k}={v}" for k, v in r.items()
                        if k not in ("figure", "us") and v is not None]
            print(f"{r.get('figure', name)}/{r.get('algorithm', r.get('arch', ''))},"
                  f"{us},{';'.join(key_bits)}")
        all_rows.extend(rows)
        print(f"# {name}: {len(rows)} rows in {dt:.1f}s", file=sys.stderr)

    out = pathlib.Path(__file__).resolve().parent / "artifacts" / "results.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=1, default=str))


if __name__ == "__main__":
    main()
