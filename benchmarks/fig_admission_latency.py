"""Admission-latency benchmark: the async micro-batching front-end under an
open-loop arrival process, swept over deadline budgets.

Setup: a Zipf corpus is indexed once; the query log is drawn Zipf-style
from a finite pool of conjunctions (``repeated_query_log``) so exact
repeats occur — live-traffic shape, and the regime where the result cache
pays.  At index-build time every device-routed shape signature of the pool
is compile-warmed at every power-of-two batch tier up to the flush tier,
so serving compiles nothing (``serve_time_traces`` must be 0).

Timing model: arrivals and flush scheduling run on a **virtual clock**
(fixed inter-arrival gap; the driver advances time to each arrival and to
each pending deadline, pumping exactly when a serving loop would), while
bucket *executions* are real measured device wall time.  Queue waits are
therefore deterministic — a deadline-flushed bucket's oldest query waits
exactly its budget, younger ones less, so ``p99_wait_us <= deadline_us``
holds by construction *of the policy* (it is the property under test:
without deadline flushing a lone query's wait is unbounded) — and the
throughput/utilization numbers reflect real compute.  Wall-clock pacing
was tried first and rejected: on a shared CI box, scheduler jitter of
several ms dominates a 50 ms run and the tail measures the container, not
the policy.

Per budget we record p50/p99 admission wait (submit -> flush start, the
quantity the deadline bounds), p50/p99 end-to-end latency for device-
queued queries (wait + amortized bucket execution; cache hits and host
paths are ~0-wait and reported via hit rate), offered/served QPS, device
utilization (real device seconds per virtual second — the cost of tighter
deadlines is more, smaller buckets), result-cache hit rate, jit executions
vs. #signatures, and flush causes (tier vs. deadline).

Run:  PYTHONPATH=src python benchmarks/fig_admission_latency.py [--docs N]
      [--queries N] [--out BENCH_admission_latency.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core.engine import EXEC_COUNTERS, pow2_tiers
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.admission import AdmissionQueue
from repro.serve.search import AsyncSearchEngine, repeated_query_log


class SimClock:
    """Virtual clock (seconds); the driver advances it explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def serve_run(eng: AsyncSearchEngine, log, deadline_us: float,
              flush_tier: int, gap_us: float):
    """One open-loop serving run at a fixed deadline budget (virtual time)."""
    clk = SimClock()
    eng.clock = clk
    eng.cache.clear()
    eng.admission = AdmissionQueue(flush_tier=flush_tier,
                                   deadline_us=deadline_us, clock=clk)
    EXEC_COUNTERS.reset()
    tickets = []

    def pump_until(t_target):
        # fire every deadline that falls before t_target, in order — this
        # is what a serving loop sleeping on next_deadline_in_us() does
        while True:
            nd = eng.admission.next_deadline_in_us()
            if nd is None:
                break
            t_deadline = clk.t + nd * 1e-6
            if t_target is not None and t_deadline > t_target:
                break
            clk.t = max(clk.t, t_deadline)
            eng.pump()

    for i, q in enumerate(log):
        t_arrival = i * gap_us * 1e-6
        pump_until(t_arrival)
        clk.t = t_arrival
        tickets.append(eng.submit(q))
    pump_until(None)                               # drain by deadline
    assert eng.pending() == 0 and all(t.done for t in tickets)
    sim_wall_s = clk.t

    # real device seconds spent executing buckets (batch_us is measured
    # wall time amortized per query, so summing it over queries restores
    # the total)
    device_s = sum(t.value.stats["batch_us"] for t in tickets
                   if t.value.stats.get("batch_us")) * 1e-6
    # device-queued subset: classify by bucket stats, not wait > 0 — the
    # submitter that fills a flush tier has wait exactly 0 under the
    # virtual clock but still went through the queue
    queued_tickets = [t for t in tickets
                      if t.value.stats.get("batch_size") and
                      not t.value.stats.get("cached")]
    queued = np.asarray([t.wait_us for t in queued_tickets])
    e2e_queued = np.asarray([t.wait_us + t.value.latency_us
                             for t in queued_tickets])
    hits = EXEC_COUNTERS["result_cache_hits"]
    misses = EXEC_COUNTERS["result_cache_misses"]
    p99_wait = float(np.percentile(queued, 99)) if len(queued) else 0.0
    return {
        "deadline_us": deadline_us,
        "queries": len(log),
        "offered_qps": 1e6 / gap_us,
        "served_qps": len(log) / sim_wall_s,
        "device_utilization": device_s / sim_wall_s,
        "queued_queries": int(len(queued)),
        "p50_wait_us": float(np.percentile(queued, 50)) if len(queued) else 0.0,
        "p99_wait_us": p99_wait,
        # 0.5us epsilon: virtual-time round-trips through next_deadline_in_us
        # carry ~1e-10 s float error, never a scheduling miss
        "p99_wait_within_deadline": bool(p99_wait <= deadline_us + 0.5),
        "p50_e2e_us": (float(np.percentile(e2e_queued, 50))
                       if len(e2e_queued) else 0.0),
        "p99_e2e_us": (float(np.percentile(e2e_queued, 99))
                       if len(e2e_queued) else 0.0),
        "result_cache_hits": hits,
        "result_cache_misses": misses,
        "result_cache_hit_rate": hits / max(1, hits + misses),
        "jit_executions": EXEC_COUNTERS["batch_calls"],
        # dispatch amortization: executions per query << 1 means bucketing
        # works even under deadline pressure (compiled-executable count
        # stays O(#signatures x tiers) — that's warm_executions)
        "jit_executions_per_query": EXEC_COUNTERS["batch_calls"] / len(log),
        "overflow_reruns": EXEC_COUNTERS["rerun_calls"],
        "serve_time_traces": EXEC_COUNTERS["batch_traces"],
        "tier_flushes": EXEC_COUNTERS["tier_flushes"],
        "deadline_flushes": EXEC_COUNTERS["deadline_flushes"],
    }


def run(n_docs: int = 12000, vocab: int = 8000, n_queries: int = 512,
        n_distinct: int = 160, flush_tier: int = 8, gap_us: float = 250.0,
        deadlines_us=(1000.0, 2000.0, 5000.0), min_df: int = 24,
        max_df_frac: float = 0.04, seed: int = 17):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=60, seed=seed)
    # same index pruning as fig_batched_qps: serve the paper's mid-frequency
    # r << n regime, not stopword enumeration
    postings = {t: p for t, p in inverted_index(docs).items()
                if min_df <= len(p) <= max_df_frac * n_docs}
    log = repeated_query_log(sorted(postings), n_queries,
                             n_distinct=n_distinct, seed=seed + 1)

    eng = AsyncSearchEngine(postings, w=256, m=2, seed=seed,
                            flush_tier=flush_tier)
    # index-build-time warming: every signature in the pool, every pow2
    # batch tier a partial flush can produce
    warmed = eng.warm(log, top_k=len(log), b_tiers=pow2_tiers(flush_tier))
    warm_execs = EXEC_COUNTERS["warm_executions"]

    sigs = {p.sig for p in (eng.plan(q) for q in log)
            if p.algorithm == "device"}
    # one discarded priming run: absorbs one-time lazy-init transients
    # (first dispatch bookkeeping, allocator growth) so measured bucket
    # executions reflect steady state
    serve_run(eng, log, deadlines_us[0], flush_tier, gap_us)
    runs = [serve_run(eng, log, d, flush_tier, gap_us) for d in deadlines_us]
    return {
        "n_docs": n_docs,
        "vocab_kept": len(postings),
        "queries": n_queries,
        "distinct_pool": n_distinct,
        "distinct_device_signatures": len(sigs),
        "flush_tier": flush_tier,
        "arrival_gap_us": gap_us,
        "warmed_signatures": len(warmed),
        "warm_executions": warm_execs,
        "runs": runs,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--distinct", type=int, default=160)
    ap.add_argument("--gap-us", type=float, default=250.0)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_admission_latency.json"))
    args = ap.parse_args()
    res = run(args.docs, args.vocab, args.queries, n_distinct=args.distinct,
              gap_us=args.gap_us)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
