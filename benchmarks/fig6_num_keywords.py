"""Paper Fig. 6: k = 2, 3, 4 equal-size sets (m=2 images, as in the paper).

Claim: RanGroupScan fastest, lead grows with k (more group tuples filtered
to empty by the k-way AND); RanGroup next; Merge degrades with k.
"""
from __future__ import annotations
import numpy as np
from .common import baseline_algos, check_and_time, gen_k, paper_algos, truth_of


def run(quick: bool = True):
    n = 1 << 17 if quick else 1 << 20
    rows = []
    for k in (2, 3, 4):
        sets = gen_k(k, n, max(1, n // 200), seed=k)
        truth = truth_of(sets)
        algos = paper_algos(sets, w=256, m=2,
                            include=("RanGroupScan", "RanGroup"))
        algos.update(baseline_algos(sets, include=["Merge", "SvS", "Hash"]))
        times = check_and_time(algos, truth, reps=2)
        for name, us in times.items():
            rows.append({"figure": "fig6", "k": k, "n": n, "r": len(truth),
                         "algorithm": name, "us": round(us, 1),
                         "speedup_vs_merge": round(times["Merge"] / us, 3)})
    return rows
