"""Paper Fig. 10/11: pre-processing overhead vs plain sorting."""
from __future__ import annotations
import time
import numpy as np
from repro.core.compress import compress_lowbits, delta_encode
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import preprocess_fixed, preprocess_prefix


def run(quick: bool = True):
    sizes = [1 << 16, 1 << 18] if quick else [1 << 16, 1 << 18, 1 << 20, 1 << 22]
    rng = np.random.default_rng(0)
    fam = random_hash_family(2, 256, seed=0)
    fam1 = random_hash_family(1, 64, seed=1)
    perm = default_permutation(0)
    rows = []
    for n in sizes:
        vals = rng.choice(1 << 28, size=n, replace=False).astype(np.uint32)
        t0 = time.perf_counter(); np.sort(vals); t_sort = time.perf_counter() - t0
        t0 = time.perf_counter()
        idx = preprocess_prefix(vals, w=256, m=2, family=fam, perm=perm)
        t_prefix = time.perf_counter() - t0
        t0 = time.perf_counter()
        preprocess_fixed(vals, w=64, family=fam1)
        t_fixed = time.perf_counter() - t0
        t0 = time.perf_counter()
        compress_lowbits(idx)
        t_low = time.perf_counter() - t0
        t0 = time.perf_counter()
        delta_encode(np.sort(vals))
        t_delta = time.perf_counter() - t0
        rows.append({"figure": "fig10", "n": n,
                     "sort_ms": round(t_sort * 1e3, 2),
                     "rangroupscan_ms": round(t_prefix * 1e3, 2),
                     "intgroup_ms": round(t_fixed * 1e3, 2),
                     "lowbits_extra_ms": round(t_low * 1e3, 2),
                     "delta_encode_ms": round(t_delta * 1e3, 2),
                     "prefix_vs_sort": round(t_prefix / t_sort, 2)})
    return rows
