"""Throughput benchmark: per-query device dispatch vs bucketed-batch
execution over the paper-mix zipf query log.

The per-query loop is the seed architecture — one jit execution and one
host↔device round-trip per query.  The bucketed path plans the whole log,
groups device-routed queries by shape signature, and issues one jit
execution per bucket (plus rare overflow re-runs).  Both paths run the same
normalized plans on the same corpus, so the speedup isolates dispatch /
round-trip amortization — the quantity that matters at serving scale.

Run:  PYTHONPATH=src python benchmarks/fig_batched_qps.py [--docs N]
      [--queries N] [--out BENCH_batched_qps.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.engine import EXEC_COUNTERS, reset_exec_counters
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.search import SearchEngine, zipf_query_log


def run(n_docs: int = 20000, vocab: int = 15000, n_queries: int = 256,
        min_df: int = 32, max_df_frac: float = 0.04, seed: int = 11):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=60, seed=seed)
    # Standard IR index pruning: drop stopwords (terms in nearly every doc —
    # their conjunctions enumerate most of the corpus and belong to a top-k
    # path, not full enumeration) and hapax-range terms.  What remains is
    # the paper's serving regime: mid-frequency terms, r << n, selective
    # filters — where the group filter actually skips work.
    postings = {t: p for t, p in inverted_index(docs).items()
                if min_df <= len(p) <= max_df_frac * n_docs}
    engine = SearchEngine(postings, w=256, m=2, seed=seed, use_device=True)
    log = zipf_query_log(sorted(engine.index), n_queries, seed=seed + 1)

    # warm both paths so every (signature, B) executable is compiled before
    # timing — compile time is a one-off at serving scale
    engine.query_batch(log)
    for q in log[: len(log) // 4]:
        engine.query(q)
    for q in log:
        engine.query(q)

    t0 = time.perf_counter()
    per_query = [engine.query(q) for q in log]
    per_query_s = time.perf_counter() - t0

    reset_exec_counters()
    t0 = time.perf_counter()
    batched = engine.query_batch(log)
    batched_s = time.perf_counter() - t0
    jit_calls = EXEC_COUNTERS["batch_calls"]
    reruns = EXEC_COUNTERS["rerun_calls"]

    for q, a, b in zip(log, per_query, batched):
        assert np.array_equal(a.doc_ids, b.doc_ids), f"path mismatch for {q}"

    sigs = {p.sig for p in (engine.plan(q) for q in log)
            if p.algorithm == "device"}
    return {
        "n_docs": n_docs,
        "vocab": vocab,
        "queries": len(log),
        "distinct_device_signatures": len(sigs),
        "jit_executions_batched": jit_calls,
        "overflow_reruns": reruns,
        "per_query_s": per_query_s,
        "batched_s": batched_s,
        "per_query_qps": len(log) / per_query_s,
        "batched_qps": len(log) / batched_s,
        "speedup": per_query_s / batched_s,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--vocab", type=int, default=15000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_batched_qps.json"))
    args = ap.parse_args()
    res = run(args.docs, args.vocab, args.queries)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
