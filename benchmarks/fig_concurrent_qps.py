"""Concurrent-serving benchmark: overlapped dispatch window vs the PR-5
synchronous flusher, on the hot-z replica workload over a 2x2 mesh.

The same burst of conjunctive queries is served twice by identically
configured ``AsyncSearchEngine``\\ s whose only difference is the in-flight
window bound:

- *synchronous* (``max_inflight=1``): the PR-5 serving shape — every
  bucket's dispatch blocks on its own collection (transfer + overflow
  check) before the next bucket may dispatch, so the device idles during
  each collect and the balancer never sees two buckets at once;
- *overlapped* (``max_inflight=8``): the dispatch/collect split — the
  flusher issues due buckets back-to-back under the exec lock and collects
  outside it, so independent buckets execute concurrently on different
  replica rows (the balancer spreads them because in-flight weight is now
  visible until collect time).

The workload keeps buckets single-device (``shard_min_g`` out of reach) so
each tier-flush bucket lands on one replica row of the 2x2 mesh via the
balancer: overlap turns the second row from dead weight into concurrent
capacity, bounded at 2x by the row count.  Queries alternate 2-term and
3-term hot conjunctions — two shape signatures, so the admission queue
always feeds the window two independent buckets and the overlap is
structural at any smoke scale, not an artifact of arrival timing.

Measurement protocol: every engine is compile-warmed across all power-of-2
batch tiers the burst can produce (serve-time compilation of an unwarmed
partial-flush tier concurrent with execution stalls the pipeline for
seconds and dominates any single pass), then the two modes run
``--passes`` interleaved passes each and the per-mode median wall time is
the headline — single passes on shared hosts swing far too much to gate
on.  Reported per mode: served QPS (burst start -> last ticket resolved),
per-pass walls, p50/p99 queue wait, and the new overlap telemetry
(``inflight_dispatches`` / ``collect_us`` / ``overlap_high_water``).
Results are checked bit-identical to the synchronous ``query_batch``
oracle; the headline ``qps_ratio_overlapped_vs_sync`` is what the CI gate
floors.

Hardware bound, measured honestly: the ratio is capped by the host's
spare parallelism.  On a single-hardware-thread container (where the
committed artifact was produced) the forced host "devices" all multiplex
one core, so overlapped and synchronous serving tie at ~1.0x — the window
can only reclaim idle handoff latency, not create compute.  With real
spare cores (multi-core CI runners, accelerator slices) the collect of
bucket N runs concurrently with the execution of bucket N+1 and the ratio
rises toward the replica-row bound (2x on 2x2); the CI floor is therefore
a noise-tolerant "overlap never costs throughput" check rather than a
speedup claim.

Run:  PYTHONPATH=src python benchmarks/fig_concurrent_qps.py [--queries N]
      [--set-size N] [--passes N] [--out BENCH_concurrent_qps.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices to lay out, and the CPU
# backend explicitly (with libtpu on the image a concurrently running jax
# process would otherwise serialize on the TPU lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from fig_mesh2d_qps import hot_z_postings
from repro.core.engine import EXEC_COUNTERS
from repro.exec.topology import make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine

LAYOUT = (2, 2)


def hot_mixed_log(n_terms: int, n_queries: int, seed: int = 7):
    """Alternating 2-term / 3-term hot conjunctions.

    Two arities means two shape signatures, so the admission queue always
    holds two independent buckets: the overlap window genuinely has two
    buckets to overlap at ANY workload scale (a single-signature burst
    would coalesce into one big bucket and the high-water mark could
    degenerate to 1 on small smoke runs)."""
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(n_terms, 2 + (i % 2), replace=False).tolist())
            for i in range(n_queries)]


def _percentiles(xs):
    arr = np.asarray(xs, dtype=np.float64)
    if not len(arr):
        return 0.0, 0.0
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 99)))


def serve_burst(eng: AsyncSearchEngine, log):
    """Serve one closed-loop burst through the background flusher.

    The submitter queues every query as fast as it can (tier flushes keep
    the admission queue short) and then waits for all tickets; wall time
    measures how fast the flusher drains the stream of same-signature
    buckets — exactly the dispatch/collect pipelining the window bound
    throttles.  Returns (tickets, metrics)."""
    eng.cache.clear()
    EXEC_COUNTERS.reset()
    eng.start()
    t0 = time.perf_counter()
    tickets = [eng.submit(q) for q in log]
    for t in tickets:
        t.wait(timeout=300.0)
    wall_s = time.perf_counter() - t0
    eng.stop()
    assert eng._flusher_error is None, eng._flusher_error
    assert all(t.done for t in tickets)
    queued = [t.wait_us for t in tickets
              if t.value.stats.get("batch_size") and
              not t.value.stats.get("cached")]
    p50, p99 = _percentiles(queued)
    return tickets, {
        "max_inflight": eng.max_inflight,
        "queries": len(log),
        "wall_s": wall_s,
        "served_qps": len(log) / wall_s,
        "queued_queries": len(queued),
        "p50_wait_us": p50,
        "p99_wait_us": p99,
        "inflight_dispatches": EXEC_COUNTERS["inflight_dispatches"],
        "overlap_high_water": EXEC_COUNTERS["overlap_high_water"],
        "collect_us": EXEC_COUNTERS["collect_us"],
        "replica_dispatches": EXEC_COUNTERS["replica_dispatches"],
        "tier_flushes": EXEC_COUNTERS["tier_flushes"],
        "deadline_flushes": EXEC_COUNTERS["deadline_flushes"],
        "flusher_wakeups": EXEC_COUNTERS["flusher_wakeups"],
        "overflow_reruns": EXEC_COUNTERS["rerun_calls"],
    }


def _pow2_tiers(max_b: int):
    """Every power-of-2 batch tier a flush of up to ``max_b`` rows can hit."""
    return [1 << i for i in range(max(1, max_b - 1).bit_length() + 1)]


def _make_engine(postings, log, m, seed, max_inflight, flush_tier,
                 deadline_us):
    topo = make_topology(*LAYOUT)
    eng = AsyncSearchEngine(
        postings, w=256, m=m, seed=seed, topology=topo,
        shard_min_g=1 << 20,            # single-device buckets -> balancer
        flush_tier=flush_tier, deadline_us=deadline_us,
        result_cache=0,                 # repeats must hit the device
        max_inflight=max_inflight)
    # burst submission coalesces buckets far past flush_tier (take_due pops
    # everything accumulated), so deadline flushes can land on ANY tier up
    # to the per-signature query count — warm them all or a serve-time
    # compile stalls the window mid-measurement
    eng.warm(log, top_k=len(log), b_tiers=_pow2_tiers(len(log)))
    return eng, topo


def run(n_queries: int = 256, n_terms: int = 12, set_size: int = 50000,
        overlap: int = 400, m: int = 6, flush_tier: int = 8,
        deadline_us: float = 2000.0, passes: int = 5, seed: int = 11):
    # perm_seed == the engines' seed: the planted hot-quarter values must be
    # hot under the SAME permutation the engines partition with
    postings, planted = hot_z_postings(n_terms, set_size, overlap, seed=seed,
                                       perm_seed=seed)
    log = hot_mixed_log(n_terms, n_queries, seed=seed + 1)
    avail = len(jax.devices())
    assert avail >= LAYOUT[0] * LAYOUT[1], f"needs 4 devices, have {avail}"

    oracle = SearchEngine(postings, w=256, m=m, seed=seed,
                          use_device=True).query_batch(log)

    plan = (("synchronous", 1), ("overlapped", 8))
    engines = {}
    for mode, max_inflight in plan:
        eng, topo = _make_engine(postings, log, m, seed, max_inflight,
                                 flush_tier, deadline_us)
        serve_burst(eng, log)           # priming pass: lazy init + any
        engines[mode] = (eng, topo)     # shape warming missed

    # interleaved passes: mode A's pass k runs back-to-back with mode B's
    # pass k, so slow drift on a shared host hits both modes alike; the
    # per-mode MEDIAN pass is the headline
    runs = {mode: [] for mode, _ in plan}
    for _ in range(passes):
        for mode, _ in plan:
            eng, topo = engines[mode]
            tickets, metrics = serve_burst(eng, log)
            assert all(d["in_flight"] == 0 for d in topo.load_snapshot())
            metrics["balancer_dispatched"] = [
                d["dispatched"] for d in topo.load_snapshot()]
            runs[mode].append((tickets, metrics))

    modes = {}
    identical = True
    for mode, _ in plan:
        walls = [m_["wall_s"] for _, m_ in runs[mode]]
        # the pass with the median wall represents the mode (odd `passes`
        # hits the true median; even picks the lower middle)
        rep = sorted(range(len(walls)), key=lambda i: walls[i])[
            (len(walls) - 1) // 2]
        metrics = dict(runs[mode][rep][1])
        metrics["passes"] = passes
        metrics["walls_s"] = walls
        modes[mode] = metrics
        identical &= all(
            np.array_equal(t.value.doc_ids, o.doc_ids)
            for tickets, _ in runs[mode]
            for t, o in zip(tickets, oracle))
    assert identical, "overlapped serving diverged from query_batch oracle"

    return {
        "devices": avail,
        "layout": f"{LAYOUT[0]}x{LAYOUT[1]}",
        "queries": n_queries,
        "n_terms": n_terms,
        "set_size": set_size,
        "overlap": len(planted),
        "m": m,
        "flush_tier": flush_tier,
        "deadline_us": deadline_us,
        "shard_min_g": 1 << 20,
        "identical_to_query_batch": int(identical),
        "modes": modes,
        "qps_ratio_overlapped_vs_sync": (
            modes["overlapped"]["served_qps"]
            / modes["synchronous"]["served_qps"]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--terms", type=int, default=12)
    ap.add_argument("--set-size", type=int, default=50000)
    ap.add_argument("--overlap", type=int, default=400)
    ap.add_argument("--m", type=int, default=6)
    ap.add_argument("--flush-tier", type=int, default=8)
    ap.add_argument("--passes", type=int, default=5)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_concurrent_qps.json"))
    args = ap.parse_args()
    res = run(args.queries, args.terms, args.set_size, args.overlap,
              m=args.m, flush_tier=args.flush_tier, passes=args.passes)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
