"""Paper §4 'Varying the Sets Size Ratios': |L2| fixed, sr = |L2|/|L1| sweep.

Claims: RanGroupScan best for sr < 32; Hash/Lookup best for sr > 100;
HashBin and RanGroupScan always close to the best performer.
"""
from __future__ import annotations
import numpy as np
from .common import baseline_algos, check_and_time, gen_pair, paper_algos, truth_of


def run(quick: bool = True):
    n2 = 1 << 18 if quick else 1 << 21
    ratios = [1, 4, 16, 64, 256] if quick else [1, 4, 16, 32, 64, 128, 256, 1024]
    rows = []
    for sr in ratios:
        n1 = max(16, n2 // sr)
        a, b = gen_pair(n1, n2, max(1, n1 // 100), seed=sr)
        truth = truth_of([a, b])
        algos = paper_algos([a, b], w=256, m=2,
                            include=("RanGroupScan", "HashBin"))
        algos.update(baseline_algos([a, b], include=["Merge", "SvS", "Hash", "Lookup"]))
        times = check_and_time(algos, truth, reps=2)
        best = min(times.values())
        for name, us in times.items():
            rows.append({"figure": "size_ratio", "n1": n1, "n2": n2, "sr": sr,
                         "algorithm": name, "us": round(us, 1),
                         "vs_best": round(us / best, 3)})
    return rows
