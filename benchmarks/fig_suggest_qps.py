"""Suggestion-service benchmark: top-K set-similarity QPS through the
count-only execution path.

Builds a corpus of random sets, replays it through the streaming binary
ingestion pipeline (``repro.data.ingest``) into a
:class:`~repro.serve.search.SuggestEngine`, pre-traces the hot count
signatures (:meth:`SuggestEngine.warm`), and serves a Zipf-skewed probe
workload in micro-batches — the skew makes repeated probes common, so the
generation-stamped result cache absorbs part of the load exactly as live
suggestion traffic would.  Every served top-K list is checked
bit-identical (deterministic ``(-count, id)`` tie-break included) against
an exact numpy oracle, and the warmed serving loop is asserted
trace-free: ``EXEC_COUNTERS["count_traces"]`` must stay flat once warm.

Reported: served suggest QPS (cache on), device-pass QPS (cache off),
pre-filter selectivity (candidates kept / examined), count-path call and
trace counters, ingestion throughput, and — when >= 4 forced host devices
are available — a 2x2 (data x shard) mesh replay whose oracle equality
folds into ``identical_to_oracle``.

Run:  PYTHONPATH=src python benchmarks/fig_suggest_qps.py [--queries N]
      [--sets N] [--out BENCH_suggest_qps.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices so the mesh section can
# lay out, and the CPU backend explicitly (with libtpu on the image a
# concurrently running jax process would otherwise serialize on the TPU
# lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.core.engine import EXEC_COUNTERS, pow2_tiers
from repro.data.ingest import ingest_file, write_records
from repro.exec.topology import make_topology
from repro.serve.search import SuggestEngine


def random_corpus(n_sets: int, set_size: int, distinct_pool: int,
                  seed: int):
    """Random sets over a shared element pool.

    ``set_size**2 / distinct_pool`` pairs of sets overlap in expectation,
    so top-K lists are nontrivial; two duplicated sets force exact count
    ties, exercising the deterministic tie-break end-to-end.
    """
    rng = np.random.default_rng(seed)
    pool = rng.choice(1 << 24, size=distinct_pool, replace=False)
    corpus = {
        sid: np.unique(rng.choice(pool, size=set_size,
                                  replace=False).astype(np.uint32))
        for sid in range(n_sets)
    }
    corpus[n_sets] = corpus[0].copy()        # forced ties vs set 0
    corpus[n_sets + 1] = corpus[0].copy()
    return corpus


def zipf_probe_log(set_ids, n_queries: int, seed: int, a: float = 1.3):
    """Zipf-skewed probe ids: head probes repeat -> result-cache traffic."""
    rng = np.random.default_rng(seed)
    ids = sorted(set_ids)
    ranks = np.minimum(rng.zipf(a, size=n_queries) - 1, len(ids) - 1)
    return [ids[r] for r in ranks]


def oracle_topk(corpus, sid: int, k: int):
    pairs = []
    for c in sorted(corpus):
        if c == sid:
            continue
        n = len(np.intersect1d(corpus[sid], corpus[c]))
        if n >= 1:
            pairs.append((c, n))
    pairs.sort(key=lambda p: (-p[1], p[0]))
    return pairs[:k]


def serve_log(eng: SuggestEngine, log, k: int, batch: int):
    """Serve the probe log in micro-batches; returns (results, metrics)."""
    EXEC_COUNTERS.reset()
    t0 = time.perf_counter()
    results = []
    for i in range(0, len(log), batch):
        requests = [(sid, k) for sid in log[i:i + batch]]
        results.extend(eng.suggest_batch(requests))
    wall_s = time.perf_counter() - t0
    pre_in = EXEC_COUNTERS["suggest_prefilter_in"]
    return results, {
        "queries": len(log),
        "served_qps": len(log) / wall_s,
        "wall_s": wall_s,
        "count_calls": EXEC_COUNTERS["count_calls"],
        "count_traces": EXEC_COUNTERS["count_traces"],
        "result_cache_hits": EXEC_COUNTERS["result_cache_hits"],
        "prefilter_in": pre_in,
        "prefilter_kept": EXEC_COUNTERS["suggest_prefilter_kept"],
        "prefilter_selectivity": (
            EXEC_COUNTERS["suggest_prefilter_kept"] / max(1, pre_in)),
    }


def mesh_section(corpus, log, k: int, batch: int, oracle):
    """Replay the log on a 2x2 (data x shard) topology; identity-check."""
    topo = make_topology(2, 2)
    eng = SuggestEngine(corpus, topology=topo, shard_min_g=1)
    eng.warm(sorted(set(log)), k, b_tiers=pow2_tiers(batch))
    results, metrics = serve_log(eng, log, k, batch)
    identical = all(r.suggestions == oracle[sid]
                    for sid, r in zip(log, results))
    if not identical:
        print("MISMATCH vs oracle on the mesh section")
    metrics.update({
        "layout": topo.describe(),
        "identical": int(identical),
        "mesh2d_row_dispatches": EXEC_COUNTERS["mesh2d_row_dispatches"],
    })
    return metrics


def run(n_queries: int = 192, n_sets: int = 64, set_size: int = 200,
        distinct_pool: int = 4096, top_k: int = 8, batch: int = 16,
        seed: int = 29):
    corpus = random_corpus(n_sets, set_size, distinct_pool, seed)

    # corpus arrives through the streaming binary format, one set at a time
    eng = SuggestEngine({}, use_device=True)
    with tempfile.TemporaryDirectory() as tmp:
        path = pathlib.Path(tmp) / "corpus.rsi"
        write_records(path, sorted(corpus.items()))
        t0 = time.perf_counter()
        n_ingested = ingest_file(path, eng)
        ingest_s = time.perf_counter() - t0
    assert n_ingested == len(corpus)

    log = zipf_probe_log(corpus, n_queries, seed + 1)
    oracle = {sid: oracle_topk(corpus, sid, top_k) for sid in set(log)}

    # pre-trace the count executables for every probe the log can draw, at
    # every pow2 bucket tier a micro-batch of ``batch`` can produce (a
    # request contributes at most one row per class signature, so bucket
    # sizes never exceed the micro-batch)
    eng.warm(sorted(set(log)), top_k, b_tiers=pow2_tiers(batch))
    serve_log(eng, log[:batch], top_k, batch)    # absorb lazy-init tails

    # cached serving: the Zipf head repeats -> result-cache hits
    results, metrics = serve_log(eng, log, top_k, batch)
    identical = all(r.suggestions == oracle[sid]
                    for sid, r in zip(log, results))
    if not identical:
        print("MISMATCH vs numpy oracle on the cached run")

    # pure device serving: cache cleared before every micro-batch
    def uncached():
        EXEC_COUNTERS.reset()
        t0 = time.perf_counter()
        out = []
        for i in range(0, len(log), batch):
            eng.cache.clear()
            out.extend(eng.suggest_batch(
                [(sid, top_k) for sid in log[i:i + batch]]))
        return out, time.perf_counter() - t0

    dev_results, dev_wall = uncached()
    identical = identical and all(
        r.suggestions == oracle[sid] for sid, r in zip(log, dev_results))
    device_traces = EXEC_COUNTERS["count_traces"]

    mesh = None
    if len(jax.devices()) >= 4:
        mesh = mesh_section(corpus, log, top_k, batch, oracle)
        identical = identical and bool(mesh["identical"])

    out = {
        "devices": len(jax.devices()),
        "queries": n_queries,
        "n_sets": len(corpus),
        "set_size": set_size,
        "distinct_pool": distinct_pool,
        "top_k": top_k,
        "micro_batch": batch,
        "identical_to_oracle": int(identical),
        "ingest_records_per_s": n_ingested / max(ingest_s, 1e-9),
        "device_qps": len(log) / dev_wall,
        "count_traces_serving": device_traces,
        "mesh2d": mesh,
    }
    out.update(metrics)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=192)
    ap.add_argument("--sets", type=int, default=64)
    ap.add_argument("--set-size", type=int, default=200)
    ap.add_argument("--pool", type=int, default=4096)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_suggest_qps.json"))
    args = ap.parse_args()
    res = run(args.queries, args.sets, args.set_size, args.pool,
              top_k=args.top_k, batch=args.batch)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
