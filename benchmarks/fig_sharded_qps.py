"""Throughput benchmark: z-sharded batched execution over a device mesh vs
the single-device bucketed engine.

Runs the same paper-mix zipf query log through a ``SearchEngine`` without a
mesh (the PR-1 bucketed baseline) and with 1-D meshes of increasing shard
count, all on FORCED host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set below before
jax initializes) — so on CPU this measures the *structure* of the sharded
path (QPS, per-bucket jit executions, overflow re-runs, routed fraction)
rather than real accelerator scaling; on a TPU slice the same script
measures both.  Results are cross-checked query-by-query against the
unsharded baseline, which is itself oracle-checked by the tier-1 suite.

Run:  PYTHONPATH=src python benchmarks/fig_sharded_qps.py [--docs N]
      [--queries N] [--shards 2,4] [--out BENCH_sharded_qps.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices to shard over, and the
# CPU backend explicitly (with libtpu on the image a concurrently running
# jax process would otherwise serialize on the TPU lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core.engine import EXEC_COUNTERS, make_shard_mesh
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.search import SearchEngine, zipf_query_log


def _run_engine(engine, log, baseline_results=None):
    """Warm (compile) then time one query_batch pass; returns metrics."""
    engine.query_batch(log)  # compile warming pass, untimed
    EXEC_COUNTERS.reset()
    t0 = time.perf_counter()
    results = engine.query_batch(log)
    wall_s = time.perf_counter() - t0
    if baseline_results is not None:
        for q, a, b in zip(log, results, baseline_results):
            assert np.array_equal(a.doc_ids, b.doc_ids), f"mismatch for {q}"
    plans = [engine.plan(q) for q in log]
    sharded_q = sum(1 for p in plans
                    if p.algorithm == "device" and p.sig.shards > 1)
    return results, {
        "n_shards": engine.device.n_shards,
        "queries": len(log),
        "sharded_routed_queries": sharded_q,
        "jit_executions": (EXEC_COUNTERS["batch_calls"]
                           + EXEC_COUNTERS["sharded_calls"]),
        "single_device_calls": EXEC_COUNTERS["batch_calls"],
        "sharded_calls": EXEC_COUNTERS["sharded_calls"],
        "overflow_reruns": (EXEC_COUNTERS["rerun_calls"]
                            + EXEC_COUNTERS["sharded_rerun_calls"]),
        "wall_s": wall_s,
        "qps": len(log) / wall_s,
    }


def run(n_docs: int = 20000, vocab: int = 15000, n_queries: int = 256,
        shard_counts=(2, 4), shard_min_g: int = 64,
        min_df: int = 32, max_df_frac: float = 0.04, seed: int = 11):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=60, seed=seed)
    postings = {t: p for t, p in inverted_index(docs).items()
                if min_df <= len(p) <= max_df_frac * n_docs}
    avail = len(jax.devices())
    shard_counts = [s for s in shard_counts if s <= avail]
    assert len(shard_counts) >= 2, (
        f"need >= 2 viable shard counts, have {avail} devices"
    )

    baseline = SearchEngine(postings, w=256, m=2, seed=seed, use_device=True)
    log = zipf_query_log(sorted(baseline.index), n_queries, seed=seed + 1)
    base_results, base_metrics = _run_engine(baseline, log)

    sharded_metrics = []
    for n_shards in shard_counts:
        eng = SearchEngine(postings, w=256, m=2, seed=seed,
                           mesh=make_shard_mesh(n_shards),
                           shard_min_g=shard_min_g)
        _, metrics = _run_engine(eng, log, baseline_results=base_results)
        metrics["speedup_vs_unsharded"] = base_metrics["wall_s"] / metrics["wall_s"]
        sharded_metrics.append(metrics)

    return {
        "n_docs": n_docs,
        "vocab": vocab,
        "queries": len(log),
        "devices": avail,
        "shard_min_g": shard_min_g,
        "unsharded_baseline": base_metrics,
        "sharded": sharded_metrics,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--vocab", type=int, default=15000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--shards", type=str, default="2,4",
                    help="comma-separated shard counts to sweep")
    ap.add_argument("--shard-min-g", type=int, default=64,
                    help="route queries sharded when largest set has >= this "
                         "many z-groups (low default: CPU-sized corpora)")
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_sharded_qps.json"))
    args = ap.parse_args()
    shard_counts = tuple(int(s) for s in args.shards.split(","))
    res = run(args.docs, args.vocab, args.queries, shard_counts=shard_counts,
              shard_min_g=args.shard_min_g)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
