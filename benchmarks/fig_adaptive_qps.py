"""Adaptive serving-runtime benchmark: background flusher vs manual pump,
and learned capacity tiers vs the static G/4 rule.

Two experiments, one JSON:

**Flusher** — the same open-loop arrival process (fixed inter-arrival gap,
real wall clock) served two ways by the same ``AsyncSearchEngine``:

- *manual pump*: the PR-2 serving shape — the submitter thread itself calls
  ``pump()`` after every submit, so every due-bucket execution happens
  inline on the submission path and stalls subsequent arrivals;
- *background flusher*: ``start()`` owns the flush cadence (sleep until the
  next deadline, wake on submit) and the submitter only queues — submission
  cadence fully decoupled from flush cadence.

Reported per mode: served QPS (arrival start -> last ticket resolved),
submit-loop wall time (the decoupling shows up here), p50/p99 queue wait,
flush causes, and ``flusher_wakeups``.  Results are checked bit-identical
to the synchronous ``query_batch`` oracle.

**Adaptive capacity** — a workload salted with dense conjunctions (two
near-identical 2048-element posting lists: every group tuple survives
phase 1, so survivors ≈ G > G/4 and the static capacity rule *must*
overflow) is replayed through a static engine and an adaptive one
(``exec/adaptive.py::CapacityModel``).  The static engine pays an overflow
re-run on every dense bucket, every pass.  The adaptive engine pays them
only during the learning pass; after the model promotes the signature's
capacity tier, the replay runs with **zero** re-runs
(``adaptive_overflow_saved`` counts the executions the learned tier
absorbed).  QPS is reported for both replays — the learned tier must not
regress throughput.

Run:  PYTHONPATH=src python benchmarks/fig_adaptive_qps.py [--docs N]
      [--queries N] [--out BENCH_adaptive_qps.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.engine import EXEC_COUNTERS, pow2_tiers
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.exec.adaptive import CapacityModel
from repro.serve.search import (
    AsyncSearchEngine, SearchEngine, repeated_query_log,
)


def _pace_until(t_target: float) -> None:
    """Open-loop pacing that yields the GIL.

    A pure-Python spin loop would hold the GIL for whole switch intervals
    and starve the background flusher thread (measured: 10x wait inflation)
    — so pacing sleeps, accepting the kernel's sub-ms wakeup slop, which is
    identical for both serving modes.
    """
    while True:
        dt = t_target - time.perf_counter()
        if dt <= 0:
            return
        time.sleep(dt)


def _percentiles(xs):
    arr = np.asarray(xs, dtype=np.float64)
    if not len(arr):
        return 0.0, 0.0
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 99)))


def serve_open_loop(eng: AsyncSearchEngine, log, gap_us: float,
                    use_flusher: bool):
    """One real-time open-loop run; returns (tickets, metrics)."""
    eng.cache.clear()
    EXEC_COUNTERS.reset()
    tickets = []
    if use_flusher:
        eng.start()
    t0 = time.perf_counter()
    for i, q in enumerate(log):
        _pace_until(t0 + i * gap_us * 1e-6)
        tickets.append(eng.submit(q))
    submit_wall_s = time.perf_counter() - t0
    if use_flusher:
        for t in tickets:
            t.wait(timeout=60.0)
        eng.stop()                                  # drains any stragglers
    else:
        while eng.pending():
            eng.pump()
        eng.drain()
    wall_s = time.perf_counter() - t0
    assert all(t.done for t in tickets)
    queued = [t.wait_us for t in tickets
              if t.value.stats.get("batch_size") and
              not t.value.stats.get("cached")]
    p50, p99 = _percentiles(queued)
    return tickets, {
        "mode": "background_flusher" if use_flusher else "manual_pump",
        "queries": len(log),
        "offered_qps": 1e6 / gap_us,
        "served_qps": len(log) / wall_s,
        "submit_wall_s": submit_wall_s,
        "total_wall_s": wall_s,
        "queued_queries": len(queued),
        "p50_wait_us": p50,
        "p99_wait_us": p99,
        "tier_flushes": EXEC_COUNTERS["tier_flushes"],
        "deadline_flushes": EXEC_COUNTERS["deadline_flushes"],
        "flusher_wakeups": EXEC_COUNTERS["flusher_wakeups"],
        "jit_executions": EXEC_COUNTERS["batch_calls"],
        "overflow_reruns": EXEC_COUNTERS["rerun_calls"],
    }


def manual_pump_open_loop(eng: AsyncSearchEngine, log, gap_us: float):
    """The coupled baseline: submit, then pump inline, per arrival."""
    eng.cache.clear()
    EXEC_COUNTERS.reset()
    tickets = []
    t0 = time.perf_counter()
    for i, q in enumerate(log):
        _pace_until(t0 + i * gap_us * 1e-6)
        tickets.append(eng.submit(q))
        eng.pump()                                  # inline: stalls arrivals
    submit_wall_s = time.perf_counter() - t0
    eng.drain()
    wall_s = time.perf_counter() - t0
    assert all(t.done for t in tickets)
    queued = [t.wait_us for t in tickets
              if t.value.stats.get("batch_size") and
              not t.value.stats.get("cached")]
    p50, p99 = _percentiles(queued)
    return tickets, {
        "mode": "manual_pump",
        "queries": len(log),
        "offered_qps": 1e6 / gap_us,
        "served_qps": len(log) / wall_s,
        "submit_wall_s": submit_wall_s,
        "total_wall_s": wall_s,
        "queued_queries": len(queued),
        "p50_wait_us": p50,
        "p99_wait_us": p99,
        "tier_flushes": EXEC_COUNTERS["tier_flushes"],
        "deadline_flushes": EXEC_COUNTERS["deadline_flushes"],
        "flusher_wakeups": EXEC_COUNTERS["flusher_wakeups"],
        "jit_executions": EXEC_COUNTERS["batch_calls"],
        "overflow_reruns": EXEC_COUNTERS["rerun_calls"],
    }


def _timed_batch(eng: SearchEngine, log):
    EXEC_COUNTERS.reset()
    t0 = time.perf_counter()
    results = eng.query_batch(log)
    wall_s = time.perf_counter() - t0
    return results, wall_s, dict(EXEC_COUNTERS)


def adaptive_overflow_experiment(postings, log, min_observations: int = 8):
    """Static-vs-learned capacity tiers on an overflow-salted workload."""
    static = SearchEngine(postings, w=256, m=2, seed=17, use_device=True,
                          result_cache=0)
    static.query_batch(log)                         # compile warm-up pass
    _, static_s, static_counters = _timed_batch(static, log)

    model = CapacityModel(min_observations=min_observations)
    adaptive = SearchEngine(postings, w=256, m=2, seed=17, use_device=True,
                            result_cache=0, adaptive_capacity=model)
    _, learn_s, learn_counters = _timed_batch(adaptive, log)  # learning pass
    adaptive.query_batch(log)     # un-timed: compiles the promoted tiers
    _, replay_s, replay_counters = _timed_batch(adaptive, log)

    learned = {str(k): v for k, v in sorted(model.learned_tiers().items(),
                                            key=str)}
    return {
        "queries": len(log),
        "static_g4_rule": {
            "rerun_calls": static_counters["rerun_calls"],
            "jit_executions": static_counters["batch_calls"],
            "wall_s": static_s,
            "qps": len(log) / static_s,
        },
        "learning_pass": {
            "rerun_calls": learn_counters["rerun_calls"],
            "adaptive_promotions": learn_counters["adaptive_promotions"],
            "wall_s": learn_s,
        },
        "learned_replay": {
            "rerun_calls": replay_counters["rerun_calls"],
            "adaptive_overflow_saved":
                replay_counters["adaptive_overflow_saved"],
            "jit_executions": replay_counters["batch_calls"],
            "wall_s": replay_s,
            "qps": len(log) / replay_s,
        },
        "rerun_calls_before": static_counters["rerun_calls"],
        "rerun_calls_after": replay_counters["rerun_calls"],
        "qps_ratio_vs_static": (len(log) / replay_s) / (len(log) / static_s),
        "learned_tiers": learned,
    }


def run(n_docs: int = 12000, vocab: int = 8000, n_queries: int = 256,
        n_distinct: int = 96, flush_tier: int = 8, gap_us: float = 300.0,
        deadline_us: float = 2000.0, dense_every: int = 8,
        min_df: int = 24, max_df_frac: float = 0.04, seed: int = 17):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=60, seed=seed)
    postings = {t: p for t, p in inverted_index(docs).items()
                if min_df <= len(p) <= max_df_frac * n_docs}
    # salt the index with a dense near-duplicate pair: its conjunction's
    # survivors ≈ G > G/4, so the static capacity rule overflows every time
    rng = np.random.default_rng(seed)
    dense = rng.choice(1 << 20, size=2048, replace=False).astype(np.uint32)
    ta, tb = max(postings) + 1, max(postings) + 2
    postings[ta], postings[tb] = dense, dense.copy()

    log = repeated_query_log(sorted(set(postings) - {ta, tb}), n_queries,
                             n_distinct=n_distinct, seed=seed + 1)
    for i in range(0, len(log), dense_every):
        log[i] = [ta, tb]

    eng = AsyncSearchEngine(postings, w=256, m=2, seed=seed,
                            deadline_us=deadline_us, flush_tier=flush_tier,
                            result_cache=1024)
    # index-build-time warming: every signature in the log at every pow2
    # batch tier a partial flush can produce — measured waits must reflect
    # the policy, not trace+compile transients
    eng.warm(log, top_k=len(log), b_tiers=pow2_tiers(flush_tier))
    oracle = SearchEngine(postings, w=256, m=2, seed=seed,
                          use_device=True).query_batch(log)
    # priming pass absorbs remaining one-time lazy-init transients
    serve_open_loop(eng, log, gap_us, use_flusher=True)

    manual_tickets, manual = manual_pump_open_loop(eng, log, gap_us)
    flusher_tickets, flusher = serve_open_loop(eng, log, gap_us,
                                               use_flusher=True)
    identical = all(
        np.array_equal(t.value.doc_ids, o.doc_ids)
        for t, o in zip(flusher_tickets, oracle)
    ) and all(
        np.array_equal(t.value.doc_ids, o.doc_ids)
        for t, o in zip(manual_tickets, oracle)
    )
    assert identical, "async paths diverged from the query_batch oracle"

    adaptive = adaptive_overflow_experiment(postings, log)
    return {
        "n_docs": n_docs,
        "vocab_kept": len(postings),
        "queries": n_queries,
        "distinct_pool": n_distinct,
        "flush_tier": flush_tier,
        "deadline_us": deadline_us,
        "arrival_gap_us": gap_us,
        "dense_query_every": dense_every,
        "identical_to_query_batch": identical,
        "flusher": {"manual_pump": manual, "background_flusher": flusher},
        "adaptive": adaptive,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--distinct", type=int, default=96)
    ap.add_argument("--gap-us", type=float, default=300.0)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_adaptive_qps.json"))
    args = ap.parse_args()
    res = run(args.docs, args.vocab, args.queries, n_distinct=args.distinct,
              gap_us=args.gap_us)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
