"""Paper Fig. 9 + Lemma A.1/A.3: probability that an empty group tuple is
filtered by the m-image AND test, vs the theoretical lower bounds."""
from __future__ import annotations
import numpy as np
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix
from .common import gen_pair


def run(quick: bool = True):
    n = 1 << 15 if quick else 1 << 18
    rows = []
    for w in (64, 256):
        sw = int(np.sqrt(w))
        lemma_a1 = (1 - 1 / sw) ** sw
        for m in (1, 2, 3, 4):
            fam = random_hash_family(m, w, seed=m * w)
            perm = default_permutation(5)
            a, b = gen_pair(n, n, max(1, n // 100), seed=m)
            ia = preprocess_prefix(a, w=w, m=m, family=fam, perm=perm)
            ib = preprocess_prefix(b, w=w, m=m, family=fam, perm=perm)
            _, st = rangroupscan([ia, ib])
            # non-empty tuples that *should* pass ~ r-bearing groups; the
            # filter rate over empty tuples:
            truth_r = len(np.intersect1d(a, b))
            nonempty_est = min(st.group_tuples, truth_r)
            empty = st.group_tuples - nonempty_est
            filtered_rate = st.tuples_filtered / max(1, empty)
            rows.append({
                "figure": "fig9", "w": w, "m": m,
                "filter_rate_empty": round(filtered_rate, 4),
                "lemma_bound": round(1 - (1 - lemma_a1) ** m, 4),
                "survivors": st.tuples_survived,
            })
    return rows
