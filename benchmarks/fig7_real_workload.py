"""Paper Fig. 7/12: realistic workload — Zipf corpus + query log with the
paper's keyword-count mix (68/23/9% for 2/3/4 words).

Reports normalized mean latency (Merge = 1.0), per-k breakdown, the
fraction of queries each algorithm wins, and worst-case latency ratios.
"""
from __future__ import annotations
import numpy as np
from repro.core.baselines import merge, svs_gallop, hash_lookup, lookup_st
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import hashbin, rangroup, rangroupscan
from repro.core.partition import preprocess_prefix
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.serve.search import zipf_query_log
from .common import timeit


def run(quick: bool = True):
    n_docs = 20000 if quick else 200000
    n_q = 150 if quick else 1000
    docs = zipf_corpus(n_docs, vocab=20000, mean_len=120, seed=3)
    postings = inverted_index(docs)
    fam = random_hash_family(2, 256, seed=7)
    perm = default_permutation(7)
    index = {t: preprocess_prefix(p, w=256, m=2, family=fam, perm=perm)
             for t, p in postings.items() if len(p) >= 2}
    queries = [q for q in zipf_query_log(sorted(index), n_q, seed=9)
               if all(t in index for t in q) and len(q) >= 2]

    algo_times = {}
    wins = {}
    def record(name, us_list):
        algo_times[name] = us_list
    names = ["RanGroupScan", "RanGroup", "HashBin2", "Merge", "SvS", "Hash", "Lookup"]
    per_algo = {n: [] for n in names}
    for q in queries:
        idxs = sorted((index[t] for t in q), key=lambda i: i.n)
        posts = [np.asarray(postings[t]) for t in q]
        posts.sort(key=len)
        truth = posts[0]
        for s in posts[1:]:
            truth = np.intersect1d(truth, s)
        runs = {
            "RanGroupScan": lambda: rangroupscan(idxs)[0],
            "RanGroup": lambda: rangroup(idxs)[0],
            "Merge": lambda: merge(posts)[0],
            "SvS": lambda: svs_gallop(posts)[0],
            "Hash": lambda: hash_lookup(posts)[0],
            "Lookup": lambda: lookup_st(posts)[0],
        }
        if len(idxs) == 2:
            runs["HashBin2"] = lambda: hashbin(idxs[0], idxs[1])[0]
        for name, fn in runs.items():
            us, res = timeit(fn, reps=1)
            assert np.array_equal(res, truth), name
            per_algo[name].append(us)
        done = {n: per_algo[n][-1] for n in runs}
        best = min(done, key=done.get)
        wins[best] = wins.get(best, 0) + 1

    merge_mean = float(np.mean(per_algo["Merge"]))
    rows = []
    for name, ts in per_algo.items():
        if not ts:
            continue
        rows.append({
            "figure": "fig7", "algorithm": name, "queries": len(ts),
            "normalized_mean": round(float(np.mean(ts)) / merge_mean, 3),
            "normalized_worst": round(float(np.max(ts)) /
                                      float(np.max(per_algo["Merge"])), 3),
            "win_fraction": round(wins.get(name, 0) / max(1, len(queries)), 3),
        })
    return rows
