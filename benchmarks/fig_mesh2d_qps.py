"""Throughput benchmark: 2-D (data x shard) mesh layouts vs the 1-D
z-shard special case, on the replica-friendly workload.

Sweeps the three 4-device layouts — 1x4 (pure z-shard, the PR-3 topology),
2x2, and 4x1 (pure data-parallel) — over the same hot-z conjunctive
workload and reports QPS, mesh pipeline executions, and overflow re-runs
per layout, equality-checked query-by-query against the unsharded
single-device baseline (which the tier-1 suite oracle-checks against the
host path).

The replica-friendly workload plants the conjunctions' intersection inside
one hot z-quarter (values chosen so the permutation ``g`` maps them to the
top-quarter prefix range).  Survivors then concentrate on a single z-shard,
and the per-shard survivor budget — ``capacity_tier / shards`` — becomes
the binding constraint: the wider the z axis, the thinner each shard's
slice of the budget.  At 1x4 the hot shard deterministically overflows and
every bucket pays the enlarged re-run pass (~2x work); at 2x2 the same
survivors fit the twice-as-fat per-shard buffer and the bucket completes
in one pass, with the data axis absorbing the other half of the mesh.
This is the structural argument for composing replication with
partitioning instead of sharding wider: replication multiplies throughput
without fragmenting the survivor budget.  (On CPU with forced host
devices, QPS measures this *structure* — work and passes — rather than
real accelerator scaling; on a TPU slice the same script measures both.)

Run:  PYTHONPATH=src python benchmarks/fig_mesh2d_qps.py [--queries N]
      [--set-size N] [--overlap N] [--out BENCH_mesh2d_qps.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices to lay out, and the CPU
# backend explicitly (with libtpu on the image a concurrently running jax
# process would otherwise serialize on the TPU lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core.engine import EXEC_COUNTERS
from repro.core.hashing import default_permutation
from repro.exec.topology import make_topology
from repro.serve.search import SearchEngine

LAYOUTS = ((1, 4), (2, 2), (4, 1))


def hot_z_postings(n_terms: int, set_size: int, overlap: int,
                   seed: int = 11, perm_seed: int = 11):
    """Posting lists whose pairwise intersections live in one z-quarter.

    Every term shares one planted set of ``overlap`` values whose
    permutation image has top-2 bits 0 (=> they land on the first quarter
    of the z range at every partition depth t >= 2, i.e. on shard 0 of any
    2- or 4-way z split), padded to ``set_size`` with disjoint values from
    the other three quarters.  Any conjunction of hot terms intersects to
    exactly the planted set, so phase-1 survivors concentrate on one
    shard.
    """
    rng = np.random.default_rng(seed)
    perm = default_permutation(perm_seed)
    pool = np.unique(rng.choice(1 << 31, 16 * n_terms * set_size // 10,
                                replace=False).astype(np.uint32))
    quarter = (perm.forward(pool) >> np.uint32(30)).astype(np.uint32)
    hot = pool[quarter == 0]
    cold = pool[quarter != 0]
    assert len(hot) >= overlap and len(cold) >= n_terms * set_size
    planted = hot[:overlap]
    postings = {}
    for i in range(n_terms):
        fill = cold[i * (set_size - overlap):(i + 1) * (set_size - overlap)]
        postings[i] = np.unique(np.concatenate([fill, planted]))
    return postings, planted


def hot_pair_log(n_terms: int, n_queries: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(n_terms, 2, replace=False).tolist())
            for _ in range(n_queries)]


def _run_engine(engine, log, passes: int, baseline_results=None):
    """One untimed warm pass (compiles), then ``passes`` timed passes.

    A mismatch against the baseline is RECORDED (``identical: 0``), not
    asserted — the artifact must always be written so the CI gate's
    ``identical_to_baseline equals 1`` rule can report the failure
    readably instead of the job dying on a missing file."""
    engine.query_batch(log)
    EXEC_COUNTERS.reset()
    t0 = time.perf_counter()
    for _ in range(passes):
        results = engine.query_batch(log)
    wall_s = time.perf_counter() - t0
    identical = True
    if baseline_results is not None:
        for q, a, b in zip(log, results, baseline_results):
            if not np.array_equal(a.doc_ids, b.doc_ids):
                identical = False
                print(f"MISMATCH vs baseline for query {q}")
    max_shard = max((r.stats.get("max_shard_survivors", 0) for r in results),
                    default=0)
    return results, {
        "queries": len(log),
        "passes": passes,
        "wall_s": wall_s,
        "qps": passes * len(log) / wall_s,
        "identical": int(identical),
        "mesh2d_calls": EXEC_COUNTERS["mesh2d_calls"],
        "mesh2d_rerun_calls": EXEC_COUNTERS["mesh2d_rerun_calls"],
        "single_device_calls": EXEC_COUNTERS["batch_calls"],
        "rerun_calls": EXEC_COUNTERS["rerun_calls"],
        "replica_dispatches": EXEC_COUNTERS["replica_dispatches"],
        "max_shard_survivors": int(max_shard),
    }


def run(n_queries: int = 256, n_terms: int = 12, set_size: int = 50000,
        overlap: int = 400, m: int = 6, passes: int = 3,
        shard_min_g: int = 64, seed: int = 11):
    # perm_seed == the engines' seed: the planted hot-quarter values must be
    # hot under the SAME permutation the engines partition with
    postings, planted = hot_z_postings(n_terms, set_size, overlap, seed=seed,
                                       perm_seed=seed)
    log = hot_pair_log(n_terms, n_queries, seed=seed + 1)
    avail = len(jax.devices())
    layouts = [(r, s) for r, s in LAYOUTS if r * s <= avail]
    assert layouts, f"no viable layout on {avail} devices"

    baseline = SearchEngine(postings, w=256, m=m, seed=seed, use_device=True)
    base_results, base_metrics = _run_engine(baseline, log, passes)

    layout_metrics = []
    identical = True
    for replicas, shards in layouts:
        topo = make_topology(replicas, shards)
        eng = SearchEngine(postings, w=256, m=m, seed=seed, topology=topo,
                           shard_min_g=shard_min_g)
        plans = [eng.plan(q) for q in log]
        assert all(p.algorithm == "device" and p.sig.replicas == replicas
                   and p.sig.shards == shards for p in plans), (
            "workload must route to the full mesh in every layout")
        _, metrics = _run_engine(eng, log, passes,
                                 baseline_results=base_results)
        identical &= bool(metrics["identical"])
        metrics["layout"] = topo.describe()
        metrics["replicas"] = replicas
        metrics["shards"] = shards
        metrics["speedup_vs_baseline"] = (
            base_metrics["wall_s"] / metrics["wall_s"])
        metrics["balancer_dispatched"] = [
            d["dispatched"] for d in topo.load_snapshot()]
        layout_metrics.append(metrics)

    by_layout = {mtr["layout"]: mtr for mtr in layout_metrics}
    speedup = None
    if "2x2" in by_layout and "1x4" in by_layout:
        speedup = by_layout["1x4"]["wall_s"] / by_layout["2x2"]["wall_s"]
    return {
        "devices": avail,
        "queries": n_queries,
        "n_terms": n_terms,
        "set_size": set_size,
        "overlap": len(planted),
        "m": m,
        "passes": passes,
        "shard_min_g": shard_min_g,
        "identical_to_baseline": int(identical),
        "baseline": base_metrics,
        "layouts": layout_metrics,
        "speedup_2x2_vs_1x4": speedup,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--terms", type=int, default=12)
    ap.add_argument("--set-size", type=int, default=50000)
    ap.add_argument("--overlap", type=int, default=400,
                    help="planted hot-quarter intersection size; sized so a "
                         "4-way z split overflows its per-shard budget and a "
                         "2-way split does not")
    ap.add_argument("--m", type=int, default=6,
                    help="hash count (6 keeps the false-positive floor well "
                         "below the per-shard budgets the workload targets)")
    ap.add_argument("--passes", type=int, default=3)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_mesh2d_qps.json"))
    args = ap.parse_args()
    res = run(args.queries, args.terms, args.set_size, args.overlap,
              m=args.m, passes=args.passes)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
