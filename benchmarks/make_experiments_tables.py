"""Generate the §Dry-run and §Roofline markdown tables from artifacts.

Usage: PYTHONPATH=src:. python benchmarks/make_experiments_tables.py
Writes benchmarks/artifacts/tables.md (pasted into EXPERIMENTS.md).
"""
from __future__ import annotations

import pathlib

from roofline import load_cells, roofline_row

OUT = pathlib.Path(__file__).resolve().parent / "artifacts" / "tables.md"


def fmt(x, nd=2):
    return f"{x:.{nd}f}"


def main() -> None:
    cells = load_cells("baseline")
    lines = []

    lines.append("### Dry-run matrix (status | GiB/device | compile s)\n")
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for mesh in ("16x16", "2x16x16"):
        lines.append(f"\n**mesh {mesh}**\n")
        lines.append("| arch | " + " | ".join(shapes) + " |")
        lines.append("|---|" + "---|" * len(shapes))
        for a in archs:
            row = [a]
            for sh in shapes:
                rec = next((c for c in cells if c["arch"] == a
                            and c["shape"] == sh and c["mesh"] == mesh), None)
                if rec is None:
                    row.append("—")
                elif rec["status"] == "skip":
                    row.append("SKIP (full-attn)")
                elif rec["status"] != "ok":
                    row.append("ERROR")
                else:
                    gib = rec["memory_analysis"]["peak_bytes_est"] / 2**30
                    row.append(f"ok {gib:.1f}G {rec['compile_s']:.0f}s")
            lines.append("| " + " | ".join(row) + " |")

    lines.append("\n### Roofline (per device; v5e 197TF/s bf16, 819GB/s HBM, "
                 "50GB/s link)\n")
    lines.append("| arch | shape | mesh | compute s | memory s | collective s "
                 "| dominant | roofline frac | useful flops |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for rec in cells:
        if rec["status"] != "ok":
            continue
        r = roofline_row(rec)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | **{r['dominant']}** | "
            f"{100*r['roofline_fraction']:.1f}% | "
            f"{100*r['useful_compute_ratio']:.0f}% |")

    OUT.write_text("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
