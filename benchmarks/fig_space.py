"""Paper §4 'Size of the Data Structure': uncompressed space overheads.

Paper numbers: +37% (RanGroupScan m=2), +63% (m=4), +75% (IntGroup),
+87% (RanGroup multi-resolution) over an uncompressed posting list.
"""
from __future__ import annotations
import numpy as np
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.partition import (preprocess_fixed, preprocess_multiresolution,
                                  preprocess_prefix)


def run(quick: bool = True):
    n = 1 << 16 if quick else 1 << 20
    rng = np.random.default_rng(2)
    vals = rng.choice(1 << 28, size=n, replace=False).astype(np.uint32)
    perm = default_permutation(2)
    rows = []
    for m in (1, 2, 4):
        fam = random_hash_family(m, 64, seed=m)
        idx = preprocess_prefix(vals, w=64, m=m, family=fam, perm=perm)
        over = idx.storage_words() / n - 1
        rows.append({"figure": "space", "structure": f"RanGroupScan_m{m}",
                     "overhead_pct": round(100 * over, 1),
                     "paper_pct": {1: None, 2: 37.0, 4: 63.0}[m]})
    fixed = preprocess_fixed(vals, w=64, family=random_hash_family(1, 64, seed=9))
    # IntGroup: words = n (elements) + G*(1 image + lo/hi) + inverted maps
    g = fixed.G
    ig_words = n + g * 3 + n  # elements + per-group words + next pointers
    rows.append({"figure": "space", "structure": "IntGroup",
                 "overhead_pct": round(100 * (ig_words / n - 1), 1),
                 "paper_pct": 75.0})
    mr = preprocess_multiresolution(vals[: 1 << 14], w=64, m=1)
    rows.append({"figure": "space", "structure": "RanGroup_multires",
                 "overhead_pct": round(100 * (mr.storage_words() / (1 << 14) - 1), 1),
                 "paper_pct": 87.0})
    return rows
