"""Observability overhead + integrity benchmark: the traced serving stack
must stay bit-identical and within noise of metrics-only serving.

The same warmed hot-z burst from ``fig_concurrent_qps`` (2x2 topology,
single-device buckets via the balancer, overlapped flusher window) is
served by two identically configured ``AsyncSearchEngine``\\ s whose only
difference is the observability mode:

- *metrics* — ``Obs(trace=False)``, the default: typed histograms and
  counters record, every span call hits the shared ``NULL_SPAN`` sentinel.
- *traced* — ``Obs(trace=True)``: full request/bucket span trees, span
  cross-links, and per-signature profile attribution on top.

Measured claims (all gated by ``tools/check_bench.py``):

- ``identical_to_oracle`` — BOTH modes reproduce the synchronous
  ``query_batch`` oracle bit-for-bit on every pass: observability is
  read-only.
- ``overhead.qps_ratio_traced_vs_metrics`` — median-of-passes served QPS
  with tracing on vs off; the CI floor is 0.95 (<= 5% overhead).  The
  modes run interleaved so shared-host drift hits both alike.
- ``leaked_spans`` — open span count after every traced pass drains: 0,
  or an instrumentation site forgot to close (the request root closes in
  ``Ticket._record_wait``, bucket roots in ``InFlightBucket.collect``).
- ``snapshot_consistent`` — the post-pass registry cut is internally
  consistent (histogram ``sum(counts) == count``, queue-wait count ==
  resolved tickets, collect count == dispatched buckets) and survives
  both exposition round-trips (Prometheus text and JSON).
- ``residual_coverage`` — every signature the traced engine executed
  (ground truth: the ``bucket`` spans' sig attrs) has a profile entry
  with CostModel-residual attribution, after ``calibrate_from_profile``
  closes the fit loop on the collected samples (ROADMAP item 5's feed).

Run:  PYTHONPATH=src python benchmarks/fig_observability.py [--queries N]
      [--passes N] [--out BENCH_observability.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices to lay out, and the CPU
# backend explicitly (with libtpu on the image a concurrently running jax
# process would otherwise serialize on the TPU lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from fig_concurrent_qps import _pow2_tiers, hot_mixed_log
from fig_mesh2d_qps import hot_z_postings
from repro.core.engine import EXEC_COUNTERS
from repro.exec.topology import make_topology
from repro.obs import Obs, parse_json, parse_prometheus, to_json, to_prometheus
from repro.serve.loadgen import calibrate_from_profile
from repro.serve.search import AsyncSearchEngine, SearchEngine

LAYOUT = (2, 2)


def make_engine(postings, log, m, seed, obs, flush_tier, deadline_us):
    topo = make_topology(*LAYOUT)
    eng = AsyncSearchEngine(
        postings, w=256, m=m, seed=seed, topology=topo,
        shard_min_g=1 << 20,            # single-device buckets -> balancer
        flush_tier=flush_tier, deadline_us=deadline_us,
        result_cache=0,                 # repeats must hit the device
        max_inflight=8, obs=obs)
    eng.warm(log, top_k=len(log), b_tiers=_pow2_tiers(len(log)))
    return eng, topo


def serve_burst(eng, obs, log):
    """One closed-loop flusher burst; obs state is reset first so the
    post-pass snapshot covers exactly this pass."""
    eng.cache.clear()
    EXEC_COUNTERS.reset()
    obs.reset()
    eng.start()
    t0 = time.perf_counter()
    tickets = [eng.submit(q) for q in log]
    for t in tickets:
        t.wait(timeout=300.0)
    wall_s = time.perf_counter() - t0
    eng.stop()
    assert eng._flusher_error is None, eng._flusher_error
    assert all(t.done for t in tickets)
    return tickets, wall_s


def check_snapshot_consistency(obs, n_queries: int) -> dict:
    """Post-pass integrity: the registry cut's internal invariants and
    both exposition round-trips.  Returns the checks as 0/1 ints."""
    snap = obs.snapshot()
    hist_ok = all(sum(h["counts"]) == h["count"]
                  for h in snap["histograms"].values())
    waits_ok = (snap["histograms"]["queue_wait_us"]["count"] == n_queries
                and snap["collected"]["exec_tickets_resolved"] == n_queries)
    buckets = EXEC_COUNTERS["inflight_dispatches"]
    collect_ok = (snap["histograms"]["collect_latency_us"]["count"]
                  == buckets
                  and snap["histograms"]["bucket_batch_size"]["count"]
                  == buckets
                  and snap["histograms"]["bucket_batch_size"]["sum"]
                  == n_queries)
    prom = parse_prometheus(to_prometheus(snap))
    prom_ok = (prom["repro_queue_wait_us"]["count"] == n_queries
               and prom["repro_exec_tickets_resolved"]["value"] == n_queries)
    json_ok = parse_json(to_json(snap)) == snap
    return {
        "histograms_internally_consistent": int(hist_ok),
        "counts_match_execution": int(waits_ok and collect_ok),
        "prometheus_round_trip": int(prom_ok),
        "json_round_trip": int(json_ok),
    }


def run(n_queries: int = 256, n_terms: int = 12, set_size: int = 50000,
        overlap: int = 400, m: int = 6, flush_tier: int = 8,
        deadline_us: float = 2000.0, passes: int = 5, seed: int = 11):
    postings, planted = hot_z_postings(n_terms, set_size, overlap, seed=seed,
                                       perm_seed=seed)
    log = hot_mixed_log(n_terms, n_queries, seed=seed + 1)
    avail = len(jax.devices())
    assert avail >= LAYOUT[0] * LAYOUT[1], f"needs 4 devices, have {avail}"

    oracle = SearchEngine(postings, w=256, m=m, seed=seed,
                          use_device=True).query_batch(log)

    plan = (("metrics", Obs(trace=False)), ("traced", Obs(trace=True)))
    engines = {}
    for mode, obs in plan:
        eng, topo = make_engine(postings, log, m, seed, obs, flush_tier,
                                deadline_us)
        serve_burst(eng, obs, log)      # priming pass: lazy init + any
        engines[mode] = (eng, obs, topo)  # shape warming missed

    walls = {mode: [] for mode, _ in plan}
    identical = True
    leaked_spans = 0
    consistency = None
    trace_shape = None
    for p in range(passes):
        for mode, _ in plan:
            eng, obs, topo = engines[mode]
            tickets, wall_s = serve_burst(eng, obs, log)
            walls[mode].append(wall_s)
            identical &= all(np.array_equal(t.value.doc_ids, o.doc_ids)
                             for t, o in zip(tickets, oracle))
            assert all(d["in_flight"] == 0 for d in topo.load_snapshot())
            if mode == "traced":
                leaked_spans += obs.tracer.open_count()
                consistency = check_snapshot_consistency(obs, len(log))
                roots = obs.tracer.finished("request")
                bspans = obs.tracer.finished("bucket")
                trace_shape = {
                    "request_spans": len(roots),
                    "bucket_spans": len(bspans),
                    "all_requests_closed_once": int(
                        len(roots) == len(log)
                        and all(s.end_us is not None for s in roots)),
                }
            else:
                assert obs.tracer.finished() == [], \
                    "disabled tracer recorded spans"
    assert identical, "observability changed served results"

    # residual attribution: fit the cost model from the collected samples
    # (ROADMAP item 5's loop), attach it, and re-serve one pass so every
    # executed signature carries a predicted/residual attribution
    eng, obs, topo = engines["traced"]
    fit = calibrate_from_profile(obs.profile)
    assert fit is not None, "profile had < 2 distinct batch tiers"
    obs.profile.cost_model = fit
    serve_burst(eng, obs, log)
    executed = {s.attrs["sig"] for s in obs.tracer.finished("bucket")}
    residuals = obs.profile.residuals()
    covered = executed & set(residuals)
    residual_coverage = len(covered) / max(1, len(executed))
    attributed = all(residuals[lbl]["predicted_us"] > 0 for lbl in covered)

    med = {mode: float(np.median(ws)) for mode, ws in walls.items()}
    qps = {mode: len(log) / w for mode, w in med.items()}
    return {
        "devices": avail,
        "layout": f"{LAYOUT[0]}x{LAYOUT[1]}",
        "queries": n_queries,
        "n_terms": n_terms,
        "set_size": set_size,
        "overlap": len(planted),
        "m": m,
        "flush_tier": flush_tier,
        "deadline_us": deadline_us,
        "passes": passes,
        "identical_to_oracle": int(identical),
        "walls_s": walls,
        "served_qps": qps,
        "overhead": {
            "qps_ratio_traced_vs_metrics": qps["traced"] / qps["metrics"],
            "median_wall_metrics_s": med["metrics"],
            "median_wall_traced_s": med["traced"],
        },
        "leaked_spans": leaked_spans,
        "snapshot": consistency,
        "snapshot_consistent": int(all(consistency.values())),
        "trace_shape": trace_shape,
        "cost_fit": {"per_bucket_us": fit.per_bucket_us,
                     "per_query_us": fit.per_query_us},
        "residual_coverage": residual_coverage,
        "residuals_attributed": int(attributed),
        "residuals": residuals,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--terms", type=int, default=12)
    ap.add_argument("--set-size", type=int, default=50000)
    ap.add_argument("--overlap", type=int, default=400)
    ap.add_argument("--m", type=int, default=6)
    ap.add_argument("--flush-tier", type=int, default=8)
    ap.add_argument("--passes", type=int, default=5)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_observability.json"))
    args = ap.parse_args()
    res = run(args.queries, args.terms, args.set_size, args.overlap,
              m=args.m, flush_tier=args.flush_tier, passes=args.passes)
    print(json.dumps({k: v for k, v in res.items() if k != "residuals"},
                     indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
