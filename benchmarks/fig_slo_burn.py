"""SLO-burn benchmark: the serving stack under open-loop production traffic.

Closed-loop benches (``fig_concurrent_qps``, ``fig_adaptive_qps``) measure
how fast the flusher drains a burst it controls; this one measures what
users experience when arrivals are scheduled by the outside world —
**SLO burn**, the fraction of completed queries whose queue wait exceeded
the deadline budget, plus p50/p99 waits and a windowed burn-rate curve.

Traffic comes from ``serve/loadgen.py``: Zipf-skewed terms over the index
vocabulary, the paper's keyword-count mix, a diurnal rate sinusoid, and
Poisson burst clumps, drawn from a finite distinct pool (live-log shape).
Two replay modes:

- **virtual-time sweep** (deterministic, CI-gated): the engine is rebound
  to a virtual clock and the driver emulates the background flusher's
  sleep-until-deadline loop, charging each flush's cost to a single-server
  ``busy_until`` horizon through a *calibrated* cost model (median wall of
  warmed 1-query and ``flush_tier``-query buckets → affine
  per-bucket/per-query fit).  Offered rates are expressed as ``rate_x``
  multiples of the calibrated **singleton capacity** ``1e6 / (c0 + c1)``
  queries/s — the relevant bottleneck under signature-diverse open traffic,
  where deadline flushes dominate and buckets are small (the pow2-tier
  capacity is ~``flush_tier``x higher and only reachable when traffic
  coalesces; micro-batching makes capacity elastic between the two, which
  is exactly the regime the sweep walks through).  Low ``rate_x`` must not
  burn (the gated ceiling); high ``rate_x`` must burn (the gated floor —
  proof the harness can detect overload rather than flattering it).
- **wall-clock run** (reported, identity/hygiene-gated, burn not gated —
  shared CI hosts make real-time tails measure the container): the same
  generator replayed in real time by submitter threads against the *real*
  background flusher, with scheduled-arrival back-stamping (coordinated-
  omission correction) and a ``threading.enumerate`` leak check.

Every completed ticket in every run is checked bit-identical to the host
oracle (``SearchEngine(use_device=False)`` — the paper's §4 reference
path), and ``inflight_dispatches == inflight_collects`` must hold after
every drain (no lost buckets).  The measurement loop closes with an
analytical summary of the hot bucket executable: optimized HLO via
``core.engine.bucket_hlo_text`` → ``launch/hlo_analysis.analyze_hlo`` →
roofline terms against ``benchmarks/roofline.py``'s device constants.

Run:  PYTHONPATH=src python benchmarks/fig_slo_burn.py [--docs N]
      [--duration-s S] [--out BENCH_slo_burn.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices, and the CPU backend
# explicitly (libtpu on the image would serialize on the TPU lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import sys
from collections import Counter

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.core.engine import EXEC_COUNTERS, bucket_hlo_text, pow2_tiers
from repro.data.pipeline import inverted_index, zipf_corpus
from repro.launch.hlo_analysis import analyze_hlo
from repro.serve.loadgen import (
    QueryMix, TrafficShape, attach_wall_clock, build_schedule,
    calibrate_cost, run_virtual, run_wallclock,
)
from repro.serve.search import AsyncSearchEngine, SearchEngine

# gated operating points: BURN at CAL_X must stay under the ceiling, burn
# at OVER_X must clear the floor (tools/check_bench.py RULES)
CAL_X = 0.04
OVER_X = 0.75


def check_identity(oracle: SearchEngine, entries, queries, memo) -> bool:
    """Bit-identity of every completed ticket against the host oracle
    (memoized per distinct conjunction)."""
    ok = True
    for (_, ticket), q in zip(entries, queries):
        key = tuple(q)
        if key not in memo:
            memo[key] = oracle.query(list(q)).doc_ids
        ok &= (ticket.error is None
               and np.array_equal(ticket.value.doc_ids, memo[key]))
    return ok


def hlo_summary(eng: AsyncSearchEngine, pool, b_tier: int):
    """Analytical FLOP/byte summary of the modal bucket executable."""
    plans = [eng.plan(list(q)) for q in pool]
    device = [p for p in plans if p.algorithm == "device"]
    if not device:
        return {"note": "no device-routed signature in the pool"}
    sig = Counter(p.sig for p in device).most_common(1)[0][0]
    rep = next(p for p in device if p.sig == sig)
    row = [eng.device.sets[str(t)] for t in rep.terms]
    text = bucket_hlo_text([row] * b_tier, capacity=sig.capacity_tier,
                           use_pallas=eng.device.use_pallas)
    ha = analyze_hlo(text, default_group=1)
    flops = float(ha["flops_per_device"])
    hbm = float(ha["hbm_bytes_per_device"])
    wire = float(ha["wire_bytes_per_device"])
    compute_us = flops / PEAK_FLOPS * 1e6
    memory_us = hbm / HBM_BW * 1e6
    wire_us = wire / LINK_BW * 1e6
    bound = max((("compute", compute_us), ("memory", memory_us),
                 ("wire", wire_us)), key=lambda kv: kv[1])[0]
    return {
        "sig": repr(sig),
        "b_tier": b_tier,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "wire_bytes_per_device": wire,
        "flops_per_query": flops / b_tier,
        "hbm_bytes_per_query": hbm / b_tier,
        # roofline terms at the guide's device constants: analytical floor
        # on bucket time per bottleneck, and which one binds
        "roofline": {
            "peak_flops": PEAK_FLOPS,
            "hbm_bw": HBM_BW,
            "link_bw": LINK_BW,
            "compute_term_us": compute_us,
            "memory_term_us": memory_us,
            "wire_term_us": wire_us,
            "bound": bound,
        },
    }


def run(n_docs: int = 12000, vocab: int = 8000, min_df: int = 24,
        max_df_frac: float = 0.04, distinct_pool: int = 96,
        flush_tier: int = 8, deadline_us: float = 2000.0,
        duration_s: float = 3.0, rates=(CAL_X, 0.25, OVER_X),
        windows: int = 10, wall_qps: float = 250.0,
        wall_duration_s: float = 1.2, submitters: int = 2, seed: int = 23):
    docs = zipf_corpus(n_docs, vocab=vocab, mean_len=60, seed=seed)
    # mid-frequency pruning as in the other serving benches: the paper's
    # r << n regime, not stopword enumeration
    postings = {t: p for t, p in inverted_index(docs).items()
                if min_df <= len(p) <= max_df_frac * n_docs}
    terms = sorted(postings)
    mix = QueryMix(distinct_pool=distinct_pool, pareto_scale=8.0)

    eng = AsyncSearchEngine(postings, w=256, m=2, seed=seed,
                            flush_tier=flush_tier, deadline_us=deadline_us,
                            result_cache=0)  # every repeat hits the device:
    # capacity (and therefore burn) measures execution, not cache luck

    # ---- one fixed query pool for every schedule (pinned via
    # build_schedule(pool=...)), so index-build-time warming covers every
    # signature any run can flush and the oracle memo is shared
    pool_rng = np.random.default_rng(seed)
    pool = [tuple(q) for q in
            QueryMix(distinct_pool=None, pareto_scale=8.0,
                     kw_dist=mix.kw_dist).sample(terms, distinct_pool,
                                                 pool_rng)]
    eng.warm([list(q) for q in pool], top_k=len(pool),
             b_tiers=pow2_tiers(flush_tier))

    # ---- calibration: modal-signature closed-loop cost fit
    plans = [eng.plan(list(q)) for q in pool]
    by_sig = Counter(p.sig for p in plans if p.algorithm == "device")
    modal_sig = by_sig.most_common(1)[0][0]
    modal = [list(p.terms) for p in plans if p.sig == modal_sig]
    cost = calibrate_cost(eng, (modal * flush_tier)[:2 * flush_tier],
                          tier=flush_tier)
    singleton_qps = 1e6 / cost.flush_cost_us(1, 1)
    tier_qps = cost.capacity_qps(flush_tier)

    oracle = SearchEngine(postings, w=256, m=2, seed=seed, use_device=False)
    memo = {}
    identical = True
    balanced = True
    errors_total = 0

    virtual_runs = []
    by_rate = {}
    for rate_x in rates:
        shape = TrafficShape(
            base_qps=rate_x * singleton_qps,
            duration_s=duration_s,
            diurnal_amplitude=0.5,
            diurnal_period_s=duration_s / 2.0,  # two compressed "days"
            burst_rate_hz=1.0,
            burst_size=12.0,
        )
        sched = build_schedule(shape, terms, mix, seed=seed + 1, pool=pool)
        report, entries = run_virtual(eng, sched, cost, windows=windows)
        identical &= check_identity(oracle, entries, sched.queries, memo)
        balanced &= (report.counters["inflight_dispatches"]
                     == report.counters["inflight_collects"])
        errors_total += report.errors
        rec = {"rate_x": rate_x, **report.to_json()}
        virtual_runs.append(rec)
        by_rate[rate_x] = report

    # ---- wall-clock replay: real flusher thread, real sleeps
    attach_wall_clock(eng)
    wall_shape = TrafficShape(base_qps=wall_qps, duration_s=wall_duration_s,
                              diurnal_amplitude=0.5,
                              diurnal_period_s=wall_duration_s,
                              burst_rate_hz=1.0, burst_size=8.0)
    wall_sched = build_schedule(wall_shape, terms, mix, seed=seed + 2,
                                pool=pool)
    wall_report, wall_entries = run_wallclock(eng, wall_sched,
                                             submitters=submitters,
                                             windows=windows)
    identical &= check_identity(oracle, wall_entries, wall_sched.queries,
                                memo)
    balanced &= (wall_report.counters["inflight_dispatches"]
                 == wall_report.counters["inflight_collects"])
    errors_total += wall_report.errors
    serve_traces = wall_report.counters["batch_traces"]

    return {
        "n_docs": n_docs,
        "vocab_kept": len(postings),
        "distinct_pool": distinct_pool,
        "queries": sum(r["arrivals"] for r in virtual_runs),
        "flush_tier": flush_tier,
        "deadline_us": deadline_us,
        "duration_s": duration_s,
        "calibration": {
            "per_bucket_us": cost.per_bucket_us,
            "per_query_us": cost.per_query_us,
            "singleton_capacity_qps": singleton_qps,
            "tier_capacity_qps": tier_qps,
            "modal_sig": repr(modal_sig),
        },
        "virtual_runs": virtual_runs,
        # gated headline metrics (tools/check_bench.py):
        "calibrated_rate_x": CAL_X,
        "overload_rate_x": OVER_X,
        "calibrated_burn_rate": by_rate[CAL_X].burn_rate,
        "overload_burn_rate": by_rate[OVER_X].burn_rate,
        "identical_to_oracle": int(identical),
        "dispatch_collect_balanced": int(balanced),
        "errors_total": errors_total,
        "thread_leak": wall_report.thread_leak,
        "wallclock": {"submitters": submitters,
                      "serve_time_traces": serve_traces,
                      **wall_report.to_json()},
        "hlo": hlo_summary(eng, pool, b_tier=flush_tier),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=12000)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--distinct", type=int, default=96)
    ap.add_argument("--duration-s", type=float, default=3.0)
    ap.add_argument("--wall-qps", type=float, default=250.0)
    ap.add_argument("--wall-duration-s", type=float, default=1.2)
    ap.add_argument("--submitters", type=int, default=2)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_slo_burn.json"))
    args = ap.parse_args()
    res = run(args.docs, args.vocab, distinct_pool=args.distinct,
              duration_s=args.duration_s, wall_qps=args.wall_qps,
              wall_duration_s=args.wall_duration_s,
              submitters=args.submitters)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
