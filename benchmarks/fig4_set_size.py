"""Paper Fig. 4: intersect 2 equal-size sets, r = 1% of n, vary n.

Claim to validate: RanGroupScan ~40-50% faster than Merge across sizes;
ordering RanGroupScan <= IntGroup < Merge < Lookup < adaptive < Hash/SkipList.
"""
from __future__ import annotations
import numpy as np
from .common import (baseline_algos, check_and_time, gen_pair, paper_algos,
                     truth_of, INTERP_ONLY)


def run(quick: bool = True):
    sizes = [1 << 17, 1 << 19] if quick else [1 << 17, 1 << 19, 1 << 21, 1 << 23]
    rows = []
    for n in sizes:
        a, b = gen_pair(n, n, max(1, n // 100), seed=n)
        truth = truth_of([a, b])
        algos = paper_algos([a, b], w=256, m=2)
        base = ["Merge", "SvS", "Hash", "Lookup"] + (
            [] if quick else ["SkipList", "BaezaYates", "BPP"])
        algos.update(baseline_algos([a, b], include=base))
        times = check_and_time(algos, truth, reps=2 if quick else 3)
        for name, us in times.items():
            rows.append({"figure": "fig4", "n": n, "r": len(truth),
                         "algorithm": name, "us": round(us, 1),
                         "interp": name in INTERP_ONLY,
                         "speedup_vs_merge": round(times["Merge"] / us, 3)})
    return rows
