"""Boolean-expression serving benchmark: expression-DAG QPS and the
subexpression cache's contribution on a shared-subtree workload.

The workload is a pool of distinct boolean queries that *share composite
subtrees in conjunctive context*: a small set of union "bases"
``o_j = (a_j | b_j)`` combined with varying extra terms as
``o_j & t`` and ``(o_j & t) - u``.  Sharing must happen under ∩/∪ — the
canonicalizer pushes differences down (``(a|b) - e`` rewrites to
``(a-e)|(b-e)``), so a subtree used only as a minuend of ∖ would not
survive normalization and could never be shared.

Served open-loop (fixed inter-arrival gap, real wall clock) through the
``AsyncSearchEngine`` background flusher.  The first query touching a
base pays the device DAG evaluation and stores every canonicalized
composite subexpression (plus the root itself) in the result cache;
later *distinct* roots over the same base resolve at submit time by a
host-side set-algebra merge over cached subtrees — no device work, no
queue wait.  Reported: served QPS, subexpression-cache hit/store/merge
counters, queue-wait percentiles, and device-pass counts.  Every ticket
is checked bit-identical to the ``eval_host`` numpy oracle.

When >= 4 forced host devices are available, a second section replays
the same expression log through a 2x2 (data x shard) mesh engine with
the result cache disabled — pure ``expr/mesh2d`` device evaluation —
and folds its oracle equality into ``identical_to_oracle``.

Run:  PYTHONPATH=src python benchmarks/fig_boolean_qps.py [--queries N]
      [--docs N] [--out BENCH_boolean_qps.json]
"""
from __future__ import annotations

import os

# before the first jax import: forced host devices so the mesh2d section
# can lay out, and the CPU backend explicitly (with libtpu on the image a
# concurrently running jax process would otherwise serialize on the TPU
# lockfile)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core.engine import EXEC_COUNTERS, pow2_tiers
from repro.exec.expr import And, Diff, Or, Term, eval_host
from repro.exec.topology import make_topology
from repro.serve.search import AsyncSearchEngine, SearchEngine


def _pace_until(t_target: float) -> None:
    """Open-loop pacing that yields the GIL (see fig_adaptive_qps)."""
    while True:
        dt = t_target - time.perf_counter()
        if dt <= 0:
            return
        time.sleep(dt)


def _percentiles(xs):
    arr = np.asarray(xs, dtype=np.float64)
    if not len(arr):
        return 0.0, 0.0
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 99)))


def random_postings(n_terms: int, n_docs: int, set_size: int, seed: int):
    """Uniform random posting lists over a shared doc-id universe.

    With ``set_size**2 / n_docs`` well above zero every pairwise
    intersection is nonempty in expectation, so unions, intersections
    and differences over these terms all produce nontrivial results.
    """
    rng = np.random.default_rng(seed)
    return {
        t: np.unique(rng.choice(n_docs, size=set_size,
                                replace=False).astype(np.uint32))
        for t in range(n_terms)
    }


def shared_subtree_log(n_terms: int, n_queries: int, n_bases: int,
                       seed: int):
    """Expression log over ``n_bases`` shared union bases.

    Base ``j`` is ``Or(Term(2j), Term(2j+1))``; each query draws a base
    and an extra term from the remaining vocabulary and emits either
    ``base & extra`` or ``(base & extra) - cut``.  Distinct (base,
    extra) pairs give distinct roots that share the base subtree — the
    shape the subexpression cache is built for.
    """
    assert 2 * n_bases < n_terms, "need extra terms beyond the bases"
    rng = np.random.default_rng(seed)
    bases = [Or((Term(2 * j), Term(2 * j + 1))) for j in range(n_bases)]
    extras = list(range(2 * n_bases, n_terms))
    log = []
    for i in range(n_queries):
        base = bases[int(rng.integers(n_bases))]
        extra = Term(extras[int(rng.integers(len(extras)))])
        e = And((base, extra))
        if i % 3 == 2:
            cut = Term(extras[int(rng.integers(len(extras)))])
            e = Diff(e, cut)
        log.append(e)
    return log


def serve_open_loop(eng: AsyncSearchEngine, log, gap_us: float):
    """One real-time open-loop flusher run; returns (tickets, metrics)."""
    eng.cache.clear()
    EXEC_COUNTERS.reset()
    tickets = []
    eng.start()
    t0 = time.perf_counter()
    for i, q in enumerate(log):
        _pace_until(t0 + i * gap_us * 1e-6)
        tickets.append(eng.submit(q))
    submit_wall_s = time.perf_counter() - t0
    for t in tickets:
        t.wait(timeout=60.0)
    eng.stop()                                      # drains any stragglers
    wall_s = time.perf_counter() - t0
    assert all(t.done for t in tickets)
    queued = [t.wait_us for t in tickets
              if t.value.stats.get("batch_size") and
              not t.value.stats.get("cached")]
    p50, p99 = _percentiles(queued)
    hits = EXEC_COUNTERS["subexpr_cache_hits"]
    misses = EXEC_COUNTERS["subexpr_cache_misses"]
    merges = EXEC_COUNTERS["subexpr_host_merges"]
    return tickets, {
        "queries": len(log),
        "offered_qps": 1e6 / gap_us,
        "served_qps": len(log) / wall_s,
        "submit_wall_s": submit_wall_s,
        "total_wall_s": wall_s,
        "device_queries": len(queued),
        "host_merged_queries": merges,
        "p50_wait_us": p50,
        "p99_wait_us": p99,
        "subexpr_cache_hits": hits,
        "subexpr_cache_misses": misses,
        "subexpr_cache_stores": EXEC_COUNTERS["subexpr_cache_stores"],
        "subexpr_host_merges": merges,
        "subexpr_hit_rate": hits / max(1, hits + misses),
        "expr_calls": EXEC_COUNTERS["expr_calls"],
        "expr_rerun_calls": EXEC_COUNTERS["expr_rerun_calls"],
        "flusher_wakeups": EXEC_COUNTERS["flusher_wakeups"],
    }


def mesh2d_section(postings, log, oracle, seed: int, shard_min_g: int = 4):
    """Replay the log through a 2x2 mesh with caching off: pure device
    DAG evaluation, equality-checked against the same oracle."""
    topo = make_topology(2, 2)
    eng = SearchEngine(postings, w=256, m=6, seed=seed, topology=topo,
                       shard_min_g=shard_min_g, result_cache=0)
    eng.query_batch(log)                            # compile warm-up pass
    EXEC_COUNTERS.reset()
    t0 = time.perf_counter()
    results = eng.query_batch(log)
    wall_s = time.perf_counter() - t0
    identical = all(np.array_equal(r.doc_ids, o)
                    for r, o in zip(results, oracle))
    if not identical:
        print("MISMATCH vs oracle on the mesh2d section")
    mesh_served = sum(r.algorithm == "expr/mesh2d" for r in results)
    return {
        "layout": topo.describe(),
        "queries": len(log),
        "qps": len(log) / wall_s,
        "wall_s": wall_s,
        "identical": int(identical),
        "expr_mesh2d_queries": int(mesh_served),
        "expr_calls": EXEC_COUNTERS["expr_calls"],
        "expr_rerun_calls": EXEC_COUNTERS["expr_rerun_calls"],
    }


def run(n_queries: int = 256, n_docs: int = 20000, n_terms: int = 24,
        set_size: int = 3000, n_bases: int = 6, flush_tier: int = 8,
        deadline_us: float = 2000.0, gap_us: float = 400.0,
        seed: int = 23):
    postings = random_postings(n_terms, n_docs, set_size, seed)
    log = shared_subtree_log(n_terms, n_queries, n_bases, seed + 1)
    oracle = [eval_host(e, lambda t: postings[t]) for e in log]

    eng = AsyncSearchEngine(postings, w=256, m=6, seed=seed,
                            deadline_us=deadline_us, flush_tier=flush_tier,
                            result_cache=1024)
    # index-build-time warming: every expression signature in the log at
    # every pow2 batch tier a partial flush can produce — measured waits
    # must reflect the policy, not trace+compile transients
    eng.warm(log, top_k=len(log), b_tiers=pow2_tiers(flush_tier))
    # priming pass absorbs remaining one-time lazy-init transients
    serve_open_loop(eng, log, gap_us)

    tickets, metrics = serve_open_loop(eng, log, gap_us)
    identical = all(np.array_equal(t.value.doc_ids, o)
                    for t, o in zip(tickets, oracle))
    if not identical:
        print("MISMATCH vs eval_host oracle on the flusher run")

    avail = len(jax.devices())
    mesh = None
    if avail >= 4:
        mesh = mesh2d_section(postings, log, oracle, seed)
        identical = identical and bool(mesh["identical"])

    distinct_roots = len({repr(e) for e in log})
    out = {
        "devices": avail,
        "queries": n_queries,
        "n_docs": n_docs,
        "n_terms": n_terms,
        "set_size": set_size,
        "shared_bases": n_bases,
        "distinct_roots": distinct_roots,
        "flush_tier": flush_tier,
        "deadline_us": deadline_us,
        "arrival_gap_us": gap_us,
        "identical_to_oracle": int(identical),
        "mesh2d": mesh,
    }
    out.update(metrics)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--terms", type=int, default=24)
    ap.add_argument("--set-size", type=int, default=3000)
    ap.add_argument("--bases", type=int, default=6,
                    help="shared union bases; fewer bases -> more subtree "
                         "reuse -> higher subexpression-cache hit rate")
    ap.add_argument("--gap-us", type=float, default=400.0)
    ap.add_argument("--out", type=str,
                    default=str(pathlib.Path(__file__).resolve().parent.parent
                                / "BENCH_boolean_qps.json"))
    args = ap.parse_args()
    res = run(args.queries, args.docs, args.terms, args.set_size,
              n_bases=args.bases, gap_us=args.gap_us)
    print(json.dumps(res, indent=2))
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
