"""Paper Fig. 8 + §4.1: compressed structures — space AND intersection time.

RanGroupScan_Lowbits (App. B) vs gamma/delta-compressed Merge.  Space is
bit-exact accounting; timing includes the decode path (Lowbits decode is a
vectorized shift-OR; Elias decode is an inherently serial bit-walk, flagged
`interp` as its python constant factor is not comparable).
"""
from __future__ import annotations
import numpy as np
from repro.core.compress import (compress_lowbits, decompress_group,
                                 delta_decode, delta_encode, space_report)
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import rangroupscan
from repro.core.partition import preprocess_prefix
from .common import gen_pair, timeit, truth_of


def run(quick: bool = True):
    n = 1 << 16 if quick else 1 << 20
    a, b = gen_pair(n, n, max(1, n // 100), seed=4)
    truth = truth_of([a, b])
    fam = random_hash_family(1, 64, seed=4)   # m=1 as in the paper's Fig. 8
    perm = default_permutation(4)
    ia = preprocess_prefix(a, w=64, m=1, family=fam, perm=perm)
    ib = preprocess_prefix(b, w=64, m=1, family=fam, perm=perm)
    ca, cb = compress_lowbits(ia), compress_lowbits(ib)

    def scan_lowbits():
        # decode groups on the fly (vectorized shift-OR), then intersect
        # via the usual image filter + group match
        return rangroupscan([ia, ib])[0]   # images live; elements decoded

    us_scan, res = timeit(scan_lowbits, reps=2)
    assert np.array_equal(res, truth)

    bits_a, nb_a = delta_encode(np.sort(a))
    bits_b, nb_b = delta_encode(np.sort(b))

    def merge_delta():
        da = delta_decode(bits_a, nb_a)
        db = delta_decode(bits_b, nb_b)
        return np.intersect1d(da, db, assume_unique=True)

    us_md, res2 = timeit(merge_delta, reps=1)
    assert np.array_equal(res2, truth)

    rep = space_report(ia)
    rows = [
        {"figure": "fig8", "algorithm": "RanGroupScan_Lowbits", "n": n,
         "us": round(us_scan, 1), "bits_per_elem": round(ca.storage_bits() / ia.n, 2),
         "interp": False},
        {"figure": "fig8", "algorithm": "Merge_Delta", "n": n,
         "us": round(us_md, 1), "bits_per_elem": round(rep["merge_delta"], 2),
         "interp": True},
        {"figure": "fig8", "algorithm": "Merge_Gamma", "n": n, "us": None,
         "bits_per_elem": round(rep["merge_gamma"], 2), "interp": True},
        {"figure": "fig8", "algorithm": "Merge_uncompressed", "n": n,
         "us": None, "bits_per_elem": 32.0, "interp": False},
    ]
    rows.append({"figure": "fig8", "algorithm": "space_ratio_lowbits_vs_delta",
                 "n": n, "us": None,
                 "bits_per_elem": round(
                     ca.storage_bits() / ia.n / rep["merge_delta"], 2),
                 "interp": False})
    return rows
