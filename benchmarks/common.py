"""Shared helpers for the paper-figure benchmarks.

Timing notes: all "fast path" algorithms compared in wall-clock (the paper's
metric) are vectorized C-backed numpy on both sides (RanGroupScan /
IntGroup / Merge / SvS / Lookup / Hash), so constant factors are
comparable; inherently serial pointer-walk baselines (SkipList, BaezaYates,
BPP) are python-loop implementations and are reported with an `interp`
flag — as in the paper they lose everywhere, but their *operation counts*
are implementation-independent and reported alongside.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.baselines import BASELINES
from repro.core.hashing import default_permutation, random_hash_family
from repro.core.intersect import hashbin, intgroup, rangroup, rangroupscan
from repro.core.partition import preprocess_fixed, preprocess_prefix

INTERP_ONLY = {"SkipList", "BaezaYates", "BPP"}


def gen_pair(n1: int, n2: int, r: int, universe: int = 1 << 28, seed: int = 0
             ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    common = rng.choice(universe, size=r, replace=False).astype(np.uint32)
    a = rng.choice(universe, size=n1, replace=False).astype(np.uint32)
    b = rng.choice(universe, size=n2, replace=False).astype(np.uint32)
    return (np.unique(np.concatenate([a[:max(0, n1 - r)], common])),
            np.unique(np.concatenate([b[:max(0, n2 - r)], common])))


def gen_k(k: int, n: int, r: int, universe: int = 1 << 28, seed: int = 0
          ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    common = rng.choice(universe, size=r, replace=False).astype(np.uint32)
    out = []
    for i in range(k):
        own = rng.choice(universe, size=n, replace=False).astype(np.uint32)
        out.append(np.unique(np.concatenate([own[:max(0, n - r)], common])))
    return out


def timeit(fn: Callable, reps: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out  # microseconds


def paper_algos(sets: Sequence[np.ndarray], w: int = 256, m: int = 2,
                seed: int = 0, include=("RanGroupScan", "RanGroup",
                                        "IntGroup", "HashBin")):
    """Pre-process once, return {name: callable} for the paper algorithms."""
    fam = random_hash_family(m, w, seed=seed)
    fam1 = random_hash_family(1, 64, seed=seed + 1)
    perm = default_permutation(seed)
    out: Dict[str, Callable] = {}
    if "RanGroupScan" in include or "RanGroup" in include or "HashBin" in include:
        idxs = [preprocess_prefix(s, w=w, m=m, family=fam, perm=perm)
                for s in sets]
        if "RanGroupScan" in include:
            out["RanGroupScan"] = lambda: rangroupscan(idxs)[0]
        if "RanGroup" in include:
            out["RanGroup"] = lambda: rangroup(idxs)[0]
        if "HashBin" in include and len(sets) == 2:
            out["HashBin"] = lambda: hashbin(idxs[0], idxs[1])[0]
    if "IntGroup" in include and len(sets) == 2:
        fixed = [preprocess_fixed(s, w=64, family=fam1) for s in sets]
        out["IntGroup"] = lambda: intgroup(fixed[0], fixed[1])[0]
    return out


def baseline_algos(sets: Sequence[np.ndarray], include=None):
    include = include or list(BASELINES)
    return {name: (lambda fn=fn: fn(sets)[0])
            for name, fn in BASELINES.items() if name in include}


def check_and_time(algos: Dict[str, Callable], truth: np.ndarray,
                   reps: int = 3) -> Dict[str, float]:
    out = {}
    for name, fn in algos.items():
        us, res = timeit(fn, reps=reps)
        assert np.array_equal(res, truth), f"{name} produced a wrong result"
        out[name] = us
    return out


def truth_of(sets: Sequence[np.ndarray]) -> np.ndarray:
    out = sets[0]
    for s in sets[1:]:
        out = np.intersect1d(out, s)
    return out
