"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  For every (arch x shape x mesh) cell:

  compute term    = HLO flops / chip / 197e12          [s]
  memory term     = HBM-boundary bytes / chip / 819e9  [s]
  collective term = wire bytes / chip / 50e9           [s]

All three inputs are trip-count-aware per-device numbers from the HLO
walker (launch/hlo_analysis.py).  The dominant term is the bottleneck; the
roofline fraction reported is compute_term / dominant_term (1.0 = the
chip's MXUs are the binding constraint — perfect for a training step).
MODEL_FLOPS uses 6*N*D (dense) or 6*N_active*D (MoE) per trained token;
the useful-compute ratio MODEL_FLOPS / HLO_FLOPS exposes remat and
dispatch overheads (> 1/3 is healthy for full-remat training).
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load_cells(variant: str = "baseline") -> List[Dict]:
    out = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("variant", "baseline") != variant:
            continue
        out.append(rec)
    return out


def tokens_of(rec: Dict) -> int:
    from repro.configs import shape_by_name

    s = shape_by_name(rec["shape"])
    if rec["kind"] == "decode":
        return s.global_batch  # one new token per sequence
    return s.global_batch * s.seq_len


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    ha = rec["hlo_analysis"]
    n = rec["n_devices"]
    compute = ha["flops_per_device"] / PEAK_FLOPS
    memory = ha["hbm_bytes_per_device"] / HBM_BW
    coll = ha["wire_bytes_per_device"] / LINK_BW
    dom = max(compute, memory, coll)
    which = ("compute" if dom == compute else
             "memory" if dom == memory else "collective")
    toks = tokens_of(rec)
    mult = 3 if rec["kind"] == "train" else 1  # fwd+bwd
    model_flops = 2 * rec["active_params_B"] * 1e9 * toks * mult
    hlo_global = ha["flops_per_device"] * n
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": which,
        "roofline_fraction": compute / dom if dom else 0.0,
        "model_flops": model_flops,
        "useful_compute_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "mem_per_dev_gib": rec["memory_analysis"]["peak_bytes_est"] / 2**30,
        "tokens_per_step": toks,
        "step_time_bound_s": dom,
        "collective_bytes_by_type": ha["collective_bytes_by_type"],
    }


def table(variant: str = "baseline") -> List[Dict]:
    rows = []
    for rec in load_cells(variant):
        if rec.get("status") == "skip":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "dominant": "SKIP",
                         "reason": rec.get("reason", "")})
            continue
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def run(quick: bool = True):
    rows = []
    for r in table():
        if r.get("dominant") == "SKIP":
            continue
        rows.append({
            "figure": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"],
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "roofline_fraction": round(r["roofline_fraction"], 4),
            "useful_compute_ratio": round(r["useful_compute_ratio"], 3),
        })
    return rows


def main() -> None:
    rows = table()
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<8} {'comp_ms':>9} {'mem_ms':>9} "
           f"{'coll_ms':>9} {'dom':<10} {'roof%':>6} {'useful%':>8} {'GiB/dev':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("dominant") == "SKIP":
            print(f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<8} "
                  f"{'—':>9} {'—':>9} {'—':>9} {'SKIP':<10}")
            continue
        print(f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<8} "
              f"{r['compute_s']*1e3:>9.2f} {r['memory_s']*1e3:>9.2f} "
              f"{r['collective_s']*1e3:>9.2f} {r['dominant']:<10} "
              f"{100*r['roofline_fraction']:>5.1f}% "
              f"{100*r['useful_compute_ratio']:>7.1f}% "
              f"{r['mem_per_dev_gib']:>8.2f}")


if __name__ == "__main__":
    main()
