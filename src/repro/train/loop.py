"""Training loop with checkpoint/restart, preemption and straggler guards.

The loop is deliberately boring: all cleverness lives in the step function
(train/step.py) and the checkpoint manager.  Fault tolerance properties:

  * deterministic resume — data is index-addressable (data/pipeline.py);
    the only pipeline state is the step counter in the manifest;
  * SIGTERM (preemption) triggers a synchronous save then a clean exit;
  * per-step deadline monitor: a step exceeding ``straggler_factor`` x the
    trailing-median step time increments a counter and logs — on a real
    cluster this feeds the controller that evicts/replaces the slow host
    (see train/elastic.py for the restart-side mechanics);
  * periodic async checkpoints overlap serialization with compute.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..models.model import Model
from ..optim import adamw
from ..parallel.sharding import shardings_of
from . import checkpoint as ckpt
from .step import abstract_params, build_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    resume: bool = True
    straggler_factor: float = 3.0
    seed: int = 0


def train(model: Model, mesh, data, loop_cfg: LoopConfig,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          microbatch: int = 1,
          log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P

    step_fn, (p_specs, o_specs), opt_cfg = build_train_step(
        model, mesh, opt_cfg=opt_cfg, microbatch=microbatch)
    p_abs = abstract_params(model)

    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        mgr = ckpt.CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
        mgr.install_preemption_handler()

        start_step = 0
        restored = None
        if loop_cfg.resume and ckpt.latest_step(loop_cfg.ckpt_dir) is not None:
            o_abs = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), p_abs)
            start_step, restored, extra = ckpt.restore(
                loop_cfg.ckpt_dir, {"params": p_abs, "opt": o_abs})
            log_fn(f"[resume] restored step {start_step} from {loop_cfg.ckpt_dir}")
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
        else:
            params = model.init(jax.random.PRNGKey(loop_cfg.seed))
            opt_state = adamw.init(opt_cfg, params)

        history: List[Dict[str, float]] = []
        times: List[float] = []
        stragglers = 0
        final_step = start_step
        for step in range(start_step, loop_cfg.steps):
            batch = data.batch_at(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])  # blocks; acts as the step barrier
            dt = time.perf_counter() - t0
            times.append(dt)
            if len(times) >= 5:
                med = statistics.median(times[-20:])
                if dt > loop_cfg.straggler_factor * med:
                    stragglers += 1
                    log_fn(f"[straggler] step {step} took {dt:.3f}s "
                           f"(median {med:.3f}s) — would trigger host swap")
            if step % loop_cfg.log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f} "
                       f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            history.append({"step": step, "loss": loss, "time_s": dt})
            final_step = step + 1
            if (step + 1) % loop_cfg.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                               extra={"data_step": step + 1})
            if mgr.preempted:
                log_fn(f"[preempt] SIGTERM at step {step}; saving and exiting")
                mgr.save_sync(step + 1, {"params": params, "opt": opt_state},
                              extra={"data_step": step + 1, "preempted": True})
                break
        else:
            mgr.save_sync(final_step, {"params": params, "opt": opt_state},
                          extra={"data_step": final_step})

    return {
        "history": history,
        "final_step": final_step,
        "stragglers": stragglers,
        "params": params,
        "opt_state": opt_state,
    }
