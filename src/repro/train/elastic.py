"""Elastic scaling: restart a checkpoint onto a different mesh.

Runbook (1000+ node operation):
  1. the cluster controller detects a failed/preempted host group;
  2. surviving hosts already hold the latest async checkpoint (sharded
     npz + manifest, atomic) — nothing to salvage from the dead host;
  3. the controller relaunches with the new device count; ``remesh``
     below rebuilds the mesh from whatever ``jax.devices()`` now reports,
     re-derives every PartitionSpec (they are rules over *names*, not
     device counts) and device_puts the restored host arrays through the
     new NamedShardings;
  4. the data pipeline resumes from the manifest's step counter — batches
     are index-addressable so no data is skipped or repeated;
  5. per-step-deadline straggler counters (train/loop.py) feed the same
     controller for proactive eviction.

Because every sharding rule divisibility-checks against the live mesh,
shrinking from 16-way to 8-way model parallelism (or dropping the `pod`
axis entirely) only changes *placement*, never the math.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from ..models.model import Model
from ..optim import adamw
from ..parallel.sharding import param_pspecs, shardings_of
from . import checkpoint as ckpt
from .step import abstract_params, needs_fsdp


def best_mesh_for(n_devices: int) -> jax.sharding.Mesh:
    """Factor the surviving device count into (data, model), preferring
    model <= 16 (TP islands should stay within an ICI domain)."""
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n_devices % cand == 0:
            model = cand
            break
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def remesh(model: Model, ckpt_dir: str,
           mesh: Optional[jax.sharding.Mesh] = None,
           opt_cfg: Optional[adamw.AdamWConfig] = None
           ) -> Tuple[int, Dict[str, Any], jax.sharding.Mesh]:
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    mesh = mesh or best_mesh_for(len(jax.devices()))
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    p_abs = abstract_params(model)
    o_abs = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), p_abs)
    fsdp = needs_fsdp(model)
    p_specs = param_pspecs(p_abs, mesh, fsdp=fsdp)
    o_specs = adamw.AdamWState(
        step=jax.sharding.PartitionSpec(),
        m=param_pspecs(o_abs.m, mesh, fsdp=fsdp),
        v=param_pspecs(o_abs.v, mesh, fsdp=fsdp),
    )
    shardings = {
        "params": shardings_of(p_abs, p_specs, mesh),
        "opt": jax.tree_util.tree_map(
            lambda _, s: jax.sharding.NamedSharding(mesh, s), o_abs, o_specs),
    }
    step, state, extra = ckpt.restore(
        ckpt_dir, {"params": p_abs, "opt": o_abs}, shardings=shardings)
    return step, state, mesh
