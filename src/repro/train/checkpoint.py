"""Fault-tolerant sharded checkpointing.

Design (scales to multi-host):
  * one leaf == one ``.npy`` blob inside an ``npz`` per process; leaf names
    are the pytree paths, so restore is structure-checked;
  * writes go to ``<dir>/tmp.<step>`` then a single atomic rename to
    ``<dir>/step_<n>`` — a crash mid-write never corrupts the latest
    checkpoint;
  * an async writer thread overlaps serialization with the next train
    steps (the arrays are snapshotted to host first, so donation is safe);
  * a manifest records step, mesh shape, data-pipeline cursor and config
    fingerprint — restore onto a *different* mesh re-device_puts through
    the new NamedShardings (elastic restart; see train/elastic.py);
  * ``install_preemption_handler`` converts SIGTERM (the cloud preemption
    signal) into a final synchronous save.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import re
import shutil
import signal
import threading
import time
import uuid
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "//"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(like: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves = []
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    for path, leaf in paths:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save. ``state`` is a dict of pytrees."""
    d = pathlib.Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"tmp.{step}.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    tmp.mkdir()
    for name, tree in state.items():
        np.savez(tmp / f"{name}.npz", **_flatten(tree))
    manifest = {
        "step": int(step),
        "time": time.time(),
        "names": sorted(state),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    final = d / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Dict[str, Any], step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None
            ) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
    """Restore state matching the ``like`` structure; optionally place each
    tree onto ``shardings`` (a dict of sharding pytrees — pass shardings
    built from a *new* mesh for an elastic restart)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    out = {}
    for name, tree in like.items():
        with np.load(d / f"{name}.npz") as z:
            flat = {k: z[k] for k in z.files}
        host_tree = _unflatten(tree, flat)
        if shardings and name in shardings:
            host_tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), host_tree, shardings[name])
        out[name] = host_tree
    return manifest["step"], out, manifest.get("extra", {})


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return
    steps = sorted(p for p in d.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


class CheckpointManager:
    """Async checkpointing + preemption-to-save + retention GC."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._preempted = threading.Event()
        self.last_saved: Optional[int] = None

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, state, extra = item
                save(self.ckpt_dir, step, state, extra)
                gc_old(self.ckpt_dir, self.keep)
                self.last_saved = step
            finally:
                self._q.task_done()

    def save_async(self, step: int, state: Dict[str, Any],
                   extra: Optional[Dict[str, Any]] = None) -> None:
        host = {k: jax.tree_util.tree_map(np.asarray, v) for k, v in state.items()}
        self._q.put((step, host, extra))

    def save_sync(self, step: int, state: Dict[str, Any],
                  extra: Optional[Dict[str, Any]] = None) -> str:
        self.drain()
        path = save(self.ckpt_dir, step, state, extra)
        gc_old(self.ckpt_dir, self.keep)
        self.last_saved = step
        return path

    def drain(self) -> None:
        """Block until every queued async save has fully finished."""
        self._q.join()

    # ---- preemption ----
    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            self._preempted.set()
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted.is_set()
