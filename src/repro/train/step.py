"""train_step / serve_step builders with full sharding annotations.

These are the functions the dry-run lowers and the launcher executes:

  * ``build_train_step(model, opt_cfg)`` — loss -> grad -> AdamW update;
    params/optimizer-state sharded per ``parallel.sharding.param_pspecs``
    (FSDP on request), batch over DP, optional gradient-accumulation
    microbatching via an inner scan.
  * ``build_serve_prefill`` / ``build_serve_decode`` — inference steps with
    KV-cache sharding per ``cache_pspecs``.

Everything returns (fn, in_shardings, out_shardings) ready for
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(...)``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import Model
from ..optim import adamw
from ..parallel import ctx
from ..parallel.sharding import (
    batch_pspecs, cache_pspecs, dp_axes, param_pspecs, shardings_of,
)


def needs_fsdp(model: Model) -> bool:
    """FSDP once params+optimizer at TP-only sharding would crowd HBM:
    ~12 bytes/param over 16 TP shards > ~2 GiB/chip  =>  ~3B params."""
    return model.cfg.param_count() > 3e9


def auto_microbatch(global_batch: int, seq: int, mesh: Mesh,
                    target_tokens_per_device: Optional[int] = None) -> int:
    """Gradient-accumulation factor: keep per-device live activation tokens
    near `target`, constrained to divide the per-device batch."""
    from .. import tuning
    if target_tokens_per_device is None:
        target_tokens_per_device = tuning.get("micro_tokens")
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    b_local = max(1, global_batch // dp)
    micro = max(1, (b_local * seq) // target_tokens_per_device)
    micro = min(micro, b_local)
    while b_local % micro:
        micro -= 1
    return micro


def abstract_params(model: Model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_train_step(model: Model, mesh: Mesh,
                     opt_cfg: Optional[adamw.AdamWConfig] = None,
                     fsdp: Optional[bool] = None,
                     microbatch: int = 1):
    """Returns (train_step, state_shardings, batch_shardings).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        state_dtype="bfloat16" if model.cfg.param_count() > 2e11 else "float32")
    fsdp = needs_fsdp(model) if fsdp is None else fsdp

    p_abs = abstract_params(model)
    p_specs = param_pspecs(p_abs, mesh, fsdp=fsdp)
    opt_abs = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), p_abs)
    o_specs = adamw.AdamWState(
        step=P(),
        m=param_pspecs(opt_abs.m, mesh, fsdp=fsdp),
        v=param_pspecs(opt_abs.v, mesh, fsdp=fsdp),
    )

    dp = dp_axes(mesh)

    def train_step(params, opt_state, batch):
        with ctx.activation_mesh(mesh):
            return _train_step_inner(params, opt_state, batch)

    def _train_step_inner(params, opt_state, batch):
        if microbatch > 1:
            def micro(carry, mb):
                gsum = carry
                loss, g = jax.value_and_grad(model.loss)(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return gsum, loss
            def split(x):
                x = x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    x, P(None, dp, *([None] * (x.ndim - 2))))
            sliced = jax.tree_util.tree_map(split, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(micro, zeros, sliced)
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_p, new_o, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = {**metrics, "loss": loss}
        return new_p, new_o, metrics

    return train_step, (p_specs, o_specs), opt_cfg


def build_serve_prefill(model: Model, mesh: Mesh):
    """prefill(params, batch) -> last-token logits; returns (fn, p_specs)."""
    p_abs = abstract_params(model)
    p_specs = param_pspecs(p_abs, mesh, fsdp=needs_fsdp(model))

    def prefill(params, batch):
        with ctx.activation_mesh(mesh):
            return model.prefill(params, batch)

    return prefill, p_specs


def build_serve_decode(model: Model, mesh: Mesh, batch: int, max_seq: int):
    """decode(params, cache, tokens, pos) -> (logits, cache)."""
    p_abs = abstract_params(model)
    p_specs = param_pspecs(p_abs, mesh, fsdp=needs_fsdp(model))
    cache_abs = jax.eval_shape(lambda: model.init_cache(batch, max_seq))
    c_specs = cache_pspecs(cache_abs, mesh)

    def decode(params, cache, tokens, pos):
        with ctx.activation_mesh(mesh):
            return model.decode(params, cache, tokens, pos)

    return decode, p_specs, c_specs, cache_abs
