"""Pre-processing stage: partition sets into small groups (Sections 3.1-3.3).

Three structures are built here:

* :class:`FixedWidthIndex` — Section 3.1 (IntGroup): a *sorted* set cut into
  consecutive rank-ranges of ``sqrt(w)`` elements, with per-group [min, max]
  ranges, word images under ``h``, and the faithful ``first/next`` threaded
  inverted mappings ``h^{-1}(y, L^j)``.

* :class:`PrefixIndex` — Sections 3.2/3.3 (RanGroup / RanGroupScan /
  HashBin): elements ordered by the permutation ``g``; group ``L^z`` = the
  elements whose ``t``-bit prefix ``g_t(x)`` equals ``z``.  Stored both as CSR
  (host algorithms) and as a dense padded ``(2^t, gmax)`` matrix (the TPU
  layout; padding uses the sentinel 0xFFFFFFFF which never equals a real
  g-key since g is a bijection and we exclude the single key that maps there
  from test universes).

* :class:`MultiResolutionIndex` — Section 3.2.1: every power-of-two
  resolution ``t = 0..ceil(log2 n)`` of one PrefixIndex family in O(n) space
  (images total <= 2n words; offsets implicit per resolution).

Pre-processing is host-side numpy (the paper's offline stage); device-side
mirrors are created by ``engine.DeviceSet``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

from .hashing import (
    BitMixPermutation, HashFamily, default_permutation, random_hash_family,
)
from .bitmaps import build_images_chunked, num_lanes

__all__ = [
    "FixedWidthIndex",
    "PrefixIndex",
    "MultiResolutionIndex",
    "choose_t",
    "preprocess_fixed",
    "preprocess_prefix",
]

SENTINEL = np.uint32(0xFFFFFFFF)


def choose_t(n: int, w: int) -> int:
    """t_i = ceil(log2(n_i / sqrt(w))) — Theorems 3.6/3.7/3.9."""
    if n <= 1:
        return 0
    return max(0, math.ceil(math.log2(max(1.0, n / math.sqrt(w)))))


def _pad_groups(flat: np.ndarray, offsets: np.ndarray, gmax: Optional[int] = None):
    """CSR -> dense padded (G, gmax) + mask."""
    G = len(offsets) - 1
    counts = np.diff(offsets)
    if gmax is None:
        gmax = int(counts.max()) if G else 1
        gmax = max(8, int(8 * math.ceil(gmax / 8)))  # sublane-align the pad
    dense = np.full((G, gmax), SENTINEL, dtype=np.uint32)
    mask = np.zeros((G, gmax), dtype=bool)
    # vectorized scatter: position of each element within its group
    if len(flat):
        group_of = np.repeat(np.arange(G), counts)
        within = np.arange(len(flat)) - np.repeat(offsets[:-1], counts)
        dense[group_of, within] = flat
        mask[group_of, within] = True
    return dense, mask, gmax


def _first_next(h_vals: np.ndarray, offsets: np.ndarray, w: int):
    """Faithful inverted mappings (Fig. 2): ``next`` pointers threading equal
    hash values in storage order, plus per-group CSR of (y, first_index).

    The paper packs ``first(y, L^z)`` into O(log|L^z|) bits; we store int32
    indices in a CSR keyed by the set bits actually present (<= |L^z| entries
    per group, O(n) total) — the space *accounting* in benchmarks/fig_space.py
    follows the paper's bit-level scheme.
    """
    n = len(h_vals)
    nxt = np.full(n, -1, dtype=np.int64)
    # next same-hash element to the right, computed per hash bucket globally;
    # group boundaries are handled at query time via offsets.
    order = np.lexsort((np.arange(n), h_vals))  # stable by (h, position)
    sorted_h = h_vals[order]
    same = sorted_h[1:] == sorted_h[:-1]
    nxt[order[:-1][same]] = order[1:][same]
    # per-group first occurrence of each y
    G = len(offsets) - 1
    first_y: List[np.ndarray] = []
    first_idx: List[np.ndarray] = []
    for gi in range(G):
        lo, hi = offsets[gi], offsets[gi + 1]
        hs = h_vals[lo:hi]
        ys, first_pos = np.unique(hs, return_index=True)
        first_y.append(ys.astype(np.uint32))
        first_idx.append((first_pos + lo).astype(np.int64))
    return nxt, first_y, first_idx


@dataclasses.dataclass
class FixedWidthIndex:
    """Section 3.1 structure: rank-partition of a sorted set."""

    values: np.ndarray        # (n,) uint32, sorted ascending
    group_size: int           # s (= sqrt(w) by default)
    padded_vals: np.ndarray   # (G, s) sentinel-padded
    mask: np.ndarray          # (G, s) bool
    offsets: np.ndarray       # (G+1,)
    lo: np.ndarray            # (G,) inf of each group
    hi: np.ndarray            # (G,) sup of each group
    images: np.ndarray        # (G, 1, W) uint32 — single h image
    nxt: np.ndarray           # (n,) next same-h index or -1
    first_y: List[np.ndarray]
    first_idx: List[np.ndarray]
    family: HashFamily
    w: int

    @property
    def n(self) -> int:
        return len(self.values)

    @property
    def G(self) -> int:
        return len(self.offsets) - 1

    def h_of(self, x):
        return self.family.apply(x, 0)


def preprocess_fixed(
    values: np.ndarray,
    w: int = 64,
    group_size: Optional[int] = None,
    family: Optional[HashFamily] = None,
    seed: int = 0,
) -> FixedWidthIndex:
    """Pre-process for IntGroup (Theorem 3.4): sort + fixed-width groups."""
    values = np.unique(np.asarray(values, dtype=np.uint32))
    n = len(values)
    s = group_size or max(1, int(round(math.sqrt(w))))
    family = family or random_hash_family(1, w, seed=seed)
    G = max(1, math.ceil(n / s))
    offsets = np.minimum(np.arange(G + 1) * s, n).astype(np.int64)
    lo = values[offsets[:-1].clip(max=max(n - 1, 0))]
    hi = values[(offsets[1:] - 1).clip(min=0, max=max(n - 1, 0))]
    h = family.apply(values, 0)
    dense, mask, gmax = _pad_groups(values, offsets)
    hashes = family.apply_all(dense).astype(np.uint32)  # (G, gmax, m=1)
    images = build_images_chunked(hashes, mask, w)
    nxt, first_y, first_idx = _first_next(np.asarray(h), offsets, w)
    return FixedWidthIndex(
        values=values, group_size=s, padded_vals=dense, mask=mask,
        offsets=offsets, lo=lo, hi=hi,
        images=images, nxt=nxt, first_y=first_y, first_idx=first_idx,
        family=family, w=w,
    )


@dataclasses.dataclass
class PrefixIndex:
    """Sections 3.2/3.3 structure: g-ordered, prefix-partitioned set.

    ``g_keys`` are the permuted keys g(x), sorted ascending; ``values`` are
    the original elements in the same order.  Group ``z`` occupies
    ``[offsets[z], offsets[z+1])``.  ``images[z, j]`` is the packed word
    representation of ``h_j(L^z)``.
    """

    values: np.ndarray        # (n,) uint32 — original ids, ordered by g(x)
    g_keys: np.ndarray        # (n,) uint32 — g(x), ascending
    t: int
    offsets: np.ndarray       # (2^t + 1,)
    padded_keys: np.ndarray   # (2^t, gmax) uint32 (sentinel-padded g keys)
    padded_vals: np.ndarray   # (2^t, gmax) uint32 (original values)
    mask: np.ndarray          # (2^t, gmax) bool
    gmax: int
    images: np.ndarray        # (2^t, m, W) uint32
    family: HashFamily        # the m filter hashes h_j
    perm: BitMixPermutation   # g
    w: int

    @property
    def n(self) -> int:
        return len(self.values)

    @property
    def G(self) -> int:
        return 1 << self.t

    def group_slice(self, z: int):
        lo, hi = self.offsets[z], self.offsets[z + 1]
        return self.values[lo:hi], self.g_keys[lo:hi]

    def storage_words(self) -> int:
        """Uncompressed structure size (words), per Section 3.3.1:
        n*(1 + (m+1)/|group|) words — elements + m images + len per group."""
        m = self.family.m
        return int(self.n + self.G * (m + 1))


def preprocess_prefix(
    values: np.ndarray,
    w: int = 256,
    m: int = 2,
    t: Optional[int] = None,
    family: Optional[HashFamily] = None,
    perm: Optional[BitMixPermutation] = None,
    seed: int = 0,
    gmax: Optional[int] = None,
) -> PrefixIndex:
    """Pre-process for RanGroup/RanGroupScan/HashBin (Theorems 3.8/3.10)."""
    values = np.unique(np.asarray(values, dtype=np.uint32))
    n = len(values)
    family = family or random_hash_family(m, w, seed=seed)
    perm = perm or default_permutation(seed)
    if t is None:
        t = choose_t(n, w)
    g = np.asarray(perm.forward(values))
    order = np.argsort(g, kind="stable")
    g_sorted = g[order]
    v_sorted = values[order]
    z = ((g_sorted >> np.uint32(32 - t)).astype(np.int64) if t > 0
         else np.zeros(n, np.int64))
    counts = np.bincount(z, minlength=1 << t)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    padded_keys, mask, gmax = _pad_groups(g_sorted, offsets, gmax)
    padded_vals, _, _ = _pad_groups(v_sorted, offsets, gmax)
    hashes = family.apply_all(padded_vals).astype(np.uint32)
    images = build_images_chunked(hashes, mask, w)
    return PrefixIndex(
        values=v_sorted, g_keys=g_sorted, t=t, offsets=offsets,
        padded_keys=padded_keys, padded_vals=padded_vals, mask=mask,
        gmax=gmax, images=images, family=family, perm=perm, w=w,
    )


@dataclasses.dataclass
class MultiResolutionIndex:
    """Section 3.2.1: all resolutions t = 0..T of one set in O(n) space.

    ``resolutions[t]`` gives (offsets, images) for the partition induced by
    ``g_t``; elements/g_keys are shared across resolutions (they are the same
    g-sorted array — each group is a contiguous interval).  Inverted mappings
    are threaded once globally (``nxt``) and resolved per group via binary
    search over ``first`` entries, as in Fig. 2.
    """

    base: PrefixIndex                      # finest resolution (t = T)
    offsets_by_t: List[np.ndarray]         # index t -> (2^t + 1,)
    images_by_t: List[np.ndarray]          # index t -> (2^t, m, W)

    @property
    def T(self) -> int:
        return self.base.t

    def at(self, t: int) -> "PrefixIndex":
        """Materialize a PrefixIndex view at resolution t (cheap: reuses the
        shared g-ordered arrays; pads groups on demand)."""
        assert 0 <= t <= self.T
        if t == self.T:
            return self.base
        offsets = self.offsets_by_t[t]
        padded_keys, mask, gmax = _pad_groups(self.base.g_keys, offsets)
        padded_vals, _, _ = _pad_groups(self.base.values, offsets, gmax)
        return PrefixIndex(
            values=self.base.values, g_keys=self.base.g_keys, t=t,
            offsets=offsets, padded_keys=padded_keys, padded_vals=padded_vals,
            mask=mask, gmax=gmax, images=self.images_by_t[t],
            family=self.base.family, perm=self.base.perm, w=self.base.w,
        )

    def storage_words(self) -> int:
        """Total words over all resolutions — O(n): sum_t 2^t * (m + 1) + n."""
        m = self.base.family.m
        tot = self.base.n
        for t in range(self.T + 1):
            tot += (1 << t) * (m + 1)
        return int(tot)


def preprocess_multiresolution(
    values: np.ndarray,
    w: int = 256,
    m: int = 2,
    family: Optional[HashFamily] = None,
    perm: Optional[BitMixPermutation] = None,
    seed: int = 0,
) -> MultiResolutionIndex:
    values = np.unique(np.asarray(values, dtype=np.uint32))
    n = len(values)
    T = max(0, math.ceil(math.log2(max(1, n))))
    base = preprocess_prefix(values, w=w, m=m, t=T, family=family, perm=perm, seed=seed)
    offsets_by_t: List[np.ndarray] = []
    images_by_t: List[np.ndarray] = []
    z_full = ((base.g_keys >> np.uint32(32 - T)).astype(np.int64) if T
              else np.zeros(n, np.int64))
    for t in range(T + 1):
        if t == T:
            offsets_by_t.append(base.offsets)
            images_by_t.append(base.images)
            continue
        z = z_full >> (T - t)
        counts = np.bincount(z, minlength=1 << t)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        offsets_by_t.append(offsets)
        padded_vals, mask, _ = _pad_groups(base.values, offsets)
        hashes = base.family.apply_all(padded_vals).astype(np.uint32)
        images_by_t.append(build_images_chunked(hashes, mask, base.w))
    return MultiResolutionIndex(base=base, offsets_by_t=offsets_by_t,
                                images_by_t=images_by_t)
