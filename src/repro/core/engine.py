"""Device-resident batched intersection engine (the paper's system on TPU).

Module map (the device path, bottom-up):

  kernels/            bitmap_filter / group_match Pallas kernels + jnp oracles;
                      both accept a leading batch axis folded into the grid.
  core/engine.py      this file — DeviceSet mirrors, the jit'd batched
                      two-phase pipeline (``_intersect_k_batch``), the
                      bucket executor entry point ``intersect_device_batch``,
                      and the z-sharded variant ``intersect_sharded``.
  exec/plan.py        query normalization (term dedup, sort by (t, n),
                      hashbin-vs-device policy) into shape-keyed QueryPlans.
  exec/batch.py       groups QueryPlans by shape signature, stacks DeviceSet
                      rows, and drives ``intersect_device_batch`` — one jit
                      execution per bucket plus rare overflow re-runs.
  serve/search.py     SearchEngine: plan -> bucket -> execute -> scatter.

Pre-processed sets (``partition.PrefixIndex``) are mirrored to the device as
dense arrays; intersections run as two fused phases:

  phase 1 (filter):  gather prefix-aligned images, k-way AND, m-way test
                     (kernels.ops.bitmap_filter — the paper's Alg. 5 line 3)
  phase 2 (recover): compact survivors to a static capacity, all-pairs match
                     of the raw groups (kernels.ops.group_match)

Static shapes everywhere: the survivor set is compacted into a fixed
``capacity`` buffer (per-query overflow flags returned; the executor re-runs
the rare overflowing subset once at full capacity).  This preserves the
paper's work-saving — the expensive phase 2 runs on ``capacity ≈
E[survivors]`` group tuples instead of all ``G`` — inside an XLA-compatible
regime.

Multi-query batching: the online stage is embarrassingly parallel across
queries, and real query logs concentrate on a handful of shape signatures
``(k, ts, gmaxes, capacity)`` (the paper's workload model: 68% 2-word, 23%
3-word queries).  ``_intersect_k_batch`` therefore takes ``(B, …)`` stacked
arrays and runs a whole same-signature bucket in ONE jit execution; the
single-query ``intersect_device`` is just a batch of one through the same
pipeline, so both paths share one compile cache.

Distribution: :func:`intersect_sharded` shard_maps the z-prefix space over
the ``model`` mesh axis.  Because every set is partitioned by the *same*
permutation ``g`` (Theorem 3.7's alignment), equal z-range blocks of every
set land on the same shard and both phases are entirely local; only the
per-shard result buffers are concatenated at the end.  The paper's
partitioning function doubles as the sharding function.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from .partition import PrefixIndex

__all__ = [
    "DeviceSet",
    "default_capacity",
    "intersect_device",
    "intersect_device_batch",
    "intersect_sharded",
    "pow2_tiers",
    "warm_executables",
    "warm_from_plans",
    "clear_exec_jit_cache",
    "BatchedEngine",
    "EXEC_COUNTERS",
    "ExecCounters",
    "reset_exec_counters",
]

class ExecCounters(dict):
    """Telemetry for the batched device path and the serving front-end.

    A plain ``dict`` subclass so existing ``EXEC_COUNTERS["key"]`` reads and
    writes keep working; ``reset()`` zeroes every counter (test setup calls
    it autouse so counter-asserting tests are order-independent).

    Keys:

    - ``batch_calls``     jit *executions* of the bucketed pipeline (what
      per-query dispatch would make O(#queries) and bucketing makes
      O(#signatures)).
    - ``batch_traces``    actual retraces (compiles) of the pipeline — one
      per distinct ``(ShapeSig, B-tier)`` pair over the process lifetime.
    - ``rerun_calls``     overflow re-run passes (survivors > capacity).
    - ``warm_executions`` pipeline executions issued by compile warming
      (:func:`warm_executables`) at index-build time.
    - ``result_cache_hits`` / ``result_cache_misses`` — lookups in the
      normalized-plan result cache (``exec/cache.py``).
    - ``tier_flushes`` / ``deadline_flushes`` — admission-queue bucket
      flushes by cause: reached the full power-of-two tier vs. the oldest
      query's deadline budget expired (``serve/admission.py``).
    """

    _KEYS = (
        "batch_calls", "batch_traces", "rerun_calls", "warm_executions",
        "result_cache_hits", "result_cache_misses",
        "tier_flushes", "deadline_flushes",
    )

    def __init__(self):
        super().__init__({k: 0 for k in self._KEYS})

    def reset(self) -> None:
        for key in self._KEYS:
            self[key] = 0


EXEC_COUNTERS = ExecCounters()


def reset_exec_counters() -> None:
    """Back-compat alias for :meth:`ExecCounters.reset`."""
    EXEC_COUNTERS.reset()


@dataclasses.dataclass(frozen=True)
class DeviceSet:
    """Device mirror of a PrefixIndex (sentinel-padded; mask implicit).

    ``gmax`` is quantized up to a power of two on mirroring: the exact
    per-set max group size is what it is on the host, but on the device it
    is a *static shape* — leaving it exact would give nearly every set its
    own shape signature and defeat bucketed batching.  Power-of-two tiers
    cost at most 2x padding on the tiny phase-2 tiles and collapse the
    signature space to a handful of buckets.
    """

    t: int
    gmax: int
    m: int
    w: int
    n: int
    vals: jnp.ndarray     # (2^t, gmax) int32 (original values; -1 padding)
    images: jnp.ndarray   # (2^t, m, W) uint32

    @classmethod
    def from_host(cls, idx: PrefixIndex) -> "DeviceSet":
        assert int(idx.values.max(initial=0)) < 0xFFFFFFFF, "sentinel collision"
        gmax = gmax_tier(idx.gmax)
        padded = np.pad(
            idx.padded_vals, ((0, 0), (0, gmax - idx.gmax)),
            constant_values=np.uint32(0xFFFFFFFF),
        )
        vals = jax.lax.bitcast_convert_type(jnp.asarray(padded), jnp.int32)
        return cls(
            t=idx.t, gmax=gmax, m=idx.family.m, w=idx.w, n=idx.n,
            vals=vals, images=jnp.asarray(idx.images),
        )


def gmax_tier(gmax: int) -> int:
    """Static-shape tier for a set's max group size: next power of two
    (>= 8).  Device mirrors pad to this, and the planner keys shape
    signatures by it, so host-exact gmaxes never fragment the buckets."""
    return 1 << max(3, (int(gmax) - 1).bit_length())


def default_capacity(ts: Tuple[int, ...]) -> int:
    """Survivor-buffer (capacity) tier for a query shape.

    capacity ≈ E[survivors]: non-empty-intersection groups ≲ r_max + the
    false-positive rate * G; G/4 + floor is conservative for the paper's
    r << n regime, and preserves the work-saving — phase 2 runs on capacity
    group tuples, not all G.  Dense queries (frequent-term pairs, survivors
    ≈ G) overflow and are re-run once at full capacity by the executor.
    Deterministic in ``ts`` so it can key shape buckets."""
    return max(64, (1 << ts[-1]) // 4)


def _aligned_images(images: Sequence[jnp.ndarray], ts: Tuple[int, ...]) -> jnp.ndarray:
    """Stack per-set images aligned by prefix (z_i = z_k >> (t_k - t_i)):
    (G_i, m, W) each -> (k, G, m, W), or (B, G_i, m, W) -> (B, k, G, m, W).

    The largest set's images are used in place; the others are gathered.  A
    gather of 2^{t_k - t_i} repeated rows is a broadcast in disguise — XLA
    lowers it to one; we reshape+broadcast explicitly to keep HLO bytes
    honest (no gather scatter overhead in the roofline).
    """
    tk = ts[-1]
    out = []
    for img, t in zip(images, ts):
        if t == tk:
            out.append(img)
        else:
            rep = 1 << (tk - t)
            *lead, g, m, w = img.shape
            rep_img = jnp.broadcast_to(
                img[..., :, None, :, :], (*lead, g, rep, m, w)
            )
            out.append(rep_img.reshape(*lead, g * rep, m, w))
    return jnp.stack(out, axis=-4)


@functools.partial(
    jax.jit, static_argnames=("ts", "gmaxes", "capacity", "use_pallas")
)
def _intersect_k_batch(
    vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    images: Tuple[Tuple[jnp.ndarray, ...], ...],
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity: int,
    use_pallas,
):
    """One jit execution for a whole same-signature bucket of B queries.

    ``vals[i]``: B arrays of (2^{t_i}, gmax_i) int32; ``images[i]``: B arrays
    of (2^{t_i}, m, W).  The (B, …) stacking happens INSIDE the jit — the
    inputs are already device-resident DeviceSet rows, so stacking eagerly
    would cost 2k extra dispatches per call; fused here it is free.
    Returns (packed, r, n_surv, overflow) with a leading B axis each.
    """
    EXEC_COUNTERS["batch_traces"] += 1  # python side effect: trace-time only
    vals = tuple(jnp.stack(v) for v in vals)
    images = tuple(jnp.stack(im) for im in images)
    tk = ts[-1]
    G = 1 << tk
    B = vals[0].shape[0]
    imgs = _aligned_images(images, ts)                          # (B, k, G, m, W)
    passed = ops.bitmap_filter(imgs, use_pallas)                # (B, G)
    n_surv = passed.sum(axis=1)
    # survivor compaction without per-query nonzero: sort survivor positions
    # (non-survivors keyed G) so every row yields its first `capacity`
    # survivor indices, G-filled past the end — identical to
    # nonzero(size=capacity, fill_value=G) but trivially batched.
    pos = jnp.where(passed, jnp.arange(G, dtype=jnp.int32)[None, :], G)
    surv = jnp.sort(pos, axis=1)
    if capacity <= G:
        surv = surv[:, :capacity]
    else:
        surv = jnp.pad(surv, ((0, 0), (0, capacity - G)), constant_values=G)
    valid_row = surv < G
    surv_c = jnp.minimum(surv, G - 1)
    rows = jnp.arange(B)[:, None]
    base = vals[0][rows, surv_c >> (tk - ts[0])]                # (B, cap, g0)
    keep = valid_row[:, :, None] & (base != -1)
    for v, t in zip(vals[1:], ts[1:]):
        other = v[rows, surv_c >> (tk - t)]                     # (B, cap, gi)
        keep = keep & ops.group_match(base, other, use_pallas)
    r = keep.sum(axis=(1, 2))
    overflow = n_surv > capacity
    # pack result values and mask into one buffer (-1 = dropped) so the
    # host round-trip is a single transfer per bucket
    packed = jnp.where(keep, base, -1)
    return packed, r, n_surv, overflow


def _signature(sets: Sequence[DeviceSet]) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    return tuple(s.t for s in sets), tuple(s.gmax for s in sets)


def intersect_device_batch(
    queries: Sequence[Sequence[DeviceSet]],
    capacity: Optional[int] = None,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Intersect B same-signature queries in one jit execution each pass.

    Every query is a list of DeviceSets; all queries must share the shape
    signature ``(ts, gmaxes)`` after the (t, n)-sort — the exec layer's
    bucketing guarantees this.  Overflowing queries (survivors > capacity)
    are re-run as ONE enlarged subset pass at capacity G, where overflow is
    impossible — a single extra jit execution per bucket, never a cascade
    of doublings.

    The batch dim is quantized: B pads up to a power of two by repeating
    the first query's rows (references to the same device arrays — the only
    cost is the fused in-jit stack).  Without this every distinct
    (signature, B) pair — including every overflow-subset size — would be
    its own executable; with it the cache holds at most log2(B_max)
    executables per signature.  Padding rows are dropped before results
    materialize.

    Returns a list of (sorted result values, stats dict) in query order.
    """
    if not len(queries):
        return []
    ordered = [sorted(q, key=lambda s: (s.t, s.n)) for q in queries]
    ts, gmaxes = _signature(ordered[0])
    for q in ordered[1:]:
        assert _signature(q) == (ts, gmaxes), "bucket mixes shape signatures"
    G = 1 << ts[-1]
    cap = capacity or default_capacity(ts)
    results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
    active = list(range(len(ordered)))
    first_pass = True
    while active:
        b_tier = 1 << (len(active) - 1).bit_length()  # pad B to a pow2 tier
        rows = active + [active[0]] * (b_tier - len(active))
        vals = tuple(
            tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
        )
        images = tuple(
            tuple(ordered[i][j].images for i in rows) for j in range(len(ts))
        )
        EXEC_COUNTERS["batch_calls"] += 1
        if not first_pass:
            EXEC_COUNTERS["rerun_calls"] += 1
        packed, r, n_surv, overflow = _intersect_k_batch(
            vals, images, ts, gmaxes, cap, use_pallas
        )
        packed_h, r_h, n_surv_h, over_h = jax.device_get(
            (packed, r, n_surv, overflow)
        )
        rerun = []
        for row, qi in enumerate(active):
            if over_h[row]:
                rerun.append(qi)
                continue
            row_vals = packed_h[row].ravel()
            out = row_vals[row_vals != -1]
            results[qi] = (
                np.sort(out.astype(np.uint32)),
                {
                    "group_tuples": G,
                    "tuples_survived": int(n_surv_h[row]),
                    "capacity": cap,
                    "r": int(r_h[row]),
                    "batch_size": len(active),
                },
            )
        active = rerun
        cap = G  # rare path: one re-run of the overflow subset, never more
        first_pass = False
    return results  # type: ignore[return-value]


def intersect_device(
    sets: Sequence[DeviceSet],
    capacity: Optional[int] = None,
    use_pallas="auto",
):
    """Intersect k device sets; returns (values, count) on host + stats.

    A batch of one through :func:`intersect_device_batch` — single queries
    and bucketed batches share the same jit cache (keyed additionally by B).
    """
    (result, stats), = intersect_device_batch(
        [list(sets)], capacity=capacity, use_pallas=use_pallas
    )
    return result, stats


def pow2_tiers(up_to: int) -> Tuple[int, ...]:
    """All power-of-two batch tiers ``(1, 2, 4, …, up_to)``.

    Warming these covers every partial-flush size in ``[1, up_to]`` (the
    executor pads B up to the next power of two), so a front-end with
    ``flush_tier = up_to`` compiles nothing at serve time.
    """
    assert up_to >= 1 and (up_to & (up_to - 1)) == 0, "up_to must be pow2"
    tiers, b = [], 1
    while b <= up_to:
        tiers.append(b)
        b <<= 1
    return tuple(tiers)


def warm_executables(
    representatives: Sequence[Sequence[DeviceSet]],
    b_tiers: Sequence[int] = (1,),
    capacity: Optional[int] = None,
    use_pallas="auto",
) -> int:
    """Pre-trace the bucketed pipeline so first live requests don't compile.

    ``representatives`` holds ONE query row (list of DeviceSets) per shape
    signature worth warming — typically the top-K signatures of a sample
    workload, extracted at index-build time.  For each row and each batch
    tier ``b`` in ``b_tiers`` the row is replicated ``b`` times and pushed
    through :func:`intersect_device_batch`, populating the jit cache for the
    ``(ShapeSig, B-tier)`` executable that a live bucket of up to ``b``
    queries will hit (the executor pads B up to a power of two, so warming
    tier ``b`` covers every partial flush of size in ``(b/2, b]``).

    Results are discarded — this warms the *compile* cache, not the result
    cache.  Increments ``EXEC_COUNTERS["warm_executions"]`` once per
    (row, tier) execution; the underlying ``batch_calls`` / ``batch_traces``
    bumps happen at build time, before serving counters are read.

    Returns the number of pipeline executions issued.
    """
    issued = 0
    for row in representatives:
        for b in b_tiers:
            assert b >= 1 and (b & (b - 1)) == 0, "b_tiers must be powers of two"
            intersect_device_batch(
                [list(row)] * b, capacity=capacity, use_pallas=use_pallas
            )
            EXEC_COUNTERS["warm_executions"] += 1
            issued += 1
    return issued


def warm_from_plans(plans, get_set, top_k: int = 8,
                    b_tiers: Sequence[int] = (1,), use_pallas="auto"):
    """Shared warming policy over already-planned queries.

    Counts device-routed shape signatures in ``plans`` (objects with
    ``.algorithm`` / ``.sig`` / ``.terms`` — i.e. ``exec.plan.QueryPlan``),
    picks the ``top_k`` most frequent, and pre-traces one representative
    row per signature at every batch tier in ``b_tiers`` via
    :func:`warm_executables`.  ``get_set`` maps a planned term to its
    DeviceSet.  Returns the warmed signatures, most frequent first.
    """
    from collections import Counter

    freq = Counter(p.sig for p in plans if p.algorithm == "device")
    rep = {}
    for p in plans:
        if p.algorithm == "device" and p.sig not in rep:
            rep[p.sig] = [get_set(t) for t in p.terms]
    warmed = [sig for sig, _ in freq.most_common(top_k)]
    warm_executables([rep[sig] for sig in warmed], b_tiers=b_tiers,
                     use_pallas=use_pallas)
    return warmed


def clear_exec_jit_cache() -> None:
    """Drop every compiled executable of the bucketed pipeline.

    Test hook: makes "warming traces, serving doesn't" assertions
    deterministic regardless of what earlier tests compiled (the jit cache
    is process-global).  No-op if the jax version lacks ``clear_cache``.
    """
    clear = getattr(_intersect_k_batch, "clear_cache", None)
    if clear is not None:
        clear()


# --------------------------------------------------------------------------
# shard_map distribution over the z-prefix space
# --------------------------------------------------------------------------

def intersect_sharded(
    sets: Sequence[DeviceSet],
    mesh: Mesh,
    axis: str = "model",
    capacity_per_shard: int = 256,
    use_pallas=False,
):
    """Zero-communication sharded intersection.

    Every set's group arrays are sharded along z over ``axis``.  Alignment
    (z_i = z_k >> shift) maps a shard's z_k range into the *same* shard's
    z_i range whenever n_shards <= 2^{t_1} — guaranteed by construction for
    corpus-scale sets.  Phase 1+2 run locally per shard; per-shard result
    buffers are returned still sharded (callers all-gather only the final
    compact results, never the posting data).
    """
    sets = sorted(sets, key=lambda s: s.t)
    n_shards = mesh.shape[axis]
    ts = tuple(s.t for s in sets)
    assert (1 << ts[0]) % n_shards == 0, "smallest set must split over shards"
    vals = tuple(s.vals for s in sets)
    images = tuple(s.images for s in sets)
    tk = ts[-1]

    from jax.experimental.shard_map import shard_map

    def local_fn(*flat):
        lvals, limages = flat[: len(sets)], flat[len(sets):]
        G_local = limages[-1].shape[0]
        imgs = _aligned_images(limages, ts)
        passed = ops.bitmap_filter(imgs, use_pallas)
        n_surv = passed.sum()
        surv = jnp.nonzero(passed, size=capacity_per_shard, fill_value=G_local)[0]
        valid = surv < G_local
        surv_c = jnp.minimum(surv, G_local - 1)
        base = lvals[0][surv_c >> (tk - ts[0])]
        keep = valid[:, None] & (base != -1)
        for v, t in zip(lvals[1:], ts[1:]):
            other = v[surv_c >> (tk - t)]
            keep = keep & ops.group_match(base, other, use_pallas)
        # local padded results; -1 where dropped
        out = jnp.where(keep, base, -1)
        return out, n_surv[None], passed.sum()[None]

    in_specs = tuple([P(axis)] * (2 * len(sets)))
    out_specs = (P(axis), P(axis), P(axis))
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    out, n_surv, _ = fn(*vals, *images)
    return out, n_surv


class BatchedEngine:
    """Corpus-level engine: name -> DeviceSet, query bucketing, jit reuse."""

    def __init__(self, use_pallas="auto"):
        self.sets = {}
        self.use_pallas = use_pallas

    def add(self, name: str, idx: PrefixIndex) -> None:
        self.sets[name] = DeviceSet.from_host(idx)

    def query(self, names: Sequence[str], capacity: Optional[int] = None):
        dsets = [self.sets[n] for n in names]
        return intersect_device(dsets, capacity=capacity, use_pallas=self.use_pallas)

    def query_many(self, queries: Sequence[Sequence[str]]):
        """Plan -> bucket by shape signature -> one jit execution per bucket
        -> scatter back in request order.  Returns [(values, stats), ...]."""
        from ..exec.batch import execute_name_queries

        return execute_name_queries(self.sets, queries, use_pallas=self.use_pallas)

    def warm(self, sample_queries: Sequence[Sequence[str]], top_k: int = 8,
             b_tiers: Sequence[int] = (1,)):
        """Compile-cache warming from a name-keyed sample workload
        (index-build time).  Plans the sample and delegates the policy to
        :func:`warm_from_plans`.  Returns the warmed
        :class:`~repro.exec.plan.ShapeSig`\\ s, most frequent first.
        """
        from ..exec.plan import plan_query

        plans = [
            plan_query(self.sets, q, hashbin_ratio=float("inf"), device=True)
            for q in sample_queries
        ]
        return warm_from_plans(plans, lambda t: self.sets[t], top_k=top_k,
                               b_tiers=b_tiers, use_pallas=self.use_pallas)
