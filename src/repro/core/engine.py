"""Device-resident batched intersection engine (the paper's system on TPU).

Module map (the device path, bottom-up):

  kernels/            bitmap_filter / group_match Pallas kernels + jnp oracles;
                      both accept a leading batch axis folded into the grid.
  core/engine.py      this file — DeviceSet mirrors, the jit'd batched
                      two-phase pipeline (``_intersect_k_batch``), the
                      bucket executor entry point ``intersect_device_batch``,
                      and the z-sharded variant ``intersect_sharded``.
  exec/plan.py        query normalization (term dedup, sort by (t, n),
                      hashbin-vs-device policy) into shape-keyed QueryPlans.
  exec/batch.py       groups QueryPlans by shape signature, stacks DeviceSet
                      rows, and drives ``intersect_device_batch`` — one jit
                      execution per bucket plus rare overflow re-runs.
  serve/search.py     SearchEngine: plan -> bucket -> execute -> scatter.

Pre-processed sets (``partition.PrefixIndex``) are mirrored to the device as
dense arrays; intersections run as two fused phases:

  phase 1 (filter):  gather prefix-aligned images, k-way AND, m-way test
                     (kernels.ops.bitmap_filter — the paper's Alg. 5 line 3)
  phase 2 (recover): compact survivors to a static capacity, all-pairs match
                     of the raw groups (kernels.ops.group_match)

Static shapes everywhere: the survivor set is compacted into a fixed
``capacity`` buffer (per-query overflow flags returned; the executor re-runs
the rare overflowing subset once at full capacity).  This preserves the
paper's work-saving — the expensive phase 2 runs on ``capacity ≈
E[survivors]`` group tuples instead of all ``G`` — inside an XLA-compatible
regime.

Multi-query batching: the online stage is embarrassingly parallel across
queries, and real query logs concentrate on a handful of shape signatures
``(k, ts, gmaxes, capacity)`` (the paper's workload model: 68% 2-word, 23%
3-word queries).  ``_intersect_k_batch`` therefore takes ``(B, …)`` stacked
arrays and runs a whole same-signature bucket in ONE jit execution; the
single-query ``intersect_device`` is just a batch of one through the same
pipeline, so both paths share one compile cache.

Distribution: :func:`intersect_sharded_batch` shard_maps the z-prefix space
over a 1-D device mesh.  Because every set is partitioned by the *same*
permutation ``g`` (Theorem 3.7's alignment), equal z-range blocks of every
set land on the same shard and both phases are entirely local; only the
per-shard result buffers are concatenated at the end.  The paper's
partitioning function doubles as the sharding function.  The sharded path
is the same ``(B, …)`` bucketed pipeline as :func:`intersect_device_batch`
— sort-compaction survivor selection, packed single-transfer results,
per-(query, shard) overflow flags with ONE enlarged re-run pass — so
sharded results are bit-identical to the unsharded and host paths.
:func:`intersect_sharded` is a batch of one through it.

2-D distribution: :func:`intersect_mesh2d_batch` generalizes the 1-D case
to a ``Mesh(("data", "shard"))`` built by :func:`make_mesh2d` — the batch
axis splits over ``data`` (each replica row holds a full copy of the
posting mirrors and processes ``B / replicas`` queries) while the z-prefix
space splits over ``shard`` within every replica, exactly as in the 1-D
path.  The data axis is driven host-side: each row's batch slice is ONE
async dispatch of the row-local pipeline (the 1-D z-sharded shard_map over
the row's submesh, or the plain single-device pipeline when ``shards ==
1``), and all rows are collected at a single point — so rows overlap in
flight and no collective ever crosses the data axis.  (A single 2-D
shard_map was measured 3-10x slower here: GSPMD materializes the in-jit
batch stack replicated on every row before slicing it.)  Both phases stay
communication-free; the same per-(query, shard) overflow flags drive the
same single enlarged re-run, so 2-D results are bit-identical to the 1-D,
unsharded, and host paths.  The topology layer (``exec/topology.py``) owns
mesh construction, replica placement, and the per-replica load balancer
that spreads single-device buckets across replica rows.

Asynchronous dispatch: every pipeline is split into a non-blocking
``dispatch_*_batch`` half (jit call issued; JAX async dispatch returns
device arrays that are futures) and a blocking :meth:`PendingBatch.collect`
half (deferred ``jax.device_get`` + overflow re-runs + host
post-processing), with ``intersect_*_batch`` kept as the synchronous
composition of the two.  The exec layer (``exec/batch.py``) builds its
:class:`InFlightBucket` window on these halves so *independent buckets*
overlap on the device — the serving-layer throughput win the per-bucket
row overlap above cannot provide.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops, setops
from .partition import PrefixIndex

__all__ = [
    "DeviceSet",
    "ReplicatedDeviceSet",
    "DATA_AXIS",
    "SHARD_AXIS",
    "SHARD_MIN_G",
    "default_capacity",
    "default_capacity_per_shard",
    "PendingBatch",
    "dispatch_device_batch",
    "dispatch_mesh2d_batch",
    "dispatch_sharded_batch",
    "intersect_device",
    "intersect_device_batch",
    "intersect_mesh2d_batch",
    "intersect_sharded",
    "intersect_sharded_batch",
    "default_k_tier",
    "dispatch_count_batch",
    "dispatch_count_sharded_batch",
    "dispatch_count_mesh2d_batch",
    "intersect_count_batch",
    "intersect_count_sharded_batch",
    "intersect_count_mesh2d_batch",
    "dispatch_expr_batch",
    "dispatch_expr_sharded_batch",
    "dispatch_expr_mesh2d_batch",
    "intersect_expr_batch",
    "intersect_expr_sharded_batch",
    "intersect_expr_mesh2d_batch",
    "expr_total_width",
    "default_expr_capacity",
    "default_expr_capacity_per_shard",
    "make_mesh2d",
    "make_shard_mesh",
    "bucket_hlo_text",
    "pow2_tiers",
    "set_sort_key",
    "warm_executables",
    "warm_from_plans",
    "clear_exec_jit_cache",
    "BatchedEngine",
    "EXEC_COUNTERS",
    "ExecCounters",
    "reset_exec_counters",
]

SHARD_AXIS = "shard"  # canonical name of the z-sharding mesh axis
DATA_AXIS = "data"    # canonical name of the data-parallel (replica) axis

# Default sharding threshold: route a query z-sharded only when its largest
# set has at least this many group tuples.  2^12 groups ≈ a 65k-element set
# at w=256 — below that, a single device finishes a bucket faster than the
# mesh can dispatch it.  (Single source of truth; exec.plan re-exports it.)
SHARD_MIN_G = 4096

class ExecCounters(dict):
    """Telemetry for the batched device path and the serving front-end.

    A plain ``dict`` subclass so existing ``EXEC_COUNTERS["key"]`` reads and
    writes keep working; ``reset()`` zeroes every counter (test setup calls
    it autouse so counter-asserting tests are order-independent).

    Keys:

    - ``batch_calls``     jit *executions* of the bucketed pipeline (what
      per-query dispatch would make O(#queries) and bucketing makes
      O(#signatures)).
    - ``batch_traces``    actual retraces (compiles) of the pipeline — one
      per distinct ``(ShapeSig, B-tier)`` pair over the process lifetime.
    - ``rerun_calls``     overflow re-run passes (survivors > capacity).
    - ``sharded_calls`` / ``sharded_traces`` / ``sharded_rerun_calls`` —
      the same three for the z-sharded pipeline
      (:func:`intersect_sharded_batch`); kept separate so a mixed workload
      reports single-device and mesh executions independently.
    - ``mesh2d_calls`` / ``mesh2d_traces`` / ``mesh2d_rerun_calls`` — the
      same three for the 2-D data x shard pipeline
      (:func:`intersect_mesh2d_batch`); one ``mesh2d_calls`` per bucket
      *pass* (each pass issues ``replicas`` concurrent row executions,
      counted separately in ``mesh2d_row_dispatches``).
    - ``replica_dispatches`` — single-device buckets routed to a replica
      row by the topology's load balancer (``exec/topology.py``).
    - ``inflight_dispatches`` — buckets dispatched asynchronously through
      ``exec/batch.py::dispatch_bucket`` (one per :class:`InFlightBucket`
      handle, whether or not anything overlapped).
    - ``inflight_collects`` — in-flight buckets torn down (first collect
      completion OR failure; one-shot per bucket).  After any drain,
      ``inflight_dispatches == inflight_collects`` — the
      no-lost-bucket invariant the loadgen soak test asserts.
    - ``collect_us`` — cumulative microseconds spent in the blocking
      *collect* phase (``jax.device_get`` wait + overflow re-runs + host
      post-processing); dispatch-to-collect overlap shows up as wall time
      that is NOT in this counter.
    - ``overlap_high_water`` — the maximum number of buckets that were
      simultaneously in flight (dispatched, not yet collected) since the
      last reset: ``>= 2`` is the signature of real dispatch/collect
      overlap, ``<= 1`` means execution was effectively synchronous.
    - ``warm_executions`` pipeline executions issued by compile warming
      (:func:`warm_executables`) at index-build time.
    - ``result_cache_hits`` / ``result_cache_misses`` — lookups in the
      normalized-plan result cache (``exec/cache.py``).
    - ``tier_flushes`` / ``deadline_flushes`` — admission-queue bucket
      flushes by cause: reached the full power-of-two tier vs. the oldest
      query's deadline budget expired (``serve/admission.py``).
    - ``tickets_resolved`` / ``queue_wait_us`` / ``deadline_violations`` —
      per-ticket wait telemetry stamped at resolution
      (``serve/admission.py::Ticket``): tickets resolved (value or error),
      cumulative queue wait in integer microseconds, and resolutions whose
      wait exceeded the ticket's own deadline budget (>0.5 us past it —
      the virtual-clock float-epsilon used by the admission benchmark).
      These are what the SLO-burn load harness reads.
    - ``flusher_wakeups`` — background flusher thread wake-ups
      (``serve/search.py::AsyncSearchEngine.start``): each sleep that ended
      (deadline due, submit wake, or idle timeout) and led to a pump check.
    - ``adaptive_promotions`` / ``adaptive_demotions`` — capacity-tier
      increases / decreases learned by ``exec/adaptive.py::CapacityModel``
      (demotions happen when time-decayed survivor windows show the
      workload drifted down).  ``adaptive_overflow_saved`` — executions
      where the learned tier absorbed survivors that would have overflowed
      the static G/4 rule (i.e. re-runs the model eliminated).
    - ``expr_calls`` / ``expr_traces`` / ``expr_rerun_calls`` — the same
      call/compile/overflow-re-run triple for the boolean **expression**
      pipeline (``_eval_expr_batch`` and its sharded / 2-D twins — all
      three report under one family, like the flat pipeline's per-path
      split but coarser, since expression traffic is one workload).
    - ``subexpr_cache_hits`` / ``subexpr_cache_misses`` — lookups of
      canonicalized *sub*expression entries in the result cache
      (``exec/cache.py::ResultCache.get_sub``); ``subexpr_cache_stores``
      — sub-buffers stored after expression bucket execution;
      ``subexpr_host_merges`` — expression queries answered entirely
      host-side by merging cached subexpression values (zero device
      work).
    - ``count_calls`` / ``count_traces`` — jit executions / retraces of
      the count-only suggestion pipeline (``_intersect_count_batch`` and
      its z-sharded / 2-D twins; one family — there is no overflow re-run
      to count, the count path has no survivor buffer at all).
    - ``suggest_prefilter_in`` / ``suggest_prefilter_kept`` — corpus
      candidates considered / kept by the hashbin candidate pre-filter
      (``exec/candidates.py::CandidateIndex``); the ratio is the
      pre-filter's device-work saving on the suggest workload.
    - ``dispatch_failures`` — buckets whose dispatch or collect raised
      (the balancer releases the weight; this counter is the telemetry
      trace the release alone never left).  Mirrored as a typed counter
      in ``repro.obs``.

    Counters are process-global.  Writes and snapshots serialize on one
    internal lock: plain ``EXEC_COUNTERS["key"] += n`` sites keep working
    (each read and write is individually consistent; the read-modify-write
    itself can still lose a concurrent bump — last-write-wins noise, as
    ever), while :meth:`bump` / :meth:`bump_many` do the whole
    read-modify-write under the lock and :meth:`snapshot` copies every key
    under the same lock.  The contract: keys that must stay mutually
    consistent across a concurrent snapshot (e.g. the
    ``tickets_resolved`` / ``queue_wait_us`` pair) are updated through one
    ``bump_many`` call, and readers use ``snapshot()`` instead of key-at-
    a-time reads — a snapshot can then never observe one of the pair
    without the other.
    """

    _KEYS = (
        "batch_calls", "batch_traces", "rerun_calls",
        "sharded_calls", "sharded_traces", "sharded_rerun_calls",
        "mesh2d_calls", "mesh2d_traces", "mesh2d_rerun_calls",
        "mesh2d_row_dispatches", "replica_dispatches",
        "inflight_dispatches", "inflight_collects",
        "collect_us", "overlap_high_water",
        "warm_executions",
        "result_cache_hits", "result_cache_misses",
        "tier_flushes", "deadline_flushes",
        "tickets_resolved", "queue_wait_us", "deadline_violations",
        "flusher_wakeups",
        "adaptive_promotions", "adaptive_demotions",
        "adaptive_overflow_saved",
        "expr_calls", "expr_traces", "expr_rerun_calls",
        "subexpr_cache_hits", "subexpr_cache_misses",
        "subexpr_cache_stores", "subexpr_host_merges",
        "count_calls", "count_traces",
        "suggest_prefilter_in", "suggest_prefilter_kept",
        "dispatch_failures",
    )

    def __init__(self):
        super().__init__({k: 0 for k in self._KEYS})
        # Not reentrant: locked methods below write via dict.__setitem__
        # directly so they never recurse into the locking override.
        self._lock = threading.Lock()

    def __setitem__(self, key, value) -> None:
        with self._lock:
            dict.__setitem__(self, key, value)

    def bump(self, key: str, n: int = 1) -> None:
        """Atomic read-modify-write increment of one counter."""
        with self._lock:
            dict.__setitem__(self, key, dict.__getitem__(self, key) + n)

    def bump_many(self, deltas: dict) -> None:
        """Atomically apply several increments — no snapshot can observe
        a strict subset of them."""
        with self._lock:
            for key, n in deltas.items():
                dict.__setitem__(self, key, dict.__getitem__(self, key) + n)

    def snapshot(self) -> dict:
        """A consistent copy of every counter, taken under the write
        lock (the fix for key-at-a-time reads tearing mid-flush)."""
        with self._lock:
            return {k: dict.__getitem__(self, k) for k in self._KEYS}

    def reset(self) -> None:
        with self._lock:
            for key in self._KEYS:
                dict.__setitem__(self, key, 0)


EXEC_COUNTERS = ExecCounters()


def reset_exec_counters() -> None:
    """Back-compat alias for :meth:`ExecCounters.reset`."""
    EXEC_COUNTERS.reset()


@dataclasses.dataclass(frozen=True)
class DeviceSet:
    """Device mirror of a PrefixIndex (sentinel-padded; mask implicit).

    ``gmax`` is quantized up to a power of two on mirroring: the exact
    per-set max group size is what it is on the host, but on the device it
    is a *static shape* — leaving it exact would give nearly every set its
    own shape signature and defeat bucketed batching.  Power-of-two tiers
    cost at most 2x padding on the tiny phase-2 tiles and collapse the
    signature space to a handful of buckets.
    """

    t: int
    gmax: int
    m: int
    w: int
    n: int
    vals: jnp.ndarray     # (2^t, gmax) int32 (original values; -1 padding)
    images: jnp.ndarray   # (2^t, m, W) uint32

    @classmethod
    def from_host(cls, idx: PrefixIndex) -> "DeviceSet":
        assert int(idx.values.max(initial=0)) < 0xFFFFFFFF, "sentinel collision"
        gmax = gmax_tier(idx.gmax)
        padded = np.pad(
            idx.padded_vals, ((0, 0), (0, gmax - idx.gmax)),
            constant_values=np.uint32(0xFFFFFFFF),
        )
        vals = jax.lax.bitcast_convert_type(jnp.asarray(padded), jnp.int32)
        return cls(
            t=idx.t, gmax=gmax, m=idx.family.m, w=idx.w, n=idx.n,
            vals=vals, images=jnp.asarray(idx.images),
        )

    def shardable(self, n_shards: int) -> bool:
        """True when the z axis splits evenly over ``n_shards`` — the
        Theorem 3.7 alignment condition (every shard holds at least one
        whole z-group of this set)."""
        return n_shards >= 1 and (1 << self.t) % n_shards == 0

    def shard(self, mesh: Mesh, axis: str = SHARD_AXIS) -> "DeviceSet":
        """Z-sharded mirror: both arrays placed with their leading (z) axis
        partitioned over ``mesh[axis]``.  Built once at index time so the
        sharded pipeline never pays a per-call reshard; the unsharded
        mirror stays as-is for single-device buckets.  The 2-D topology
        builds one such mirror per replica row (on the row's 1-D submesh)
        — see :class:`ReplicatedDeviceSet`."""
        assert self.shardable(mesh.shape[axis]), (
            f"2^{self.t} z-groups do not split over {mesh.shape[axis]} shards"
        )
        return dataclasses.replace(
            self,
            vals=jax.device_put(self.vals, NamedSharding(mesh, P(axis, None))),
            images=jax.device_put(
                self.images, NamedSharding(mesh, P(axis, None, None))),
        )

    def place(self, device) -> "DeviceSet":
        """Single-device mirror committed to ``device``.

        The topology layer uses this to build one plain mirror per replica
        row, so balancer-dispatched single-device buckets execute on their
        assigned replica without a per-call transfer."""
        return dataclasses.replace(
            self,
            vals=jax.device_put(self.vals, device),
            images=jax.device_put(self.images, device),
        )


@dataclasses.dataclass(frozen=True)
class ReplicatedDeviceSet:
    """Per-replica-row mirrors of one set — the 2-D topology's unit of
    replication.

    ``rows[r]`` is replica row ``r``'s mirror: z-sharded over the row's
    1-D submesh when the topology has ``shards > 1``, committed to the
    row's anchor device otherwise.  Exposes the planner-visible metadata
    (``t`` / ``gmax`` / ``n``) of row 0 — identical on every row — so the
    shared ``(t, n)`` sort key and the shape-signature check treat it
    exactly like a :class:`DeviceSet`.
    """

    rows: Tuple[DeviceSet, ...]

    def row(self, r: int) -> DeviceSet:
        return self.rows[r]

    @property
    def t(self) -> int:
        return self.rows[0].t

    @property
    def gmax(self) -> int:
        return self.rows[0].gmax

    @property
    def n(self) -> int:
        return self.rows[0].n


def set_sort_key(s) -> Tuple[int, int]:
    """THE canonical set ordering key, ``(t, n)``: ascending partition depth
    (prefix alignment needs t ascending) with set size breaking ties, so the
    base set (index 0 after sorting) is the smallest.  Every layer — the
    planner (which appends the term as a final tie-break), the batched
    executor, and the sharded pipeline — must sort with this one helper;
    diverging keys let equal-``t`` sets pick a different base set than the
    plan's cache key and stats assume."""
    return (s.t, s.n)


def gmax_tier(gmax: int) -> int:
    """Static-shape tier for a set's max group size: next power of two
    (>= 8).  Device mirrors pad to this, and the planner keys shape
    signatures by it, so host-exact gmaxes never fragment the buckets."""
    return 1 << max(3, (int(gmax) - 1).bit_length())


def default_capacity(ts: Tuple[int, ...]) -> int:
    """Survivor-buffer (capacity) tier for a query shape.

    capacity ≈ E[survivors]: non-empty-intersection groups ≲ r_max + the
    false-positive rate * G; G/4 + floor is conservative for the paper's
    r << n regime, and preserves the work-saving — phase 2 runs on capacity
    group tuples, not all G.  Dense queries (frequent-term pairs, survivors
    ≈ G) overflow and are re-run once at full capacity by the executor.
    Deterministic in ``ts`` so it can key shape buckets."""
    return max(64, (1 << ts[-1]) // 4)


def default_capacity_per_shard(ts: Tuple[int, ...], n_shards: int,
                               capacity: Optional[int] = None) -> int:
    """Per-shard survivor-buffer tier for the sharded pipeline.

    The whole-query capacity budget — ``capacity`` when given (e.g. a
    learned ``ShapeSig.capacity_tier`` from ``exec/adaptive.py``), else
    :func:`default_capacity` — divided over the shards (survivors
    distribute ~uniformly because ``g`` randomizes z), floored, and never
    beyond the local group count ``G / n_shards`` (overflow past that is
    impossible).  Deterministic in ``(ts, n_shards, capacity)`` so
    ``(ShapeSig, shards)`` fully determines the executable's shapes.
    """
    local_g = (1 << ts[-1]) // n_shards
    whole = default_capacity(ts) if capacity is None else int(capacity)
    return min(local_g, max(16, whole // n_shards))


def _aligned_images(images: Sequence[jnp.ndarray], ts: Tuple[int, ...]) -> jnp.ndarray:
    """Stack per-set images aligned by prefix (z_i = z_k >> (t_k - t_i)):
    (G_i, m, W) each -> (k, G, m, W), or (B, G_i, m, W) -> (B, k, G, m, W).

    The largest set's images are used in place; the others are gathered.  A
    gather of 2^{t_k - t_i} repeated rows is a broadcast in disguise — XLA
    lowers it to one; we reshape+broadcast explicitly to keep HLO bytes
    honest (no gather scatter overhead in the roofline).
    """
    tk = ts[-1]
    out = []
    for img, t in zip(images, ts):
        if t == tk:
            out.append(img)
        else:
            rep = 1 << (tk - t)
            *lead, g, m, w = img.shape
            rep_img = jnp.broadcast_to(
                img[..., :, None, :, :], (*lead, g, rep, m, w)
            )
            out.append(rep_img.reshape(*lead, g * rep, m, w))
    return jnp.stack(out, axis=-4)


@functools.partial(
    jax.jit,
    static_argnames=("ts", "gmaxes", "capacity", "use_pallas",
                     "trace_counter"),
)
def _intersect_k_batch(
    vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    images: Tuple[Tuple[jnp.ndarray, ...], ...],
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity: int,
    use_pallas,
    trace_counter: str = "batch_traces",
):
    """One jit execution for a whole same-signature bucket of B queries.

    ``vals[i]``: B arrays of (2^{t_i}, gmax_i) int32; ``images[i]``: B arrays
    of (2^{t_i}, m, W).  The (B, …) stacking happens INSIDE the jit — the
    inputs are already device-resident DeviceSet rows, so stacking eagerly
    would cost 2k extra dispatches per call; fused here it is free.
    Returns (packed, r, n_surv, overflow) with a leading B axis each.
    ``trace_counter`` names the retrace telemetry bucket — the 2-D
    pipeline's single-device rows pass ``"mesh2d_traces"`` so its compiles
    are reported under the subsystem that owns them (being static, it also
    keeps the two paths' executables in separate cache entries).
    """
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    vals = tuple(jnp.stack(v) for v in vals)
    images = tuple(jnp.stack(im) for im in images)
    tk = ts[-1]
    G = 1 << tk
    B = vals[0].shape[0]
    imgs = _aligned_images(images, ts)                          # (B, k, G, m, W)
    passed = ops.bitmap_filter(imgs, use_pallas)                # (B, G)
    n_surv = passed.sum(axis=1)
    # survivor compaction without per-query nonzero: sort survivor positions
    # (non-survivors keyed G) so every row yields its first `capacity`
    # survivor indices, G-filled past the end — identical to
    # nonzero(size=capacity, fill_value=G) but trivially batched.
    pos = jnp.where(passed, jnp.arange(G, dtype=jnp.int32)[None, :], G)
    surv = jnp.sort(pos, axis=1)
    if capacity <= G:
        surv = surv[:, :capacity]
    else:
        surv = jnp.pad(surv, ((0, 0), (0, capacity - G)), constant_values=G)
    valid_row = surv < G
    surv_c = jnp.minimum(surv, G - 1)
    rows = jnp.arange(B)[:, None]
    base = vals[0][rows, surv_c >> (tk - ts[0])]                # (B, cap, g0)
    keep = valid_row[:, :, None] & (base != -1)
    for v, t in zip(vals[1:], ts[1:]):
        other = v[rows, surv_c >> (tk - t)]                     # (B, cap, gi)
        keep = keep & ops.group_match(base, other, use_pallas)
    r = keep.sum(axis=(1, 2))
    overflow = n_surv > capacity
    # pack result values and mask into one buffer (-1 = dropped) so the
    # host round-trip is a single transfer per bucket
    packed = jnp.where(keep, base, -1)
    return packed, r, n_surv, overflow


def _signature(sets: Sequence[DeviceSet]) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    return tuple(s.t for s in sets), tuple(s.gmax for s in sets)


@dataclasses.dataclass
class PendingBatch:
    """In-flight handle for one dispatched bucket pass.

    JAX dispatch is asynchronous: the jit call returns device arrays that
    are *futures* — compute proceeds while the host does other work, and
    only ``jax.device_get`` blocks.  ``dispatch_*_batch`` issues the first
    pass and wraps its handles here; :meth:`collect` performs the deferred
    transfer, the host-side result processing, and the (rare) overflow
    re-run passes, returning exactly what the synchronous
    ``intersect_*_batch`` returns.  Overflow re-runs issue new jit calls
    from inside collect — they resolve against the already-captured
    DeviceSet rows, so collect never needs the dispatcher's locks.

    ``handles`` is the first pass's raw output pytree; :meth:`is_ready`
    polls it without blocking (a non-blocking peek for schedulers that
    want to collect completed buckets first).  :meth:`collect` is
    memoized — calling it twice returns the same result list.
    """

    n_queries: int
    handles: object = None
    _collect: Optional[Callable[[], List[Tuple[np.ndarray, Dict]]]] = None
    _results: Optional[List[Tuple[np.ndarray, Dict]]] = None

    def is_ready(self) -> bool:
        """True when every first-pass device buffer has materialized (a
        collect would not block on the transfer; overflow re-runs can
        still add work).  Conservatively True for handle types without
        ``is_ready`` (e.g. already-fetched results)."""
        if self._results is not None:
            return True
        for leaf in jax.tree_util.tree_leaves(self.handles):
            ready = getattr(leaf, "is_ready", None)
            if ready is not None and not ready():
                return False
        return True

    def collect(self) -> List[Tuple[np.ndarray, Dict]]:
        """Block for the results: device transfer + overflow re-runs +
        host post-processing.  Returns [(sorted values, stats), ...] in
        query order (memoized)."""
        if self._results is None:
            self._results = self._collect()
            self._collect = None  # drop closed-over device handles
            self.handles = None
        return self._results


def dispatch_device_batch(
    queries: Sequence[Sequence[DeviceSet]],
    capacity: Optional[int] = None,
    use_pallas="auto",
) -> PendingBatch:
    """Issue the first pass of a same-signature bucket without blocking.

    The asynchronous half of :func:`intersect_device_batch`: validates the
    bucket, issues ONE jit execution for the first pass (JAX returns
    immediately — the arrays are futures), and returns a
    :class:`PendingBatch` whose :meth:`~PendingBatch.collect` finishes the
    job (transfer, overflow re-runs, result assembly).  Counter semantics
    are unchanged: ``batch_calls`` per pass (the first bumps at dispatch
    time, re-run passes bump inside collect), ``rerun_calls`` per overflow
    pass.
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    ordered = [sorted(q, key=set_sort_key) for q in queries]
    ts, gmaxes = _signature(ordered[0])
    for q in ordered[1:]:
        assert _signature(q) == (ts, gmaxes), "bucket mixes shape signatures"
    G = 1 << ts[-1]

    def issue(active: List[int], cap: int):
        b_tier = 1 << (len(active) - 1).bit_length()  # pad B to a pow2 tier
        rows = active + [active[0]] * (b_tier - len(active))
        vals = tuple(
            tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
        )
        images = tuple(
            tuple(ordered[i][j].images for i in rows) for j in range(len(ts))
        )
        EXEC_COUNTERS["batch_calls"] += 1
        return _intersect_k_batch(vals, images, ts, gmaxes, cap, use_pallas)

    first_active = list(range(len(ordered)))
    first_cap = capacity or default_capacity(ts)
    first_handles = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap, handles = first_active, first_cap, first_handles
        while True:
            packed_h, r_h, n_surv_h, over_h = jax.device_get(handles)
            rerun = []
            for row, qi in enumerate(active):
                if over_h[row]:
                    rerun.append(qi)
                    continue
                row_vals = packed_h[row].ravel()
                out = row_vals[row_vals != -1]
                results[qi] = (
                    np.sort(out.astype(np.uint32)),
                    {
                        "group_tuples": G,
                        "tuples_survived": int(n_surv_h[row]),
                        "capacity": cap,
                        "r": int(r_h[row]),
                        "batch_size": len(active),
                    },
                )
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = G  # rare path: ONE re-run of the overflow subset at G
            EXEC_COUNTERS["rerun_calls"] += 1
            handles = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def intersect_device_batch(
    queries: Sequence[Sequence[DeviceSet]],
    capacity: Optional[int] = None,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Intersect B same-signature queries in one jit execution each pass.

    Every query is a list of DeviceSets; all queries must share the shape
    signature ``(ts, gmaxes)`` after the (t, n)-sort — the exec layer's
    bucketing guarantees this.  Overflowing queries (survivors > capacity)
    are re-run as ONE enlarged subset pass at capacity G, where overflow is
    impossible — a single extra jit execution per bucket, never a cascade
    of doublings.

    The batch dim is quantized: B pads up to a power of two by repeating
    the first query's rows (references to the same device arrays — the only
    cost is the fused in-jit stack).  Without this every distinct
    (signature, B) pair — including every overflow-subset size — would be
    its own executable; with it the cache holds at most log2(B_max)
    executables per signature.  Padding rows are dropped before results
    materialize.

    The synchronous composition of :func:`dispatch_device_batch` +
    :meth:`PendingBatch.collect` — callers that can overlap buckets use
    the two halves directly.

    Returns a list of (sorted result values, stats dict) in query order.
    """
    return dispatch_device_batch(
        queries, capacity=capacity, use_pallas=use_pallas
    ).collect()


def intersect_device(
    sets: Sequence[DeviceSet],
    capacity: Optional[int] = None,
    use_pallas="auto",
):
    """Intersect k device sets; returns (values, count) on host + stats.

    A batch of one through :func:`intersect_device_batch` — single queries
    and bucketed batches share the same jit cache (keyed additionally by B).
    """
    (result, stats), = intersect_device_batch(
        [list(sets)], capacity=capacity, use_pallas=use_pallas
    )
    return result, stats


def pow2_tiers(up_to: int) -> Tuple[int, ...]:
    """All power-of-two batch tiers ``(1, 2, 4, …, up_to)``.

    Warming these covers every partial-flush size in ``[1, up_to]`` (the
    executor pads B up to the next power of two), so a front-end with
    ``flush_tier = up_to`` compiles nothing at serve time.
    """
    assert up_to >= 1 and (up_to & (up_to - 1)) == 0, "up_to must be pow2"
    tiers, b = [], 1
    while b <= up_to:
        tiers.append(b)
        b <<= 1
    return tuple(tiers)


def bucket_hlo_text(
    queries: Sequence[Sequence[DeviceSet]],
    capacity: Optional[int] = None,
    use_pallas="auto",
) -> str:
    """Optimized (post-XLA) HLO text for one bucket's jit executable.

    Lowers and compiles ``_intersect_k_batch`` for the bucket exactly as
    :func:`dispatch_device_batch` would execute it (same signature, same
    pow2 B-tier padding, same capacity default) and returns the compiled
    module text — the input ``launch/hlo_analysis.py::analyze_hlo`` wants,
    so benchmarks can report analytical FLOP/byte summaries for the
    executable they actually measured.  Shares the process jit cache with
    live execution; tracing bumps ``EXEC_COUNTERS["batch_traces"]`` like
    any other trace (lower before measuring, or reset counters after).
    """
    assert len(queries), "need at least one query row to lower"
    ordered = [sorted(q, key=set_sort_key) for q in queries]
    ts, gmaxes = _signature(ordered[0])
    for q in ordered[1:]:
        assert _signature(q) == (ts, gmaxes), "bucket mixes shape signatures"
    cap = capacity or default_capacity(ts)
    b_tier = 1 << (len(ordered) - 1).bit_length()
    rows = list(range(len(ordered))) + [0] * (b_tier - len(ordered))
    vals = tuple(
        tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
    )
    images = tuple(
        tuple(ordered[i][j].images for i in rows) for j in range(len(ts))
    )
    lowered = _intersect_k_batch.lower(vals, images, ts, gmaxes, cap,
                                       use_pallas)
    return lowered.compile().as_text()


def warm_executables(
    representatives: Sequence[Sequence[DeviceSet]],
    b_tiers: Sequence[int] = (1,),
    capacity: Optional[int] = None,
    use_pallas="auto",
    mesh: Optional[Mesh] = None,
    axis: str = SHARD_AXIS,
    topology=None,
) -> int:
    """Pre-trace the bucketed pipeline so first live requests don't compile.

    ``representatives`` holds ONE query row (list of DeviceSets) per shape
    signature worth warming — typically the top-K signatures of a sample
    workload, extracted at index-build time.  For each row and each batch
    tier ``b`` in ``b_tiers`` the row is replicated ``b`` times and pushed
    through :func:`intersect_device_batch`, populating the jit cache for the
    ``(ShapeSig, B-tier)`` executable that a live bucket of up to ``b``
    queries will hit (the executor pads B up to a power of two, so warming
    tier ``b`` covers every partial flush of size in ``(b/2, b]``).

    With ``mesh`` set, the rows are pushed through
    :func:`intersect_sharded_batch` instead, warming the sharded
    ``(ShapeSig, B-tier, shards)`` executables — pass the z-sharded mirrors
    as representatives so the warmed executable sees serving-time shardings.
    With ``topology`` set (2-D), the rows warm
    :func:`intersect_mesh2d_batch` the same way — pass
    :class:`ReplicatedDeviceSet` mirrors; one warming execution covers
    every replica row's executable, since the 2-D pipeline dispatches all
    rows per pass.

    Results are discarded — this warms the *compile* cache, not the result
    cache.  Increments ``EXEC_COUNTERS["warm_executions"]`` once per
    (row, tier) execution; the underlying ``batch_calls`` / ``batch_traces``
    (or ``sharded_*``) bumps happen at build time, before serving counters
    are read.

    Returns the number of pipeline executions issued.
    """
    issued = 0
    for row in representatives:
        for b in b_tiers:
            assert b >= 1 and (b & (b - 1)) == 0, "b_tiers must be powers of two"
            if topology is not None:
                intersect_mesh2d_batch(
                    [list(row)] * b, topology, capacity_per_shard=capacity,
                    use_pallas=use_pallas,
                )
            elif mesh is not None:
                intersect_sharded_batch(
                    [list(row)] * b, mesh, axis=axis,
                    capacity_per_shard=capacity, use_pallas=use_pallas,
                )
            else:
                intersect_device_batch(
                    [list(row)] * b, capacity=capacity, use_pallas=use_pallas
                )
            EXEC_COUNTERS["warm_executions"] += 1
            issued += 1
    return issued


def warm_from_plans(plans, get_set, top_k: int = 8,
                    b_tiers: Sequence[int] = (1,), use_pallas="auto",
                    mesh: Optional[Mesh] = None, axis: str = SHARD_AXIS,
                    get_sharded_set=None, topology=None,
                    get_replica_set=None):
    """Shared warming policy over already-planned queries.

    Counts device-routed shape signatures in ``plans`` (objects with
    ``.algorithm`` / ``.sig`` / ``.terms`` — i.e. ``exec.plan.QueryPlan``),
    picks the ``top_k`` most frequent, and pre-traces one representative
    row per signature at every batch tier in ``b_tiers`` via
    :func:`warm_executables`.  ``get_set`` maps a planned term to its
    DeviceSet; signatures routed sharded (``sig.shards > 1``) resolve
    through ``get_sharded_set`` (falling back to ``get_set``) and warm the
    ``(ShapeSig, B-tier, shards)`` executable on ``mesh`` instead.

    With a ``topology`` (2-D ``exec.topology.Topology``), mesh-routed
    signatures (``shards > 1`` or ``replicas > 1``) warm the 2-D pipeline
    on ``topology.mesh``, and single-device signatures warm on EVERY
    replica row via ``get_replica_set(r, term)`` — jit executables are
    placement-keyed, so warming only replica 0 would leave the balancer's
    other targets compiling at first live dispatch.  Returns the warmed
    signatures, most frequent first.
    """
    from collections import Counter

    freq = Counter(p.sig for p in plans if p.algorithm == "device")
    rep_terms = {}
    for p in plans:
        if p.algorithm == "device" and p.sig not in rep_terms:
            rep_terms[p.sig] = p.terms
    warmed = [sig for sig, _ in freq.most_common(top_k)]
    for sig in warmed:
        # warm at the SIGNATURE's capacity tier, not the executor default —
        # with an adaptive capacity model the plan's tier is the learned
        # one, and warming any other tier would trace an executable no
        # live bucket ever runs (the sharded paths derive their per-shard
        # buffer from the same tier, mirroring execute_bucket)
        shards = getattr(sig, "shards", 1)
        replicas = getattr(sig, "replicas", 1)
        capacity = getattr(sig, "capacity_tier", None)
        terms = rep_terms[sig]
        mesh_routed = shards > 1 or (topology is not None and replicas > 1)
        eshape = getattr(sig, "eshape", None)
        if eshape is not None:
            # expression signature: warm the expression evaluator(s).  The
            # row is the plan's leaf terms in TRAVERSAL order (never
            # sorted); mesh-routed shapes warm the sharded / 2-D twins.
            for b in b_tiers:
                if shards > 1 or (topology is not None and replicas > 1):
                    cap = (None if capacity is None else
                           default_expr_capacity_per_shard(
                               sig.ts, sig.gmaxes, shards, capacity=capacity))
                    resolve = get_sharded_set or get_set
                    row = [resolve(t) for t in terms]
                    if topology is not None:
                        intersect_expr_mesh2d_batch(
                            [list(row)] * b, eshape, topology,
                            capacity_per_shard=cap)
                    else:
                        intersect_expr_sharded_batch(
                            [list(row)] * b, eshape, mesh, axis=axis,
                            capacity_per_shard=cap)
                elif (topology is not None and topology.replicas > 1
                      and get_replica_set is not None):
                    for r in range(topology.replicas):
                        row = [get_replica_set(r, t) for t in terms]
                        intersect_expr_batch([list(row)] * b, eshape,
                                             capacity=capacity)
                else:
                    row = [get_set(t) for t in terms]
                    intersect_expr_batch([list(row)] * b, eshape,
                                         capacity=capacity)
                EXEC_COUNTERS["warm_executions"] += 1
            continue
        cands = getattr(sig, "cands", 0)
        if cands > 0:
            # count (suggest) signature: terms[0] is the probe, terms[1:]
            # the candidate representatives, and ``capacity_tier`` holds
            # the top-K selection tier (the count path has no survivor
            # buffer).  Route exactly as live dispatch will.
            k = capacity or 8
            for b in b_tiers:
                if mesh_routed and topology is not None:
                    resolve = get_sharded_set or get_set
                    row = (resolve(terms[0]), [resolve(t) for t in terms[1:]])
                    intersect_count_mesh2d_batch(
                        [row] * b, k, topology, use_pallas=use_pallas)
                elif shards > 1:
                    resolve = get_sharded_set or get_set
                    row = (resolve(terms[0]), [resolve(t) for t in terms[1:]])
                    intersect_count_sharded_batch(
                        [row] * b, k, mesh, axis=axis, use_pallas=use_pallas)
                elif (topology is not None and topology.replicas > 1
                      and get_replica_set is not None):
                    for r in range(topology.replicas):
                        row = (get_replica_set(r, terms[0]),
                               [get_replica_set(r, t) for t in terms[1:]])
                        intersect_count_batch(
                            [row] * b, k, use_pallas=use_pallas)
                else:
                    row = (get_set(terms[0]), [get_set(t) for t in terms[1:]])
                    intersect_count_batch([row] * b, k, use_pallas=use_pallas)
                EXEC_COUNTERS["warm_executions"] += 1
            continue
        if mesh_routed:
            if capacity is not None:
                capacity = default_capacity_per_shard(
                    sig.ts, shards, capacity=capacity)
            resolve = get_sharded_set or get_set
            warm_executables(
                [[resolve(t) for t in terms]], b_tiers=b_tiers,
                capacity=capacity, use_pallas=use_pallas,
                topology=topology, mesh=mesh if topology is None else None,
                axis=axis,
            )
        elif (topology is not None and topology.replicas > 1
              and get_replica_set is not None):
            for r in range(topology.replicas):
                warm_executables(
                    [[get_replica_set(r, t) for t in terms]],
                    b_tiers=b_tiers, capacity=capacity,
                    use_pallas=use_pallas,
                )
        else:
            warm_executables(
                [[get_set(t) for t in terms]], b_tiers=b_tiers,
                capacity=capacity, use_pallas=use_pallas,
            )
    return warmed


def clear_exec_jit_cache() -> None:
    """Drop every compiled executable of the bucketed pipeline.

    Test hook: makes "warming traces, serving doesn't" assertions
    deterministic regardless of what earlier tests compiled (the jit cache
    is process-global).  Clears the sharded pipeline's cache too — the 2-D
    pipeline's row executables live in the same two jits (keyed apart by
    their ``trace_counter``), so they are covered.  No-op if the jax
    version lacks ``clear_cache``.
    """
    for fn in (_intersect_k_batch, _intersect_k_sharded_batch,
               _eval_expr_batch, _eval_expr_sharded_batch,
               _intersect_count_batch, _intersect_count_sharded_batch):
        clear = getattr(fn, "clear_cache", None)
        if clear is not None:
            clear()


# --------------------------------------------------------------------------
# shard_map distribution over the z-prefix space
# --------------------------------------------------------------------------

def make_shard_mesh(n_shards: Optional[int] = None,
                    axis: str = SHARD_AXIS) -> Mesh:
    """1-D mesh over the first ``n_shards`` local devices (all by default),
    named ``axis`` — the mesh shape :func:`intersect_sharded_batch` and the
    engines' ``mesh=`` parameters expect.  On CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax call to get N host devices to shard over."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    assert 1 <= n <= len(devices), f"need {n} devices, have {len(devices)}"
    return Mesh(np.asarray(devices[:n]), (axis,))


def make_mesh2d(replicas: int, shards: Optional[int] = None,
                data_axis: str = DATA_AXIS,
                shard_axis: str = SHARD_AXIS) -> Mesh:
    """2-D ``(data, shard)`` device mesh: ``replicas`` data-parallel rows of
    ``shards`` z-sharding columns each (``shards`` defaults to using every
    local device).  Row ``r`` holds one full replica of the posting
    mirrors (:meth:`DeviceSet.shard` on this mesh replicates over ``data``
    and partitions z over ``shard``); :func:`intersect_mesh2d_batch` splits
    a bucket's batch axis over the rows.  ``replicas`` must be a power of
    two so the executor's pow2 batch tiers always divide evenly over the
    data axis.  The 1-D special cases degenerate cleanly: ``replicas = 1``
    is pure z-sharding, ``shards = 1`` is pure data parallelism."""
    devices = jax.devices()
    replicas = int(replicas)
    shards = (len(devices) // replicas) if shards is None else int(shards)
    n = replicas * shards
    assert replicas >= 1 and shards >= 1 and n <= len(devices), (
        f"need {replicas}x{shards} = {n} devices, have {len(devices)}"
    )
    assert replicas & (replicas - 1) == 0, (
        "replicas must be a power of two (batch tiers are pow2)"
    )
    grid = np.asarray(devices[:n]).reshape(replicas, shards)
    return Mesh(grid, (data_axis, shard_axis))


def _local_shard_block(lvals, limages, ts, capacity_per_shard, use_pallas):
    """One shard's local two-phase block, shared by the 1-D and 2-D
    shard_map pipelines: phase-1 filter over the local z range, sort-
    compaction into the per-shard survivor buffer, phase-2 all-pairs match.

    ``lvals[i]``: (B_local, 2^t_i / n_shards, gmax_i); ``limages[i]``:
    (B_local, 2^t_i / n_shards, m, W).  Returns (packed, r, n_surv,
    overflow) with a leading B_local axis each — the caller adds whatever
    shard/replica axes its out_specs need.
    """
    tk = ts[-1]
    G_local = limages[-1].shape[1]
    B = lvals[0].shape[0]
    imgs = _aligned_images(limages, ts)                 # (B, k, Gl, m, W)
    passed = ops.bitmap_filter(imgs, use_pallas)        # (B, Gl)
    n_surv = passed.sum(axis=1)
    pos = jnp.where(passed, jnp.arange(G_local, dtype=jnp.int32)[None, :],
                    G_local)
    # the caller clamps capacity_per_shard to the local group count, so a
    # plain slice always suffices (no pad branch, unlike the unsharded
    # pipeline where capacity may exceed G)
    assert capacity_per_shard <= G_local, "caller must clamp to local G"
    surv = jnp.sort(pos, axis=1)[:, :capacity_per_shard]
    valid_row = surv < G_local
    surv_c = jnp.minimum(surv, G_local - 1)
    rows = jnp.arange(B)[:, None]
    base = lvals[0][rows, surv_c >> (tk - ts[0])]       # (B, cap, g0)
    keep = valid_row[:, :, None] & (base != -1)
    for v, t in zip(lvals[1:], ts[1:]):
        other = v[rows, surv_c >> (tk - t)]
        keep = keep & ops.group_match(base, other, use_pallas)
    r = keep.sum(axis=(1, 2))
    overflow = n_surv > capacity_per_shard
    packed = jnp.where(keep, base, -1)
    return packed, r, n_surv, overflow


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "ts", "gmaxes", "capacity_per_shard",
                     "use_pallas", "trace_counter"),
)
def _intersect_k_sharded_batch(
    vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    images: Tuple[Tuple[jnp.ndarray, ...], ...],
    mesh: Mesh,
    axis: str,
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity_per_shard: int,
    use_pallas,
    trace_counter: str = "sharded_traces",
):
    """One jit execution of a same-signature bucket, z-sharded over ``mesh``.

    The sharded twin of :func:`_intersect_k_batch`: inputs are B
    device-resident DeviceSet rows per set (z-sharded mirrors), stacked
    inside the jit to ``(B, 2^t_i, …)`` arrays whose z axis is partitioned
    over ``mesh[axis]``.  Both phases run per shard with NO communication —
    Theorem 3.7's alignment means a shard's local z_k range maps into the
    same shard's z_i range (valid whenever n_shards divides 2^{t_0}) — and
    each shard compacts its own survivors into a local
    ``capacity_per_shard`` buffer by the same sort-compaction as the
    unsharded path.

    Returns (packed, r, n_surv, overflow):

    - ``packed``  (B, n_shards * capacity_per_shard, gmax_0) — per-shard
      result buffers concatenated along the capacity axis (-1 = dropped);
      one transfer materializes the whole bucket.
    - ``r`` / ``n_surv`` / ``overflow`` — (n_shards, B) per-(shard, query):
      exact-match count, phase-1 survivor count, and the overflow flag
      ``n_surv > capacity_per_shard`` that drives the host-side re-run.
    """
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    vals = tuple(jnp.stack(v) for v in vals)        # (B, 2^t_i, gmax_i)
    images = tuple(jnp.stack(im) for im in images)  # (B, 2^t_i, m, W)
    k = len(ts)

    def local_fn(*flat):
        packed, r, n_surv, overflow = _local_shard_block(
            flat[:k], flat[k:], ts, capacity_per_shard, use_pallas)
        # leading length-1 shard axis on the per-shard scalars so out_specs
        # can concatenate them into (n_shards, B) without communication
        return packed, r[None], n_surv[None], overflow[None]

    from jax.experimental.shard_map import shard_map

    in_specs = tuple([P(None, axis)] * (2 * k))
    out_specs = (P(None, axis), P(axis), P(axis), P(axis))
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(*vals, *images)


def dispatch_sharded_batch(
    queries: Sequence[Sequence[DeviceSet]],
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    capacity_per_shard: Optional[int] = None,
    use_pallas="auto",
) -> PendingBatch:
    """Issue the first z-sharded pass of a bucket without blocking.

    The asynchronous half of :func:`intersect_sharded_batch` — see
    :func:`dispatch_device_batch` for the dispatch/collect contract.
    Counters: ``sharded_calls`` per pass, ``sharded_rerun_calls`` per
    overflow pass (bumped inside collect).
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_shards = mesh.shape[axis]
    ordered = [sorted(q, key=set_sort_key) for q in queries]
    ts, gmaxes = _signature(ordered[0])
    for q in ordered[1:]:
        assert _signature(q) == (ts, gmaxes), "bucket mixes shape signatures"
    assert (1 << ts[0]) % n_shards == 0, (
        f"smallest set (t={ts[0]}) must split over {n_shards} shards"
    )
    G = 1 << ts[-1]
    G_local = G // n_shards

    def issue(active: List[int], cap: int):
        b_tier = 1 << (len(active) - 1).bit_length()  # pad B to a pow2 tier
        rows = active + [active[0]] * (b_tier - len(active))
        vals = tuple(
            tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
        )
        images = tuple(
            tuple(ordered[i][j].images for i in rows) for j in range(len(ts))
        )
        EXEC_COUNTERS["sharded_calls"] += 1
        return _intersect_k_sharded_batch(
            vals, images, mesh, axis, ts, gmaxes, cap, use_pallas
        )

    first_active = list(range(len(ordered)))
    first_cap = min(
        capacity_per_shard or default_capacity_per_shard(ts, n_shards),
        G_local,
    )
    first_handles = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap, handles = first_active, first_cap, first_handles
        while True:
            packed_h, r_h, n_surv_h, over_h = jax.device_get(handles)
            rerun = []
            for row, qi in enumerate(active):
                if over_h[:, row].any():
                    rerun.append(qi)
                    continue
                row_vals = packed_h[row].ravel()
                out = row_vals[row_vals != -1]
                results[qi] = (
                    np.sort(out.astype(np.uint32)),
                    {
                        "group_tuples": G,
                        "tuples_survived": int(n_surv_h[:, row].sum()),
                        "max_shard_survivors": int(n_surv_h[:, row].max()),
                        "capacity_per_shard": cap,
                        "n_shards": n_shards,
                        "r": int(r_h[:, row].sum()),
                        "batch_size": len(active),
                    },
                )
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = G_local  # rare path: one re-run at local G, no overflow
            EXEC_COUNTERS["sharded_rerun_calls"] += 1
            handles = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def intersect_sharded_batch(
    queries: Sequence[Sequence[DeviceSet]],
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    capacity_per_shard: Optional[int] = None,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Intersect B same-signature queries z-sharded over a device mesh.

    The sharded bucket executor: same contract as
    :func:`intersect_device_batch` (signature-uniform queries, pow2 B-tier
    padding, packed single-transfer results, list of (sorted values, stats)
    in query order) with the z-prefix space partitioned over
    ``mesh[axis]``.  Communication-free by Theorem 3.7's alignment; only
    the compact per-shard result buffers leave their shard.

    Overflow is tracked per (query, shard): a query whose survivors exceed
    ``capacity_per_shard`` on ANY shard is re-run as ONE enlarged subset
    pass at the local group count ``G / n_shards``, where per-shard
    overflow is impossible — so results are always exact, never silently
    truncated.  Counters: ``sharded_calls`` per pass,
    ``sharded_rerun_calls`` per overflow pass, ``sharded_traces`` per
    compile — the sharded twins of the ``batch_*`` counters.

    Pass z-sharded mirrors (:meth:`DeviceSet.shard`) to keep posting data
    resident on its shard across calls; plain mirrors also work but are
    re-partitioned on entry.  The synchronous composition of
    :func:`dispatch_sharded_batch` + :meth:`PendingBatch.collect`.
    """
    return dispatch_sharded_batch(
        queries, mesh, axis=axis, capacity_per_shard=capacity_per_shard,
        use_pallas=use_pallas,
    ).collect()


def intersect_sharded(
    sets: Sequence[DeviceSet],
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    capacity_per_shard: Optional[int] = None,
    use_pallas="auto",
):
    """Intersect k device sets z-sharded over ``mesh``; returns (values,
    stats) on host.

    A batch of one through :func:`intersect_sharded_batch` — the historical
    single-query sharded entry point, now overflow-exact (per-shard
    survivor counts past ``capacity_per_shard`` trigger the enlarged re-run
    instead of silently truncating results) and ordered by the shared
    ``(t, n)`` sort key the planner and batched executor use.
    """
    (result, stats), = intersect_sharded_batch(
        [list(sets)], mesh, axis=axis, capacity_per_shard=capacity_per_shard,
        use_pallas=use_pallas,
    )
    return result, stats


# --------------------------------------------------------------------------
# 2-D distribution: data-parallel replicas x z-sharding
# --------------------------------------------------------------------------

def dispatch_mesh2d_batch(
    queries: Sequence[Sequence[ReplicatedDeviceSet]],
    topology,
    capacity_per_shard: Optional[int] = None,
    use_pallas="auto",
) -> PendingBatch:
    """Issue the first 2-D pass of a bucket without blocking.

    The asynchronous half of :func:`intersect_mesh2d_batch` — see
    :func:`dispatch_device_batch` for the dispatch/collect contract.  A
    pass already issues all replica rows back-to-back before any
    transfer; this additionally defers the single collection point, so
    *different buckets* can have their rows in flight simultaneously.
    Counters: ``mesh2d_calls`` per pass, ``mesh2d_row_dispatches`` per row
    execution, ``mesh2d_rerun_calls`` per overflow pass (inside collect).
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_replicas = topology.replicas
    n_shards = topology.shards
    assert n_replicas & (n_replicas - 1) == 0, (
        "data axis must be a power of two (batch tiers are pow2)"
    )
    ordered = [sorted(q, key=set_sort_key) for q in queries]
    ts, gmaxes = _signature(ordered[0])
    for q in ordered[1:]:
        assert _signature(q) == (ts, gmaxes), "bucket mixes shape signatures"
    assert (1 << ts[0]) % n_shards == 0, (
        f"smallest set (t={ts[0]}) must split over {n_shards} shards"
    )
    G = 1 << ts[-1]
    G_local = G // n_shards

    def issue(active: List[int], cap: int):
        # pow2 B-tier, floored at the replica count so `data` splits evenly
        # into equal pow2 row slices (one executable shape per pass)
        b_tier = max(n_replicas, 1 << (len(active) - 1).bit_length())
        rows = active + [active[0]] * (b_tier - len(active))
        slice_len = b_tier // n_replicas
        EXEC_COUNTERS["mesh2d_calls"] += 1
        handles = {}
        for rr in range(n_replicas):
            if rr * slice_len >= len(active):
                continue  # slice is pure padding: nothing real to compute
            chunk = rows[rr * slice_len:(rr + 1) * slice_len]
            vals = tuple(
                tuple(ordered[i][j].row(rr).vals for i in chunk)
                for j in range(len(ts))
            )
            images = tuple(
                tuple(ordered[i][j].row(rr).images for i in chunk)
                for j in range(len(ts))
            )
            EXEC_COUNTERS["mesh2d_row_dispatches"] += 1
            if n_shards > 1:
                out = _intersect_k_sharded_batch(
                    vals, images, topology.row_mesh(rr),
                    topology.shard_axis, ts, gmaxes, cap, use_pallas,
                    trace_counter="mesh2d_traces",
                )
            else:
                packed, r, n_surv, overflow = _intersect_k_batch(
                    vals, images, ts, gmaxes, cap, use_pallas,
                    trace_counter="mesh2d_traces",
                )
                # single-shard layout: add the length-1 shard axis the
                # sharded kernel's (n_shards, B) outputs carry
                out = (packed, r[None], n_surv[None], overflow[None])
            handles[rr] = out
        return handles, slice_len

    first_active = list(range(len(ordered)))
    first_cap = min(
        capacity_per_shard or default_capacity_per_shard(ts, n_shards),
        G_local,
    )
    first_handles, first_slice_len = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap = first_active, first_cap
        handles, slice_len = first_handles, first_slice_len
        while True:
            # one collection point: every row was in flight before any
            # transfer started
            fetched = jax.device_get(handles)
            rerun = []
            for rr, (packed_h, r_h, n_surv_h, over_h) in fetched.items():
                chunk_start = rr * slice_len
                for local_row in range(slice_len):
                    pos = chunk_start + local_row
                    if pos >= len(active):
                        continue  # padding rows repeat query active[0]
                    qi = active[pos]
                    if over_h[:, local_row].any():
                        rerun.append(qi)
                        continue
                    row_vals = packed_h[local_row].ravel()
                    out_vals = row_vals[row_vals != -1]
                    results[qi] = (
                        np.sort(out_vals.astype(np.uint32)),
                        {
                            "group_tuples": G,
                            "tuples_survived": int(n_surv_h[:, local_row].sum()),
                            "max_shard_survivors": int(
                                n_surv_h[:, local_row].max()),
                            "capacity_per_shard": cap,
                            "n_shards": n_shards,
                            "n_replicas": n_replicas,
                            "replica": rr,
                            "r": int(r_h[:, local_row].sum()),
                            "batch_size": len(active),
                        },
                    )
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = G_local  # rare path: one re-run at local G, no overflow
            EXEC_COUNTERS["mesh2d_rerun_calls"] += 1
            handles, slice_len = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def intersect_mesh2d_batch(
    queries: Sequence[Sequence[ReplicatedDeviceSet]],
    topology,
    capacity_per_shard: Optional[int] = None,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Intersect B same-signature queries over a 2-D ``(data, shard)`` mesh.

    Same contract as :func:`intersect_sharded_batch` (signature-uniform
    queries, packed single-transfer results, list of (sorted values, stats)
    in query order) with the batch axis additionally split over the
    topology's data axis: replica row ``r`` holds a full copy of the
    posting mirrors (``queries[i][j]`` is a :class:`ReplicatedDeviceSet`)
    and processes its contiguous ``B / replicas`` slice of the bucket, so
    a bucket occupies every device without every device seeing every
    query.  B pads up to ``max(replicas, next pow2)`` so the batch axis
    always divides the data axis; padding rows repeat the first query and
    are dropped before results materialize, and a replica whose slice is
    *entirely* padding is never dispatched at all (a 1-query bucket on a
    4-replica topology runs one row, not four).

    The data axis is host-driven, the shard axis shard_map-ped: each row's
    slice is one async dispatch of the row-local pipeline — the 1-D
    z-sharded kernel over the row's submesh (``topology.row_mesh(r)``)
    when ``shards > 1``, the plain single-device kernel on the row's
    anchor otherwise — and every row's handles are collected at ONE
    ``device_get``, so rows overlap in flight.  No collective ever crosses
    the data axis (queries are independent), and within a row the z split
    is communication-free by Theorem 3.7's alignment — driving the data
    axis from the host instead of a single 2-D shard_map costs nothing in
    semantics and avoids GSPMD materializing the stacked batch replicated
    on every row (measured 3-10x slower on CPU meshes).

    Overflow stays per (query, shard): a query whose survivors exceed
    ``capacity_per_shard`` on ANY of its row's shards is re-run as ONE
    enlarged subset pass at the local group count, where overflow is
    impossible — results are bit-identical to the 1-D and host paths in
    every case.  Counters: ``mesh2d_calls`` per bucket pass,
    ``mesh2d_row_dispatches`` per row execution, ``mesh2d_traces`` /
    ``mesh2d_rerun_calls`` as in the ``sharded_*`` family.

    The synchronous composition of :func:`dispatch_mesh2d_batch` +
    :meth:`PendingBatch.collect`.
    """
    return dispatch_mesh2d_batch(
        queries, topology, capacity_per_shard=capacity_per_shard,
        use_pallas=use_pallas,
    ).collect()


# --------------------------------------------------------------------------
# count-only execution: the set-similarity suggestion workload
# --------------------------------------------------------------------------
#
# ``suggest(set_id, k)`` scores one probe set's intersection *cardinality*
# against C candidate sets and keeps the top K — the inner loop of
# set-similarity join.  Cardinality needs none of the point-query
# machinery: no phase-1 filter (every group tuple is counted, there is
# nothing to recover), no survivor compaction, no capacity buffer, and
# therefore NO overflow re-run — each (probe, candidate) pair reduces to
# one int32 and a bucket is one packed (B, C) count matrix.
#
# Exactness without a filter: with all sets partitioned by the same
# permutation g, iterate the G = 2^t_max group tuples of the DEEPER set
# and count its group-g elements present in the other set's aligned group
# ``g >> (t_max - t_min)`` (kernels.count.pair_count).  A common element x
# appears in exactly ONE tuple of the deeper set — the one indexed by its
# full-depth prefix — so summing the per-tuple counts over all G tuples
# counts x exactly once: the per-pair sum IS |probe ∩ candidate|.
#
# Top-K selection runs on device inside the same jit: padded candidate
# slots (the C axis pads to the signature's pow2 ``cands`` tier) are
# masked to -1 via the traced per-query candidate count, and
# ``jax.lax.top_k`` — which breaks ties by LOWEST index — runs over
# candidates the callers order by ascending id, so equal counts
# deterministically prefer the smallest candidate id.  The host merges
# per-bucket top lists by ``(-count, id)``.
#
# Sharding: counts are additive over disjoint z-ranges (Theorem 3.7 —
# each common element lives in exactly one z-range), so the z-sharded
# twin computes per-shard (B, C) partial counts with zero communication
# and sums them outside the shard_map (the only cross-device traffic is
# the B*C count matrix — the analogue of the point path's compact result
# buffers).  Top-K then runs on the summed totals in the same jit.  The
# 2-D path drives replica rows host-side exactly like
# :func:`dispatch_mesh2d_batch`.


def default_k_tier(k: int) -> int:
    """Static top-K selection tier: next power of two, floored at 8.

    Plays the role ``default_capacity`` plays for the point path — the
    requested ``k`` quantizes UP to a tier so nearby k values share one
    compiled executable; the host slices the device's top ``k_tier`` list
    down to the requested k.  Stored in ``ShapeSig.capacity_tier`` for
    suggest plans (the count path has no survivor buffer, so the field is
    free to key the selection width instead)."""
    return 1 << max(3, (int(k) - 1).bit_length())


def _count_block(pv: jnp.ndarray, cv: jnp.ndarray, ts: Tuple[int, int],
                 use_pallas) -> jnp.ndarray:
    """(B, Gp, gp) probe x (B, C, Gc, gc) candidates -> (B, C) counts.

    Shared by the plain jit and the per-shard local block (shapes are then
    the local z-slices; the t-difference shift is shard-invariant because
    equal z-ranges of both sets land on the same shard).  The deeper set
    supplies the iterated groups (counted once each); the shallower set's
    groups are gathered through the prefix-alignment shift — a broadcast
    in disguise, as in :func:`_aligned_images`.
    """
    tp, tc = ts
    B = pv.shape[0]
    C = cv.shape[1]
    if tp >= tc:
        G = pv.shape[1]
        a = jnp.broadcast_to(pv[:, None], (B, C) + pv.shape[1:])
        if tp == tc:
            b = cv
        else:
            idx = jnp.arange(G, dtype=jnp.int32) >> (tp - tc)
            b = cv[:, :, idx]
    else:
        G = cv.shape[2]
        idx = jnp.arange(G, dtype=jnp.int32) >> (tc - tp)
        a = cv
        b = jnp.broadcast_to(pv[:, idx][:, None],
                             (B, C, G, pv.shape[-1]))
    per_tuple = ops.pair_count(a, b, use_pallas)        # (B, C, G)
    return per_tuple.sum(axis=-1, dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("ts", "gmaxes", "k_sel", "use_pallas", "trace_counter"),
)
def _intersect_count_batch(
    probe_vals: Tuple[jnp.ndarray, ...],
    cand_vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    n_cands: jnp.ndarray,
    ts: Tuple[int, int],
    gmaxes: Tuple[int, int],
    k_sel: int,
    use_pallas,
    trace_counter: str = "count_traces",
):
    """One jit execution for a whole same-signature suggest bucket.

    ``probe_vals``: B arrays of (2^{t_p}, gmax_p) int32; ``cand_vals``: B
    tuples of C arrays of (2^{t_c}, gmax_c) int32 — stacked inside the jit
    like the point pipeline.  ``n_cands`` is a traced (B,) int32 of REAL
    candidate counts per row; slots at or past it (C-axis padding repeats
    candidate 0) are masked to count -1 so they can never win top-K and
    the executable never retraces on the fill level.  Returns
    ``(top_counts, top_idx)``, each (B, k_sel) int32 — ``top_idx`` indexes
    the row's candidate list, which callers order by ascending id so
    ``lax.top_k``'s lowest-index tie-break is the smallest-id rule.
    """
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    pv = jnp.stack(probe_vals)                            # (B, Gp, gp)
    cv = jnp.stack([jnp.stack(row) for row in cand_vals])  # (B, C, Gc, gc)
    counts = _count_block(pv, cv, ts, use_pallas)         # (B, C)
    C = cv.shape[1]
    slot = jnp.arange(C, dtype=jnp.int32)[None, :]
    masked = jnp.where(slot < n_cands[:, None], counts, -1)
    return jax.lax.top_k(masked, k_sel)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "ts", "gmaxes", "k_sel", "use_pallas",
                     "trace_counter"),
)
def _intersect_count_sharded_batch(
    probe_vals: Tuple[jnp.ndarray, ...],
    cand_vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    n_cands: jnp.ndarray,
    mesh: Mesh,
    axis: str,
    ts: Tuple[int, int],
    gmaxes: Tuple[int, int],
    k_sel: int,
    use_pallas,
    trace_counter: str = "count_traces",
):
    """The z-sharded twin of :func:`_intersect_count_batch`.

    Each shard computes partial (B, C) counts over its local z-range with
    no communication (counts are additive over disjoint z-ranges); the
    per-shard matrices concatenate to (n_shards, B, C), sum OUTSIDE the
    shard_map (still inside this jit), and top-K runs on the totals.
    Requires both 2^{t_p} and 2^{t_c} to split evenly over the mesh.
    """
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    pv = jnp.stack(probe_vals)                            # (B, Gp, gp)
    cv = jnp.stack([jnp.stack(row) for row in cand_vals])  # (B, C, Gc, gc)

    def local_fn(lpv, lcv):
        # leading length-1 shard axis so out_specs concatenate the partial
        # count matrices into (n_shards, B, C) without communication
        return _count_block(lpv, lcv, ts, use_pallas)[None]

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, axis), P(None, None, axis)),
        out_specs=P(axis), check_rep=False,
    )
    counts = fn(pv, cv).sum(axis=0)                       # (B, C)
    C = cv.shape[1]
    slot = jnp.arange(C, dtype=jnp.int32)[None, :]
    masked = jnp.where(slot < n_cands[:, None], counts, -1)
    return jax.lax.top_k(masked, k_sel)


def _count_signature(queries) -> Tuple[Tuple[int, int], Tuple[int, int], int]:
    """Validate a suggest bucket and return (ts, gmaxes, c_tier).

    Every probe must share (t, gmax), every candidate must share (t,
    gmax), and the candidate-axis tier is the pow2 ceiling of the largest
    row's candidate count (matching ``ShapeSig.cands`` for plans bucketed
    by the planner).
    """
    probe0, cands0 = queries[0]
    assert len(cands0) >= 1, "suggest rows need at least one candidate"
    tp, gp = probe0.t, probe0.gmax
    tc, gc = cands0[0].t, cands0[0].gmax
    max_c = 0
    for probe, cands in queries:
        assert (probe.t, probe.gmax) == (tp, gp), (
            "bucket mixes probe shapes")
        assert len(cands) >= 1, "suggest rows need at least one candidate"
        for c in cands:
            assert (c.t, c.gmax) == (tc, gc), "bucket mixes candidate shapes"
        max_c = max(max_c, len(cands))
    c_tier = 1 << (max_c - 1).bit_length()
    return (tp, tc), (gp, gc), c_tier


def _pack_count_rows(queries, rows: List[int], c_tier: int):
    """Stack bucket rows into the count jit's pytree inputs: pad each
    row's candidate list to ``c_tier`` by repeating candidate 0 (masked
    off by ``n_cands``), B-pad by repeating row 0 (dropped at collect)."""
    probe_vals = tuple(queries[i][0].vals for i in rows)
    cand_vals = tuple(
        tuple((queries[i][1] + [queries[i][1][0]]
               * (c_tier - len(queries[i][1])))[j].vals
              for j in range(c_tier))
        for i in rows
    )
    n_cands = jnp.asarray([len(queries[i][1]) for i in rows], jnp.int32)
    return probe_vals, cand_vals, n_cands


def _collect_count(handles, queries, k_sel: int, extra_stats: Dict,
                   row_of=None):
    """Shared collect for the count paths: one transfer, no re-run loop.

    ``row_of`` maps query index -> (handle key, local row) for the 2-D
    host-driven layout; None means a single handle covering all rows."""
    fetched = jax.device_get(handles)
    results: List[Tuple[np.ndarray, Dict]] = []
    for qi, (probe, cands) in enumerate(queries):
        if row_of is None:
            top_counts, top_idx = fetched
            row = qi
        else:
            key, row = row_of(qi)
            top_counts, top_idx = fetched[key]
        pairs = np.stack(
            [top_idx[row], top_counts[row]], axis=1).astype(np.int32)
        stats = {
            "n_cands": len(cands),
            "k_sel": k_sel,
            "batch_size": len(queries),
            **extra_stats,
        }
        if row_of is not None:
            stats["replica"] = key
        results.append((pairs, stats))
    return results


def dispatch_count_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    use_pallas="auto",
) -> PendingBatch:
    """Issue one count-only suggest bucket without blocking.

    ``queries[i]`` is ``(probe, candidates)`` — candidates ordered by
    ascending id by the caller (the tie-break contract).  ``k`` is the
    selection tier (``ShapeSig.capacity_tier`` for planned buckets); the
    device returns each row's top ``min(k, c_tier)`` (idx, count) pairs
    and the host keeps what it needs.  ONE pass per bucket — the count
    path has no overflow re-run by construction.  Counters:
    ``count_calls`` per pass, ``count_traces`` per compile.
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    queries = [(p, list(c)) for p, c in queries]
    ts, gmaxes, c_tier = _count_signature(queries)
    k_sel = min(int(k), c_tier)
    b_tier = 1 << (len(queries) - 1).bit_length()
    rows = list(range(len(queries))) + [0] * (b_tier - len(queries))
    probe_vals, cand_vals, n_cands = _pack_count_rows(queries, rows, c_tier)
    EXEC_COUNTERS["count_calls"] += 1
    handles = _intersect_count_batch(
        probe_vals, cand_vals, n_cands, ts, gmaxes, k_sel, use_pallas)
    extra = {"c_tier": c_tier, "group_tuples": 1 << max(ts)}
    return PendingBatch(
        n_queries=len(queries), handles=handles,
        _collect=lambda: _collect_count(handles, queries, k_sel, extra),
    )


def intersect_count_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Count-only suggest bucket, synchronously: B (probe, candidates)
    rows -> per row a (k_sel, 2) int32 array of (candidate index, count)
    pairs, best-first under the ``(-count, smallest id)`` order, plus
    stats.  Padded / past-the-end slots carry count -1; the serving layer
    drops counts < 1 (a zero-overlap candidate is not a suggestion).  The
    synchronous composition of :func:`dispatch_count_batch` +
    :meth:`PendingBatch.collect`."""
    return dispatch_count_batch(queries, k, use_pallas=use_pallas).collect()


def dispatch_count_sharded_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    use_pallas="auto",
) -> PendingBatch:
    """Issue one suggest bucket z-sharded over ``mesh`` without blocking.

    Same contract as :func:`dispatch_count_batch`; both the probe's and
    the candidates' z axes must split evenly over the mesh (the planner's
    routing rule guarantees it for planned buckets).  Pass z-sharded
    mirrors to avoid a per-call reshard.
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_shards = mesh.shape[axis]
    queries = [(p, list(c)) for p, c in queries]
    ts, gmaxes, c_tier = _count_signature(queries)
    assert (1 << ts[0]) % n_shards == 0 and (1 << ts[1]) % n_shards == 0, (
        f"both z axes (t={ts}) must split over {n_shards} shards"
    )
    k_sel = min(int(k), c_tier)
    b_tier = 1 << (len(queries) - 1).bit_length()
    rows = list(range(len(queries))) + [0] * (b_tier - len(queries))
    probe_vals, cand_vals, n_cands = _pack_count_rows(queries, rows, c_tier)
    EXEC_COUNTERS["count_calls"] += 1
    handles = _intersect_count_sharded_batch(
        probe_vals, cand_vals, n_cands, mesh, axis, ts, gmaxes, k_sel,
        use_pallas)
    extra = {"c_tier": c_tier, "group_tuples": 1 << max(ts),
             "n_shards": n_shards}
    return PendingBatch(
        n_queries=len(queries), handles=handles,
        _collect=lambda: _collect_count(handles, queries, k_sel, extra),
    )


def intersect_count_sharded_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Synchronous composition of :func:`dispatch_count_sharded_batch` +
    :meth:`PendingBatch.collect` — bit-identical to the plain count path
    (counts are additive over z-ranges; top-K runs on the exact totals)."""
    return dispatch_count_sharded_batch(
        queries, k, mesh, axis=axis, use_pallas=use_pallas).collect()


def dispatch_count_mesh2d_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    topology,
    use_pallas="auto",
) -> PendingBatch:
    """Issue one suggest bucket over a 2-D ``(data, shard)`` topology.

    The count twin of :func:`dispatch_mesh2d_batch`: the batch axis is cut
    into contiguous equal slices driven host-side (one async row dispatch
    each — the z-sharded count jit on the row's submesh, or the plain
    count jit when ``shards == 1``), rows overlap in flight, and one
    ``device_get`` collects everything.  ``queries[i]`` resolves per row:
    probes/candidates are :class:`ReplicatedDeviceSet` mirrors.  Counters:
    ``count_calls`` per row dispatch (each row is one jit execution),
    ``mesh2d_row_dispatches`` per row as in the point path.
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_replicas = topology.replicas
    n_shards = topology.shards
    queries = [(p, list(c)) for p, c in queries]
    ts = (queries[0][0].t, queries[0][1][0].t)
    if n_shards > 1:
        assert ((1 << ts[0]) % n_shards == 0
                and (1 << ts[1]) % n_shards == 0), (
            f"both z axes (t={ts}) must split over {n_shards} shards"
        )
    b_tier = max(n_replicas, 1 << (len(queries) - 1).bit_length())
    rows = list(range(len(queries))) + [0] * (b_tier - len(queries))
    slice_len = b_tier // n_replicas
    c_tier = 1 << (max(len(c) for _, c in queries) - 1).bit_length()
    k_sel = min(int(k), c_tier)
    handles = {}
    for rr in range(n_replicas):
        if rr * slice_len >= len(queries):
            continue  # slice is pure padding: nothing real to compute
        chunk = rows[rr * slice_len:(rr + 1) * slice_len]
        row_queries = [
            (queries[i][0].row(rr), [c.row(rr) for c in queries[i][1]])
            for i in chunk
        ]
        tsr, gmaxes, _ = _count_signature(row_queries)
        probe_vals, cand_vals, n_cands = _pack_count_rows(
            row_queries, list(range(len(chunk))), c_tier)
        EXEC_COUNTERS["count_calls"] += 1
        EXEC_COUNTERS["mesh2d_row_dispatches"] += 1
        if n_shards > 1:
            handles[rr] = _intersect_count_sharded_batch(
                probe_vals, cand_vals, n_cands, topology.row_mesh(rr),
                topology.shard_axis, tsr, gmaxes, k_sel, use_pallas)
        else:
            handles[rr] = _intersect_count_batch(
                probe_vals, cand_vals, n_cands, tsr, gmaxes, k_sel,
                use_pallas)

    def row_of(qi: int) -> Tuple[int, int]:
        return qi // slice_len, qi % slice_len

    extra = {"c_tier": c_tier, "group_tuples": 1 << max(ts),
             "n_shards": n_shards, "n_replicas": n_replicas}
    return PendingBatch(
        n_queries=len(queries), handles=handles,
        _collect=lambda: _collect_count(handles, queries, k_sel, extra,
                                        row_of=row_of),
    )


def intersect_count_mesh2d_batch(
    queries: Sequence[Tuple[DeviceSet, Sequence[DeviceSet]]],
    k: int,
    topology,
    use_pallas="auto",
) -> List[Tuple[np.ndarray, Dict]]:
    """Synchronous composition of :func:`dispatch_count_mesh2d_batch` +
    :meth:`PendingBatch.collect`."""
    return dispatch_count_mesh2d_batch(
        queries, k, topology, use_pallas=use_pallas).collect()


# --------------------------------------------------------------------------
# boolean expression evaluation: ∪ / ∩ / ∖ DAGs over dense value buffers
# --------------------------------------------------------------------------
#
# Non-flat expressions (anything but a pure conjunction of terms — those
# keep the bitmap-filter + group-match pipeline above, byte-identical)
# evaluate on **dense value buffers**: each leaf's (2^t, gmax) z-prefix
# group layout flattens to one sorted uint32 row per query
# (kernels.setops.densify — the int32 -1 padding bitcasts to the
# 0xFFFFFFFF sentinel, which sorts last), and every DAG node is a
# sort-merge pass over its children's buffers, bottom-up, entirely
# on-device inside ONE jit per bucket.  There is no bitmap/group phase
# for mixed nodes because intermediates (a∪b, …) have no precomputed
# filter images — the dense representation is the paper's structures'
# "value view", and Bille–Pagh–Pagh-style evaluation over it keeps every
# node a linear merge.
#
# The overflow contract is the flat pipeline's, verbatim: every
# *composite* node writes into a static buffer of width
# ``min(capacity, natural)`` (natural = what its children could supply);
# a per-query flag records any node whose true count exceeded its
# buffer, and flagged queries are re-run ONCE at ``capacity = total leaf
# width``, where no node can overflow — results are bit-identical to the
# numpy oracle in every case.  Sharding: all leaves share the
# permutation g, so ∪/∩/∖ distribute over z-ranges — each shard
# evaluates the whole DAG on its local slices with NO communication
# (the expression twin of Theorem 3.7's alignment), overflow stays per
# (query, shard), and per-shard result segments concatenate.
#
# Subexpression sharing: the evaluator also emits the value buffer of
# every composite proper subexpression (postorder), which the serving
# layer stores in the result cache keyed on the canonical subexpression
# — a later query containing the same subtree resolves host-side.


def _expr_signature(row: Sequence[DeviceSet]
                    ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Leaf signature in TRAVERSAL order — expression rows are ordered by
    the expression's leaf walk (``exec.expr.leaf_terms``), never re-sorted
    (position encodes which leaf of the DAG a set feeds)."""
    return tuple(s.t for s in row), tuple(s.gmax for s in row)


def expr_total_width(ts: Tuple[int, ...], gmaxes: Tuple[int, ...]) -> int:
    """Total dense width of an expression's leaves — the capacity at which
    no node can overflow (every result value originates from some leaf)."""
    return sum((1 << t) * g for t, g in zip(ts, gmaxes))


def default_expr_capacity(ts: Tuple[int, ...],
                          gmaxes: Tuple[int, ...]) -> int:
    """Survivor-buffer tier for expression nodes: total/4 with a floor,
    rounded to the power-of-two lattice — the expression analogue of
    :func:`default_capacity` (union nodes routinely carry more values
    than an intersection's survivors, so the prior is deliberately
    generous; the adaptive ``CapacityModel`` refines it per shape from
    observed node counts)."""
    total = expr_total_width(ts, gmaxes)
    tier = 1 << max(0, (total - 1).bit_length())
    return max(64, tier // 4)


def default_expr_capacity_per_shard(ts: Tuple[int, ...],
                                    gmaxes: Tuple[int, ...],
                                    n_shards: int,
                                    capacity: Optional[int] = None) -> int:
    """Per-shard node-buffer tier for the sharded expression pipeline —
    the expression analogue of :func:`default_capacity_per_shard`."""
    local_total = expr_total_width(ts, gmaxes) // n_shards
    whole = (default_expr_capacity(ts, gmaxes) if capacity is None
             else int(capacity))
    return min(local_total, max(16, whole // n_shards))


def _count_expr_subs(eshape) -> int:
    """Number of composite proper subexpressions (postorder emission count
    of `_eval_expr_block`) — static in the shape, so shard_map out_specs
    can size the sub-buffer pytree."""
    if eshape == "T":
        return 0
    n = 0
    for child in eshape[1:]:
        n += _count_expr_subs(child)
        if child != "T":
            n += 1
    return n


def _eval_expr_block(vals, eshape, capacity: int):
    """Evaluate one expression DAG over stacked leaf arrays, bottom-up.

    ``vals[i]``: (B, 2^t_i[, /S], gmax_i) int32 leaf arrays in traversal
    order.  Returns ``(root, r, max_count, overflow, subs)``: the root's
    sorted sentinel-padded (B, W_root) uint32 buffer, its true count, the
    max true count over all composite nodes (the adaptive model's
    survivor statistic), the per-query any-node-truncated flag, and the
    postorder tuple of composite proper-subexpression buffers.
    """
    dense = [setops.densify(v) for v in vals]
    next_leaf = [0]
    subs: List[jnp.ndarray] = []
    zero = jnp.zeros(dense[0].shape[0], dtype=jnp.int32)
    state = {"overflow": zero > 0, "max_count": zero}

    def node(shape, root: bool):
        if shape == "T":
            buf = dense[next_leaf[0]]
            next_leaf[0] += 1
            return buf
        op = shape[0]
        if op == "-":
            left = node(shape[1], False)
            right = node(shape[2], False)
            width = min(capacity, left.shape[1])
            out, count = setops.diff_pass(left, right, width)
        elif op == "|":
            bufs = [node(s, False) for s in shape[1:]]
            width = min(capacity, sum(b.shape[1] for b in bufs))
            out, count = setops.union_pass(bufs, width)
        else:
            bufs = [node(s, False) for s in shape[1:]]
            width = min(capacity, bufs[0].shape[1])
            out, count = setops.intersect_pass(bufs, width)
        state["overflow"] = state["overflow"] | (count > out.shape[1])
        state["max_count"] = jnp.maximum(state["max_count"], count)
        if not root:
            subs.append(out)
        else:
            state["r"] = count
        return out

    root = node(eshape, True)
    return (root, state["r"], state["max_count"], state["overflow"],
            tuple(subs))


@functools.partial(
    jax.jit,
    static_argnames=("eshape", "ts", "gmaxes", "capacity", "trace_counter"),
)
def _eval_expr_batch(
    vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    eshape,
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity: int,
    trace_counter: str = "expr_traces",
):
    """One jit execution for a whole same-shape bucket of B expression
    queries — the expression twin of :func:`_intersect_k_batch` (same
    in-jit stacking, same static-shape discipline; ``eshape`` + ``ts`` +
    ``gmaxes`` + ``capacity`` fully determine every buffer width)."""
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    vals = tuple(jnp.stack(v) for v in vals)
    return _eval_expr_block(vals, eshape, capacity)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "eshape", "ts", "gmaxes",
                     "capacity_per_shard", "trace_counter"),
)
def _eval_expr_sharded_batch(
    vals: Tuple[Tuple[jnp.ndarray, ...], ...],
    mesh: Mesh,
    axis: str,
    eshape,
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity_per_shard: int,
    trace_counter: str = "expr_traces",
):
    """The z-sharded expression evaluator: every shard runs the whole DAG
    on its local z-slices (``g`` aligns all leaves, so ∪/∩/∖ distribute
    over z-ranges with no communication), per-shard node buffers
    concatenate along the width axis, and the per-(query, shard) flags
    drive the host-side enlarged re-run exactly as in
    :func:`_intersect_k_sharded_batch`."""
    EXEC_COUNTERS[trace_counter] += 1  # python side effect: trace-time only
    vals = tuple(jnp.stack(v) for v in vals)
    n_subs = _count_expr_subs(eshape)

    def local_fn(*lvals):
        root, r, max_count, overflow, subs = _eval_expr_block(
            lvals, eshape, capacity_per_shard)
        return root, r[None], max_count[None], overflow[None], subs

    from jax.experimental.shard_map import shard_map

    in_specs = tuple([P(None, axis)] * len(ts))
    out_specs = (P(None, axis), P(axis), P(axis), P(axis),
                 tuple([P(None, axis)] * n_subs))
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(*vals)


_EXPR_SENTINEL = np.uint32(0xFFFFFFFF)


def _compact_u32(row: np.ndarray) -> np.ndarray:
    """Sentinel-padded uint32 buffer (any per-shard segment order) ->
    sorted value array, the serving result/value format."""
    flat = row.ravel()
    return np.sort(flat[flat != _EXPR_SENTINEL])


def dispatch_expr_batch(
    queries: Sequence[Sequence[DeviceSet]],
    eshape,
    capacity: Optional[int] = None,
    sub_keys: Optional[Sequence[Sequence]] = None,
) -> PendingBatch:
    """Issue the first pass of a same-shape expression bucket.

    ``queries[i]`` is query i's leaf DeviceSets in the expression's
    traversal order (NOT (t, n)-sorted — position encodes DAG wiring);
    all queries must share ``eshape`` and the leaf signature.
    ``sub_keys[i]`` (optional) are query i's canonical subexpression
    cache keys, postorder — when given, collected stats carry
    ``"subexprs": [(key, sorted values), …]`` for the serving layer to
    store.  Counters: ``expr_calls`` per pass, ``expr_rerun_calls`` per
    overflow pass, ``expr_traces`` per compile.
    """
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    ordered = [list(q) for q in queries]
    ts, gmaxes = _expr_signature(ordered[0])
    for q in ordered[1:]:
        assert _expr_signature(q) == (ts, gmaxes), (
            "bucket mixes expression leaf signatures")
    total = expr_total_width(ts, gmaxes)

    def issue(active: List[int], cap: int):
        b_tier = 1 << (len(active) - 1).bit_length()
        rows = active + [active[0]] * (b_tier - len(active))
        vals = tuple(
            tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
        )
        EXEC_COUNTERS["expr_calls"] += 1
        return _eval_expr_batch(vals, eshape, ts, gmaxes, cap)

    first_active = list(range(len(ordered)))
    first_cap = min(capacity or default_expr_capacity(ts, gmaxes), total)
    first_handles = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap, handles = first_active, first_cap, first_handles
        while True:
            root_h, r_h, maxc_h, over_h, subs_h = jax.device_get(handles)
            rerun = []
            for row, qi in enumerate(active):
                if over_h[row]:
                    rerun.append(qi)
                    continue
                stats = {
                    "expr_width": total,
                    "tuples_survived": int(maxc_h[row]),
                    "capacity": cap,
                    "r": int(r_h[row]),
                    "batch_size": len(active),
                }
                if sub_keys is not None:
                    stats["subexprs"] = [
                        (key, _compact_u32(sub[row]))
                        for key, sub in zip(sub_keys[qi], subs_h)
                    ]
                results[qi] = (_compact_u32(root_h[row]), stats)
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = total  # rare path: ONE re-run where no node can overflow
            EXEC_COUNTERS["expr_rerun_calls"] += 1
            handles = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def dispatch_expr_sharded_batch(
    queries: Sequence[Sequence[DeviceSet]],
    eshape,
    mesh: Mesh,
    axis: str = SHARD_AXIS,
    capacity_per_shard: Optional[int] = None,
    sub_keys: Optional[Sequence[Sequence]] = None,
) -> PendingBatch:
    """Issue the first z-sharded pass of an expression bucket — the
    expression twin of :func:`dispatch_sharded_batch` (same per-(query,
    shard) overflow + single enlarged re-run at the local total width).
    Pass z-sharded leaf mirrors; every leaf must split over the mesh."""
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_shards = mesh.shape[axis]
    ordered = [list(q) for q in queries]
    ts, gmaxes = _expr_signature(ordered[0])
    for q in ordered[1:]:
        assert _expr_signature(q) == (ts, gmaxes), (
            "bucket mixes expression leaf signatures")
    assert all((1 << t) % n_shards == 0 for t in ts), (
        f"every leaf must split over {n_shards} shards")
    total = expr_total_width(ts, gmaxes)
    local_total = total // n_shards

    def issue(active: List[int], cap: int):
        b_tier = 1 << (len(active) - 1).bit_length()
        rows = active + [active[0]] * (b_tier - len(active))
        vals = tuple(
            tuple(ordered[i][j].vals for i in rows) for j in range(len(ts))
        )
        EXEC_COUNTERS["expr_calls"] += 1
        return _eval_expr_sharded_batch(vals, mesh, axis, eshape, ts,
                                        gmaxes, cap)

    first_active = list(range(len(ordered)))
    first_cap = min(
        capacity_per_shard
        or default_expr_capacity_per_shard(ts, gmaxes, n_shards),
        local_total,
    )
    first_handles = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap, handles = first_active, first_cap, first_handles
        while True:
            root_h, r_h, maxc_h, over_h, subs_h = jax.device_get(handles)
            rerun = []
            for row, qi in enumerate(active):
                if over_h[:, row].any():
                    rerun.append(qi)
                    continue
                stats = {
                    "expr_width": total,
                    "tuples_survived": int(maxc_h[:, row].sum()),
                    "max_shard_survivors": int(maxc_h[:, row].max()),
                    "capacity_per_shard": cap,
                    "n_shards": n_shards,
                    "r": int(r_h[:, row].sum()),
                    "batch_size": len(active),
                }
                if sub_keys is not None:
                    stats["subexprs"] = [
                        (key, _compact_u32(sub[row]))
                        for key, sub in zip(sub_keys[qi], subs_h)
                    ]
                results[qi] = (_compact_u32(root_h[row]), stats)
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = local_total  # one re-run at local total: no overflow
            EXEC_COUNTERS["expr_rerun_calls"] += 1
            handles = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def dispatch_expr_mesh2d_batch(
    queries: Sequence[Sequence[ReplicatedDeviceSet]],
    eshape,
    topology,
    capacity_per_shard: Optional[int] = None,
    sub_keys: Optional[Sequence[Sequence]] = None,
) -> PendingBatch:
    """Issue the first 2-D (data x shard) pass of an expression bucket —
    the expression twin of :func:`dispatch_mesh2d_batch`: the batch axis
    splits over host-driven replica rows, each row runs the 1-D sharded
    (or plain) expression evaluator on its slice, one collection point."""
    if not len(queries):
        return PendingBatch(n_queries=0, _collect=lambda: [])
    n_replicas = topology.replicas
    n_shards = topology.shards
    assert n_replicas & (n_replicas - 1) == 0, (
        "data axis must be a power of two (batch tiers are pow2)")
    ordered = [list(q) for q in queries]
    ts, gmaxes = _expr_signature(ordered[0])
    for q in ordered[1:]:
        assert _expr_signature(q) == (ts, gmaxes), (
            "bucket mixes expression leaf signatures")
    assert all((1 << t) % n_shards == 0 for t in ts), (
        f"every leaf must split over {n_shards} shards")
    total = expr_total_width(ts, gmaxes)
    local_total = total // n_shards

    def issue(active: List[int], cap: int):
        b_tier = max(n_replicas, 1 << (len(active) - 1).bit_length())
        rows = active + [active[0]] * (b_tier - len(active))
        slice_len = b_tier // n_replicas
        EXEC_COUNTERS["expr_calls"] += 1
        handles = {}
        for rr in range(n_replicas):
            if rr * slice_len >= len(active):
                continue  # slice is pure padding: nothing real to compute
            chunk = rows[rr * slice_len:(rr + 1) * slice_len]
            vals = tuple(
                tuple(ordered[i][j].row(rr).vals for i in chunk)
                for j in range(len(ts))
            )
            if n_shards > 1:
                out = _eval_expr_sharded_batch(
                    vals, topology.row_mesh(rr), topology.shard_axis,
                    eshape, ts, gmaxes, cap)
            else:
                root, r, maxc, over, subs = _eval_expr_batch(
                    vals, eshape, ts, gmaxes, cap)
                out = (root, r[None], maxc[None], over[None], subs)
            handles[rr] = out
        return handles, slice_len

    first_active = list(range(len(ordered)))
    first_cap = min(
        capacity_per_shard
        or default_expr_capacity_per_shard(ts, gmaxes, n_shards),
        local_total,
    )
    first_handles, first_slice_len = issue(first_active, first_cap)

    def collect() -> List[Tuple[np.ndarray, Dict]]:
        results: List[Optional[Tuple[np.ndarray, Dict]]] = [None] * len(ordered)
        active, cap = first_active, first_cap
        handles, slice_len = first_handles, first_slice_len
        while True:
            fetched = jax.device_get(handles)
            rerun = []
            for rr, (root_h, r_h, maxc_h, over_h, subs_h) in fetched.items():
                chunk_start = rr * slice_len
                for local_row in range(slice_len):
                    pos = chunk_start + local_row
                    if pos >= len(active):
                        continue  # padding rows repeat query active[0]
                    qi = active[pos]
                    if over_h[:, local_row].any():
                        rerun.append(qi)
                        continue
                    stats = {
                        "expr_width": total,
                        "tuples_survived": int(maxc_h[:, local_row].sum()),
                        "max_shard_survivors": int(maxc_h[:, local_row].max()),
                        "capacity_per_shard": cap,
                        "n_shards": n_shards,
                        "n_replicas": n_replicas,
                        "replica": rr,
                        "r": int(r_h[:, local_row].sum()),
                        "batch_size": len(active),
                    }
                    if sub_keys is not None:
                        stats["subexprs"] = [
                            (key, _compact_u32(sub[local_row]))
                            for key, sub in zip(sub_keys[qi], subs_h)
                        ]
                    results[qi] = (_compact_u32(root_h[local_row]), stats)
            if not rerun:
                return results  # type: ignore[return-value]
            active = rerun
            cap = local_total  # one re-run at local total: no overflow
            EXEC_COUNTERS["expr_rerun_calls"] += 1
            handles, slice_len = issue(active, cap)

    return PendingBatch(n_queries=len(ordered), handles=first_handles,
                        _collect=collect)


def intersect_expr_batch(queries, eshape, capacity=None, sub_keys=None):
    """Synchronous expression bucket execution (dispatch + collect)."""
    return dispatch_expr_batch(
        queries, eshape, capacity=capacity, sub_keys=sub_keys).collect()


def intersect_expr_sharded_batch(queries, eshape, mesh, axis=SHARD_AXIS,
                                 capacity_per_shard=None, sub_keys=None):
    """Synchronous z-sharded expression bucket execution."""
    return dispatch_expr_sharded_batch(
        queries, eshape, mesh, axis=axis,
        capacity_per_shard=capacity_per_shard, sub_keys=sub_keys).collect()


def intersect_expr_mesh2d_batch(queries, eshape, topology,
                                capacity_per_shard=None, sub_keys=None):
    """Synchronous 2-D expression bucket execution."""
    return dispatch_expr_mesh2d_batch(
        queries, eshape, topology, capacity_per_shard=capacity_per_shard,
        sub_keys=sub_keys).collect()


class BatchedEngine:
    """Corpus-level engine: name -> DeviceSet, query bucketing, jit reuse.

    With a ``mesh`` (1-D, axis ``shard_axis``), :meth:`add` also builds a
    z-sharded mirror of every shardable set at index time and the planner
    routes huge-G queries (``2^t_k >= shard_min_g``) through
    :func:`intersect_sharded_batch` — small queries stay single-device,
    where the shard_map overhead would dominate.  Mutation hooks
    (:meth:`on_mutate`) fire on every :meth:`add` so owners of derived
    state — notably the serving layer's result cache — can invalidate.

    With a ``topology`` (2-D ``exec.topology.Topology``; exclusive with
    ``mesh``), :meth:`add` builds the 2-D mirrors instead — one mirror per
    replica row, z-partitioned over the row's submesh (replication over
    the data axis) — and the planner routes huge-G queries through
    :func:`intersect_mesh2d_batch` while small-query buckets are
    dispatched to the least-loaded replica by the topology's balancer,
    against per-row plain mirrors built lazily on first dispatch.
    """

    def __init__(self, use_pallas="auto", mesh: Optional[Mesh] = None,
                 shard_axis: str = SHARD_AXIS, shard_min_g: int = SHARD_MIN_G,
                 topology=None):
        assert mesh is None or topology is None, (
            "pass a 1-D mesh OR a 2-D topology, not both"
        )
        self.sets: Dict[str, DeviceSet] = {}
        self.sharded_sets: Dict[str, DeviceSet] = {}
        self.use_pallas = use_pallas
        self.mesh = mesh
        self.topology = topology
        self.shard_axis = (topology.shard_axis if topology is not None
                           else shard_axis)
        self.shard_min_g = shard_min_g
        # one plain-mirror dict per replica row (topology only; empty when
        # replicas == 1, where balancer dispatch degenerates to the default
        # single-device path over `sets`)
        self.replica_sets: List[Dict[str, DeviceSet]] = [
            {} for _ in range(topology.replicas)
        ] if topology is not None and topology.replicas > 1 else []
        self.generation = 0
        self._mutation_hooks: List = []

    @property
    def n_shards(self) -> int:
        if self.topology is not None:
            return self.topology.shards
        return self.mesh.shape[self.shard_axis] if self.mesh is not None else 1

    @property
    def n_replicas(self) -> int:
        return self.topology.replicas if self.topology is not None else 1

    def on_mutate(self, hook) -> None:
        """Register a zero-arg callback fired after every index mutation."""
        self._mutation_hooks.append(hook)

    def add(self, name: str, idx: PrefixIndex) -> None:
        ds = DeviceSet.from_host(idx)
        self.sets[name] = ds
        if self.topology is not None:
            # topology mirrors are built lazily on first use
            # (get_replica_set / get_mesh_set) — eagerly replicating every
            # set on every row would multiply device memory for the whole
            # index by the replica count at build time, when only the
            # terms that actually dispatch need row mirrors.  A replaced
            # term must drop its stale lazy mirrors, though.
            for mirrors in self.replica_sets:
                mirrors.pop(name, None)
            self.sharded_sets.pop(name, None)
        elif self.mesh is not None and ds.shardable(self.n_shards):
            self.sharded_sets[name] = ds.shard(self.mesh, self.shard_axis)
        self.generation += 1
        for hook in self._mutation_hooks:
            hook()

    def query(self, names: Sequence[str], capacity: Optional[int] = None):
        dsets = [self.sets[n] for n in names]
        return intersect_device(dsets, capacity=capacity, use_pallas=self.use_pallas)

    def query_many(self, queries: Sequence[Sequence[str]]):
        """Plan -> bucket by shape signature -> one jit execution per bucket
        -> scatter back in request order.  Returns [(values, stats), ...].
        With a mesh attached, huge-G buckets run z-sharded; with a 2-D
        topology they run on the full data x shard mesh and small buckets
        spread over the replicas."""
        from ..exec.batch import execute_name_queries

        return execute_name_queries(
            self.sets, queries, use_pallas=self.use_pallas, mesh=self.mesh,
            shard_axis=self.shard_axis, shard_min_g=self.shard_min_g,
            get_sharded_set=self.get_mesh_set, topology=self.topology,
            get_replica_set=self.get_replica_set,
        )

    def get_replica_set(self, r: int, name: str) -> DeviceSet:
        """Resolve ``name`` to replica row ``r``'s plain mirror, building
        it on first use (lazily: only terms that actually dispatch to a
        replica pay the per-row copy).  Falls back to the default mirror
        when the topology has a single replica.  Benign under the serving
        layer's concurrency: all balancer dispatch happens under the
        engines' execution lock, and a racing duplicate ``place`` of the
        same set is just a redundant copy, not a correctness hazard."""
        if not self.replica_sets:
            return self.sets[name]
        mirrors = self.replica_sets[r]
        if name not in mirrors:
            mirrors[name] = self.sets[name].place(
                self.topology.replica_device(r))
        return mirrors[name]

    def get_mesh_set(self, name: str):
        """Resolve ``name`` to its mesh mirror: the 1-D z-sharded mirror
        (``mesh=`` engines, built eagerly at :meth:`add`) or the 2-D
        :class:`ReplicatedDeviceSet` (topology engines, built lazily here
        on first mesh dispatch — one z-sharded mirror per replica row, or
        the rows' plain anchor mirrors when ``shards == 1``).  The same
        concurrency argument as :meth:`get_replica_set` applies."""
        if self.topology is None:
            return self.sharded_sets[name]
        if name not in self.sharded_sets:
            ds = self.sets[name]
            assert ds.shardable(self.n_shards), (
                f"{name!r}: 2^{ds.t} z-groups do not split over "
                f"{self.n_shards} shards (the planner never mesh-routes "
                "misaligned sets)"
            )
            if self.n_shards > 1:
                rows = tuple(
                    ds.shard(self.topology.row_mesh(r), self.shard_axis)
                    for r in range(self.n_replicas))
            else:
                rows = tuple(self.get_replica_set(r, name)
                             for r in range(self.n_replicas))
            self.sharded_sets[name] = ReplicatedDeviceSet(rows)
        return self.sharded_sets[name]

    def warm(self, sample_queries: Sequence[Sequence[str]], top_k: int = 8,
             b_tiers: Sequence[int] = (1,)):
        """Compile-cache warming from a name-keyed sample workload
        (index-build time).  Plans the sample (with this engine's sharded
        routing, so sharded signatures warm sharded executables) and
        delegates the policy to :func:`warm_from_plans`.  Returns the
        warmed :class:`~repro.exec.plan.ShapeSig`\\ s, most frequent first.
        """
        from ..exec.plan import plan_query

        plans = [
            plan_query(self.sets, q, hashbin_ratio=float("inf"), device=True,
                       mesh_shards=self.n_shards,
                       mesh_replicas=self.n_replicas,
                       shard_min_g=self.shard_min_g)
            for q in sample_queries
        ]
        return warm_from_plans(
            plans, lambda t: self.sets[t], top_k=top_k, b_tiers=b_tiers,
            use_pallas=self.use_pallas, mesh=self.mesh, axis=self.shard_axis,
            get_sharded_set=self.get_mesh_set,
            topology=self.topology, get_replica_set=self.get_replica_set,
        )
