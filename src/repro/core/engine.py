"""Device-resident batched intersection engine (the paper's system on TPU).

Pre-processed sets (``partition.PrefixIndex``) are mirrored to the device as
dense arrays; intersections run as two fused phases:

  phase 1 (filter):  gather prefix-aligned images, k-way AND, m-way test
                     (kernels.ops.bitmap_filter — the paper's Alg. 5 line 3)
  phase 2 (recover): compact survivors to a static capacity, all-pairs match
                     of the raw groups (kernels.ops.group_match)

Static shapes everywhere: the survivor set is compacted into a fixed
``capacity`` buffer (overflow flag returned; the serving layer re-runs the
rare overflowing query with doubled capacity).  This preserves the paper's
work-saving — the expensive phase 2 runs on ``capacity ≈ E[survivors]``
group tuples instead of all ``G`` — inside an XLA-compatible regime.

Distribution: :func:`intersect_sharded` shard_maps the z-prefix space over
the ``model`` mesh axis.  Because every set is partitioned by the *same*
permutation ``g`` (Theorem 3.7's alignment), equal z-range blocks of every
set land on the same shard and both phases are entirely local; only the
per-shard result buffers are concatenated at the end.  The paper's
partitioning function doubles as the sharding function.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from .partition import PrefixIndex

__all__ = ["DeviceSet", "intersect_device", "intersect_sharded", "BatchedEngine"]


@dataclasses.dataclass(frozen=True)
class DeviceSet:
    """Device mirror of a PrefixIndex (sentinel-padded; mask implicit)."""

    t: int
    gmax: int
    m: int
    w: int
    n: int
    vals: jnp.ndarray     # (2^t, gmax) int32 (original values; -1 padding)
    images: jnp.ndarray   # (2^t, m, W) uint32

    @classmethod
    def from_host(cls, idx: PrefixIndex) -> "DeviceSet":
        assert int(idx.values.max(initial=0)) < 0xFFFFFFFF, "sentinel collision"
        vals = jax.lax.bitcast_convert_type(jnp.asarray(idx.padded_vals), jnp.int32)
        return cls(
            t=idx.t, gmax=idx.gmax, m=idx.family.m, w=idx.w, n=idx.n,
            vals=vals, images=jnp.asarray(idx.images),
        )


def _aligned_images(images: Sequence[jnp.ndarray], ts: Tuple[int, ...]) -> jnp.ndarray:
    """Stack (k, G, m, W) images aligned by prefix (z_i = z_k >> (t_k - t_i)).

    The largest set's images are used in place; the others are gathered.  A
    gather of 2^{t_k - t_i} repeated rows is a broadcast in disguise — XLA
    lowers it to one; we reshape+broadcast explicitly to keep HLO bytes
    honest (no gather scatter overhead in the roofline).
    """
    tk = ts[-1]
    out = []
    for img, t in zip(images, ts):
        if t == tk:
            out.append(img)
        else:
            rep = 1 << (tk - t)
            g, m, w = img.shape
            out.append(jnp.broadcast_to(img[:, None], (g, rep, m, w)).reshape(g * rep, m, w))
    return jnp.stack(out)


@functools.partial(
    jax.jit, static_argnames=("ts", "gmaxes", "capacity", "use_pallas")
)
def _intersect_k(
    vals: Tuple[jnp.ndarray, ...],
    images: Tuple[jnp.ndarray, ...],
    ts: Tuple[int, ...],
    gmaxes: Tuple[int, ...],
    capacity: int,
    use_pallas,
):
    k = len(vals)
    tk = ts[-1]
    G = 1 << tk
    imgs = _aligned_images(images, ts)
    passed = ops.bitmap_filter(imgs, use_pallas)               # (G,) bool
    n_surv = passed.sum()
    surv = jnp.nonzero(passed, size=capacity, fill_value=G)[0]
    valid_row = surv < G
    surv_c = jnp.minimum(surv, G - 1)
    base = vals[0][surv_c >> (tk - ts[0])]                     # (cap, g0)
    keep = valid_row[:, None] & (base != -1)
    for v, t in zip(vals[1:], ts[1:]):
        other = v[surv_c >> (tk - t)]
        keep = keep & ops.group_match(base, other, use_pallas)
    r = keep.sum()
    overflow = n_surv > capacity
    return base, keep, r, n_surv, overflow


def intersect_device(
    sets: Sequence[DeviceSet],
    capacity: Optional[int] = None,
    use_pallas="auto",
):
    """Intersect k device sets; returns (values, count) on host + stats.

    ``capacity`` defaults to a survivor estimate: non-empty-intersection
    groups ≲ r_max/1 + false-positive rate * G; we use G_k/4 + 64 which is
    conservative for the paper's r << n regime, and double on overflow.
    """
    sets = sorted(sets, key=lambda s: s.t)
    ts = tuple(s.t for s in sets)
    gmaxes = tuple(s.gmax for s in sets)
    vals = tuple(s.vals for s in sets)
    images = tuple(s.images for s in sets)
    G = 1 << ts[-1]
    cap = capacity or max(64, G // 4)
    while True:
        base, keep, r, n_surv, overflow = _intersect_k(
            vals, images, ts, gmaxes, cap, use_pallas
        )
        if not bool(overflow):
            break
        cap = min(G, cap * 2)  # rare path: re-run with doubled capacity
    out = np.asarray(base)[np.asarray(keep)]
    result = np.sort(out.astype(np.uint32))
    stats = {
        "group_tuples": G,
        "tuples_survived": int(n_surv),
        "capacity": cap,
        "r": int(r),
    }
    return result, stats


# --------------------------------------------------------------------------
# shard_map distribution over the z-prefix space
# --------------------------------------------------------------------------

def intersect_sharded(
    sets: Sequence[DeviceSet],
    mesh: Mesh,
    axis: str = "model",
    capacity_per_shard: int = 256,
    use_pallas=False,
):
    """Zero-communication sharded intersection.

    Every set's group arrays are sharded along z over ``axis``.  Alignment
    (z_i = z_k >> shift) maps a shard's z_k range into the *same* shard's
    z_i range whenever n_shards <= 2^{t_1} — guaranteed by construction for
    corpus-scale sets.  Phase 1+2 run locally per shard; per-shard result
    buffers are returned still sharded (callers all-gather only the final
    compact results, never the posting data).
    """
    sets = sorted(sets, key=lambda s: s.t)
    n_shards = mesh.shape[axis]
    ts = tuple(s.t for s in sets)
    assert (1 << ts[0]) % n_shards == 0, "smallest set must split over shards"
    vals = tuple(s.vals for s in sets)
    images = tuple(s.images for s in sets)
    tk = ts[-1]

    from jax.experimental.shard_map import shard_map

    def local_fn(*flat):
        lvals, limages = flat[: len(sets)], flat[len(sets):]
        G_local = limages[-1].shape[0]
        imgs = _aligned_images(limages, ts)
        passed = ops.bitmap_filter(imgs, use_pallas)
        n_surv = passed.sum()
        surv = jnp.nonzero(passed, size=capacity_per_shard, fill_value=G_local)[0]
        valid = surv < G_local
        surv_c = jnp.minimum(surv, G_local - 1)
        base = lvals[0][surv_c >> (tk - ts[0])]
        keep = valid[:, None] & (base != -1)
        for v, t in zip(lvals[1:], ts[1:]):
            other = v[surv_c >> (tk - t)]
            keep = keep & ops.group_match(base, other, use_pallas)
        # local padded results; -1 where dropped
        out = jnp.where(keep, base, -1)
        return out, n_surv[None], passed.sum()[None]

    in_specs = tuple([P(axis)] * (2 * len(sets)))
    out_specs = (P(axis), P(axis), P(axis))
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    out, n_surv, _ = fn(*vals, *images)
    return out, n_surv


class BatchedEngine:
    """Corpus-level engine: name -> DeviceSet, query bucketing, jit reuse."""

    def __init__(self, use_pallas="auto"):
        self.sets = {}
        self.use_pallas = use_pallas

    def add(self, name: str, idx: PrefixIndex) -> None:
        self.sets[name] = DeviceSet.from_host(idx)

    def query(self, names: Sequence[str], capacity: Optional[int] = None):
        dsets = [self.sets[n] for n in names]
        return intersect_device(dsets, capacity=capacity, use_pallas=self.use_pallas)
