"""The paper's intersection algorithms (host reference implementations).

Four families, matching Section 3:

* :func:`intgroup`        — Alg. 1 + Alg. 2 (fixed-width partitions, 2 sets)
* :func:`rangroup`        — Alg. 3 / Alg. 4 (randomized partitions, k sets,
                             single-h inverted-mapping recovery)
* :func:`rangroupscan`    — Alg. 5 (m filter images + linear scan recovery;
                             the practical algorithm) — fully vectorized
* :func:`hashbin`         — Section 3.4 (skewed sizes; per-bin binary search)

Each returns ``(result, Stats)``.  ``Stats`` carries *implementation
independent* operation counters (group tuples examined / filtered, element
pairs touched, comparisons) used to validate the paper's claims in a way that
does not depend on Python-vs-C constant factors; wall-clock comparisons in
``benchmarks/`` additionally pit the vectorized fast paths against equally
vectorized baselines.

The filter phases are vectorized numpy; survivor recovery walks the faithful
``first/next`` inverted mappings (IntGroup/RanGroup) or a vectorized
all-pairs match (RanGroupScan — the same formulation the TPU kernel uses).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .partition import FixedWidthIndex, PrefixIndex, SENTINEL

__all__ = ["Stats", "intgroup", "rangroup", "rangroupscan", "hashbin"]


@dataclasses.dataclass
class Stats:
    algorithm: str
    k: int
    n_total: int
    r: int = 0
    group_tuples: int = 0        # tuples (pairs) of small groups examined
    tuples_filtered: int = 0     # tuples whose word-AND proved emptiness
    tuples_survived: int = 0     # tuples that reached the recovery phase
    element_pairs: int = 0       # |I| — element pairs sharing a hash value
    elements_touched: int = 0    # elements read during recovery
    comparisons: int = 0         # value comparisons (merge/binary search)
    words_read: int = 0          # packed bitmap lanes read by the filter

    @property
    def filter_rate(self) -> float:
        empty = max(1, self.group_tuples)
        return self.tuples_filtered / empty


# --------------------------------------------------------------------------
# IntGroup — Section 3.1 (Algorithms 1 + 2)
# --------------------------------------------------------------------------

def _walk_inverted(idx, gi: int, y: int) -> List[int]:
    """h^{-1}(y, group gi) via the first/next threading (ordered access)."""
    lo, hi = idx.offsets[gi], idx.offsets[gi + 1]
    ys = idx.first_y[gi]
    pos = np.searchsorted(ys, y)
    if pos == len(ys) or ys[pos] != y:
        return []
    cur = int(idx.first_idx[gi][pos])
    out = []
    while cur != -1 and cur < hi:
        out.append(cur)
        cur = int(idx.nxt[cur])
    return out


def intgroup(A: FixedWidthIndex, B: FixedWidthIndex,
             recovery: str = "searchsorted") -> Tuple[np.ndarray, Stats]:
    """Algorithm 1: scan fixed-width groups in order, intersect overlapping
    pairs with IntersectSmall (Algorithm 2).

    recovery="inverted" walks the faithful first/next threaded mappings
    (Fig. 2); "searchsorted" recovers survivors with one vectorized binary
    search (values are globally sorted; a hit counts only inside the paired
    group's range) — C-speed, same results.
    """
    assert A.w == B.w and A.family is B.family, "both sets must share h"
    st = Stats("intgroup", 2, A.n + B.n)
    # --- vectorized Algorithm-1 pairing: group q of B overlaps group p of A
    # iff [lo_q, hi_q] ∩ [lo_p, hi_p] != ∅; overlapping q's are a contiguous
    # range per p because both sides are sorted (two-pointer walk, batched).
    qlo = np.searchsorted(B.hi, A.lo, side="left")
    qhi = np.searchsorted(B.lo, A.hi, side="right")
    counts = np.maximum(0, qhi - qlo)
    p_ids = np.repeat(np.arange(A.G), counts)
    q_ids = (np.arange(len(p_ids))
             - np.repeat(np.cumsum(counts) - counts, counts)) + np.repeat(qlo, counts)
    st.group_tuples = len(p_ids)
    # --- Algorithm 2, phase 1: H = h(A^p) AND h(B^q), vectorized
    Ha = A.images[p_ids, 0]                      # (P, W)
    Hb = B.images[q_ids, 0]
    H = Ha & Hb
    st.words_read = H.size * 2
    nz = np.bitwise_or.reduce(H, axis=1) != 0
    st.tuples_filtered = int((~nz).sum())
    st.tuples_survived = int(nz.sum())

    if recovery == "searchsorted":
        pa = A.padded_vals[p_ids[nz]]                    # (P, s)
        ma = A.mask[p_ids[nz]]
        flat = pa[ma]
        pair_of = np.repeat(np.arange(len(pa)), ma.sum(axis=1))
        st.elements_touched += len(flat)
        pos = np.searchsorted(B.values, flat).clip(max=B.n - 1)
        st.comparisons += len(flat) * max(1, int(math.log2(B.n + 1)))
        found = B.values[pos] == flat
        qf = q_ids[nz][pair_of]
        in_q = (pos >= B.offsets[qf]) & (pos < B.offsets[qf + 1])
        hits = flat[found & in_q]
        st.element_pairs = len(hits)
        result = np.unique(hits).astype(np.uint32)
        st.r = len(result)
        return result, st

    # --- Algorithm 2, phase 2: recover via inverted mappings per set bit y
    out: List[int] = []
    W = A.w // 32
    for p, q, h_pair in zip(p_ids[nz], q_ids[nz], H[nz]):
        for lane in range(W):
            word = int(h_pair[lane])
            while word:
                low = word & -word
                y = lane * 32 + low.bit_length() - 1
                word ^= low
                ia = _walk_inverted(A, int(p), y)
                ib = _walk_inverted(B, int(q), y)
                st.elements_touched += len(ia) + len(ib)
                # linear merge of the two short value-ordered lists
                va = A.values[ia]
                vb = B.values[ib]
                i = j = 0
                while i < len(va) and j < len(vb):
                    st.comparisons += 1
                    if va[i] == vb[j]:
                        out.append(int(va[i])); i += 1; j += 1
                        st.element_pairs += 1
                    elif va[i] < vb[j]:
                        i += 1
                    else:
                        j += 1
    result = np.unique(np.asarray(out, dtype=np.uint32))
    st.r = len(result)
    return result, st


# --------------------------------------------------------------------------
# RanGroup — Section 3.2 (Algorithms 3 + 4), single-h recovery
# --------------------------------------------------------------------------

def rangroup(indexes: Sequence[PrefixIndex]) -> Tuple[np.ndarray, Stats]:
    """Algorithm 4 (Algorithm 3 when k == 2): prefix-aligned groups, one
    word-image AND, recovery through the inverted mappings.

    The AND phase over all z_k is vectorized (one gather + AND per set, the
    memoized-partial-AND trick of Appendix A.3 is subsumed by reuse of the
    gathered rows); survivors are recovered via h^{-1} walks.
    """
    idxs = sorted(indexes, key=lambda s: s.t)
    k = len(idxs)
    st = Stats("rangroup", k, sum(s.n for s in idxs))
    tk = idxs[-1].t
    G = 1 << tk
    zk = np.arange(G, dtype=np.int64)
    H = idxs[-1].images[:, 0, :].copy()          # (G, W) — use h_1 only
    st.words_read += H.size
    z_of = []
    for s in idxs[:-1]:
        zi = zk >> (tk - s.t)
        z_of.append(zi)
        H &= s.images[zi, 0, :]
        st.words_read += H.size
    z_of.append(zk)
    st.group_tuples = G
    nz = np.bitwise_or.reduce(H, axis=1) != 0
    st.tuples_filtered = int((~nz).sum())
    st.tuples_survived = int(nz.sum())
    out: List[int] = []
    W = idxs[0].w // 32
    for row in np.nonzero(nz)[0]:
        h_row = H[row]
        for lane in range(W):
            word = int(h_row[lane])
            while word:
                low = word & -word
                y = lane * 32 + low.bit_length() - 1
                word ^= low
                lists = []
                for s, zi in zip(idxs, z_of):
                    ii = _walk_inverted_prefix(s, int(zi[row]), y)
                    st.elements_touched += len(ii)
                    lists.append(s.values[ii])
                common = lists[0]
                for other in lists[1:]:
                    st.comparisons += len(common) + len(other)
                    common = np.intersect1d(common, other)
                    if len(common) == 0:
                        break
                out.extend(int(v) for v in common)
                st.element_pairs += len(common)
    result = np.unique(np.asarray(out, dtype=np.uint32))
    st.r = len(result)
    return result, st


def _walk_inverted_prefix(idx: PrefixIndex, z: int, y: int) -> List[int]:
    """h^{-1}(y, L^z) for a PrefixIndex with inverted mappings attached."""
    if not hasattr(idx, "_nxt"):
        _attach_inverted(idx)
    lo, hi = idx.offsets[z], idx.offsets[z + 1]
    ys = idx._first_y[z]
    pos = np.searchsorted(ys, y)
    if pos == len(ys) or ys[pos] != y:
        return []
    cur = int(idx._first_idx[z][pos])
    out = []
    while cur != -1 and cur < hi:
        out.append(cur)
        cur = int(idx._nxt[cur])
    return out


def _attach_inverted(idx: PrefixIndex) -> None:
    """Lazily build the Fig.-2 first/next threading for a PrefixIndex
    (only RanGroup's recovery needs it; RanGroupScan does not — §3.3)."""
    from .partition import _first_next

    h_vals = np.asarray(idx.family.apply(idx.values, 0))
    nxt, first_y, first_idx = _first_next(h_vals, idx.offsets, idx.w)
    idx._nxt = nxt
    idx._first_y = first_y
    idx._first_idx = first_idx


# --------------------------------------------------------------------------
# RanGroupScan — Section 3.3 (Algorithm 5), fully vectorized
# --------------------------------------------------------------------------

def rangroupscan(indexes: Sequence[PrefixIndex],
                 recovery: str = "searchsorted") -> Tuple[np.ndarray, Stats]:
    """Algorithm 5: skip a group tuple if ANY of the m image-ANDs is empty;
    intersect survivors by scanning the raw groups.

    Two equivalent survivor-recovery executions (same elements touched,
    same results — the skip structure is the algorithm; recovery is an
    execution detail):

    * "allpairs"     — masked all-pairs equality on the padded dense groups;
                       the branch-free formulation the TPU kernel uses
                       (kernels/group_intersect.py).
    * "searchsorted" — one vectorized binary search of every survivor
                       element into the other sets' g-sorted key arrays
                       (groups are contiguous g-intervals, so the global
                       search visits exactly the aligned group).  This is
                       the CPU-optimal form: a single C call replaces the
                       broadcast compare.  Default on host.
    """
    idxs = sorted(indexes, key=lambda s: s.t)
    k = len(idxs)
    st = Stats("rangroupscan", k, sum(s.n for s in idxs))
    tk = idxs[-1].t
    G = 1 << tk
    zk = np.arange(G, dtype=np.int64)
    z_of = [zk >> (tk - s.t) for s in idxs]
    # --- filter phase: pass only if ALL m image-ANDs are non-empty (line 3).
    # One fused AND pass over the (G, m, W) image arrays; aligned gathers are
    # skipped when t_i == t_k (identity).
    H = idxs[-1].images
    st.words_read += H.size
    for s, zi in zip(idxs[:-1], z_of[:-1]):
        im = s.images if s.t == tk else s.images[zi]
        st.words_read += im.size
        H = H & im
    nz_any = np.bitwise_or.reduce(H, axis=2) != 0        # (G, m)
    pass_mask = nz_any.all(axis=1)
    st.group_tuples = G
    st.tuples_survived = int(pass_mask.sum())
    st.tuples_filtered = G - st.tuples_survived
    surv = np.nonzero(pass_mask)[0]
    if len(surv) == 0:
        return np.empty(0, dtype=np.uint32), st

    if recovery == "searchsorted":
        # Gather surviving groups of the smallest set as (flat) g-keys, then
        # one vectorized binary search per other set.  Prefix alignment
        # guarantees a hit can only occur inside the aligned group, so a
        # global search over the g-sorted keys is exact.
        keys = idxs[0].padded_keys[z_of[0][surv]]       # (S, g0)
        mask = idxs[0].mask[z_of[0][surv]]
        flat = keys[mask]                               # true elements only
        st.elements_touched += len(flat)
        keep = np.ones(len(flat), dtype=bool)
        for s in idxs[1:]:
            pos = np.searchsorted(s.g_keys, flat).clip(max=s.n - 1)
            st.comparisons += len(flat) * max(1, int(math.log2(s.n + 1)))
            keep &= s.g_keys[pos] == flat
        hits = flat[keep]
        st.element_pairs = len(hits)
        # map g-keys back to original values; unique() dedups base elements
        # that appeared under several surviving z_k children (t_0 < t_k)
        pos0 = np.searchsorted(idxs[0].g_keys, np.unique(hits))
        result = np.sort(idxs[0].values[pos0]).astype(np.uint32)
        st.r = len(result)
        return result, st

    # --- "allpairs" recovery: masked all-pairs match (TPU-shaped reference)
    base_vals = idxs[0].padded_vals[z_of[0][surv]]      # (S, g0)
    keep = idxs[0].mask[z_of[0][surv]]
    st.elements_touched += int(keep.sum())
    for s, zi in zip(idxs[1:], z_of[1:]):
        other = s.padded_vals[zi[surv]]                 # (S, gi)
        st.elements_touched += int(s.mask[zi[surv]].sum())
        st.comparisons += keep.sum() * other.shape[1]
        keep &= (base_vals[:, :, None] == other[:, None, :]).any(axis=2)
    result = np.unique(base_vals[keep]).astype(np.uint32)
    st.r = len(result)
    st.element_pairs = int(keep.sum())
    return result, st


# --------------------------------------------------------------------------
# HashBin — Section 3.4
# --------------------------------------------------------------------------

def hashbin(A: PrefixIndex, B: PrefixIndex) -> Tuple[np.ndarray, Stats]:
    """Per-bin binary search of each x in the smaller set (A) inside the
    matching bin of B, in g-order (Appendix A.6.1).

    Execution is the vectorized global ``searchsorted`` over B's g-sorted
    keys (bins are contiguous intervals, so the per-bin search visits the
    same elements); ``comparisons`` is counted faithfully per-bin as
    ``|A^z| * ceil(log2(|B^z| + 1))``.
    """
    if A.n > B.n:
        A, B = B, A
    st = Stats("hashbin", 2, A.n + B.n)
    t = max(0, math.ceil(math.log2(max(1, A.n))))
    # bin boundaries at resolution t, computed on demand from sorted g-keys
    bounds = ((np.arange((1 << t) + 1, dtype=np.uint64) << (32 - t))
              .astype(np.uint32) if t else np.array([0, 0], np.uint32))
    if t:
        offA = np.searchsorted(A.g_keys, bounds[:-1]).astype(np.int64)
        offB = np.searchsorted(B.g_keys, bounds[:-1]).astype(np.int64)
        cntA = np.diff(np.concatenate([offA, [A.n]]))
        cntB = np.diff(np.concatenate([offB, [B.n]]))
        st.comparisons = int(np.sum(cntA * np.ceil(np.log2(cntB + 1))))
    else:
        st.comparisons = int(A.n * math.ceil(math.log2(B.n + 1)))
    pos = np.searchsorted(B.g_keys, A.g_keys).clip(max=B.n - 1)
    found = B.g_keys[pos] == A.g_keys
    result = np.sort(A.values[found]).astype(np.uint32)
    st.r = len(result)
    st.elements_touched = A.n
    st.group_tuples = 1 << t
    return result, st
