"""Compression for the pre-processed structures (Appendix B) + γ/δ coding.

Appendix-B scheme for Algorithm 5's blocks:
  (i)   group sizes |L^z| in unary code;
  (ii)  m hash-image words only when |L^z| > 0;
  (iii) elements stored as lowbits_t(x) = g(x) mod 2^{32-t} — the high t bits
        are the group id z, reconstructed by concatenation at query time.

Decode is a shift-and-OR per group — the "much more efficient than γ/δ"
property the paper measures.  γ/δ (Elias) coders are provided for the
compressed Merge/Lookup baselines and space accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from .partition import PrefixIndex

__all__ = [
    "LowbitsIndex", "compress_lowbits", "decompress_group",
    "gamma_encode", "gamma_decode", "delta_encode", "delta_decode",
    "space_report",
]


@dataclasses.dataclass
class LowbitsIndex:
    """Appendix-B compressed form of a PrefixIndex."""

    t: int
    w: int
    m: int
    n: int
    counts: np.ndarray        # (2^t,) — stored unary in the bit accounting
    offsets: np.ndarray       # (2^t+1,)
    lowbits: np.ndarray       # (n,) minimal-width storage of g(x) mod 2^{32-t}
    lowbits_dtype: str
    images: np.ndarray        # (#nonempty, m, W) — only for non-empty groups
    nonempty_map: np.ndarray  # (2^t,) -> row in images or -1

    def storage_bits(self) -> int:
        """Appendix-B accounting: unary sizes + images (non-empty only) +
        (32 - t) bits per element."""
        unary = int(self.n + len(self.counts))          # n ones + G zeros
        imgs = int((self.counts > 0).sum()) * self.m * self.w
        elems = self.n * (32 - self.t)
        return unary + imgs + elems


def compress_lowbits(idx: PrefixIndex) -> LowbitsIndex:
    low_width = 32 - idx.t
    low = idx.g_keys & np.uint32((1 << low_width) - 1) if low_width < 32 else idx.g_keys
    if low_width <= 8:
        stored, sdt = low.astype(np.uint8), "uint8"
    elif low_width <= 16:
        stored, sdt = low.astype(np.uint16), "uint16"
    else:
        stored, sdt = low.astype(np.uint32), "uint32"
    counts = np.diff(idx.offsets).astype(np.int64)
    nonempty = np.nonzero(counts > 0)[0]
    nonempty_map = np.full(len(counts), -1, dtype=np.int64)
    nonempty_map[nonempty] = np.arange(len(nonempty))
    return LowbitsIndex(
        t=idx.t, w=idx.w, m=idx.family.m, n=idx.n,
        counts=counts, offsets=idx.offsets, lowbits=stored, lowbits_dtype=sdt,
        images=idx.images[nonempty], nonempty_map=nonempty_map,
    )


def decompress_group(cidx: LowbitsIndex, z: int) -> np.ndarray:
    """Reconstruct the g-keys of group z: concatenate z to the low bits."""
    lo, hi = cidx.offsets[z], cidx.offsets[z + 1]
    low = cidx.lowbits[lo:hi].astype(np.uint32)
    if cidx.t == 0:
        return low
    return (np.uint32(z) << np.uint32(32 - cidx.t)) | low


# ---------------------------------------------------------------------------
# Elias γ / δ coding (bit-level, for baselines' compressed posting lists)
# ---------------------------------------------------------------------------

def _to_gaps(sorted_vals: np.ndarray) -> np.ndarray:
    g = np.empty_like(sorted_vals)
    g[0] = sorted_vals[0] + 1  # codes need positives
    g[1:] = sorted_vals[1:] - sorted_vals[:-1]
    return g.astype(np.uint64)


def gamma_encode(sorted_vals: np.ndarray) -> Tuple[np.ndarray, int]:
    """Elias-γ over d-gaps -> packed bit array (np.uint8) + bit length."""
    gaps = _to_gaps(sorted_vals)
    nbits_val = np.floor(np.log2(gaps)).astype(np.int64)
    total = int(np.sum(2 * nbits_val + 1))
    out = np.zeros((total + 7) // 8, dtype=np.uint8)
    starts = np.concatenate([[0], np.cumsum(2 * nbits_val + 1)])[:-1]
    for gap, nb, st in zip(gaps.tolist(), nbits_val.tolist(), starts.tolist()):
        p = st + nb  # nb zeros, then the (nb+1)-bit binary of gap (MSB first)
        for b in range(nb, -1, -1):
            if (gap >> b) & 1:
                out[(p) >> 3] |= 1 << ((p) & 7)
            p += 1
    return out, total


def gamma_decode(bits: np.ndarray, total_bits: int) -> np.ndarray:
    unpacked = np.unpackbits(bits, bitorder="little")[:total_bits]
    vals = []
    i = 0
    while i < total_bits:
        nb = 0
        while unpacked[i] == 0:
            nb += 1; i += 1
        val = 0
        for _ in range(nb + 1):
            val = (val << 1) | int(unpacked[i]); i += 1
        vals.append(val)
    gaps = np.asarray(vals, dtype=np.uint64)
    out = np.cumsum(gaps) - 1
    return out.astype(np.uint32)


def delta_encode(sorted_vals: np.ndarray) -> Tuple[np.ndarray, int]:
    """Elias-δ over d-gaps: γ-code the length field — smaller asymptotically."""
    gaps = _to_gaps(sorted_vals)
    nb = np.floor(np.log2(gaps)).astype(np.int64)           # value bits - 1
    lb = np.floor(np.log2(nb + 1)).astype(np.int64)          # γ of (nb+1)
    lens = 2 * lb + 1 + nb
    total = int(lens.sum())
    out = np.zeros((total + 7) // 8, dtype=np.uint8)
    starts = np.concatenate([[0], np.cumsum(lens)])[:-1]
    for gap, n_, l_, st in zip(gaps.tolist(), nb.tolist(), lb.tolist(),
                               starts.tolist()):
        p = st + l_  # l_ zeros then (l_+1)-bit binary of (n_+1)
        ln = n_ + 1
        for b in range(l_, -1, -1):
            if (ln >> b) & 1:
                out[p >> 3] |= 1 << (p & 7)
            p += 1
        for b in range(n_ - 1, -1, -1):  # n_ low bits of gap (MSB first)
            if (gap >> b) & 1:
                out[p >> 3] |= 1 << (p & 7)
            p += 1
    return out, total


def delta_decode(bits: np.ndarray, total_bits: int) -> np.ndarray:
    unpacked = np.unpackbits(bits, bitorder="little")[:total_bits]
    vals = []
    i = 0
    while i < total_bits:
        lb = 0
        while unpacked[i] == 0:
            lb += 1; i += 1
        ln = 0
        for _ in range(lb + 1):
            ln = (ln << 1) | int(unpacked[i]); i += 1
        nb = ln - 1
        val = 1
        for _ in range(nb):
            val = (val << 1) | int(unpacked[i]); i += 1
        vals.append(val)
    gaps = np.asarray(vals, dtype=np.uint64)
    return (np.cumsum(gaps) - 1).astype(np.uint32)


def space_report(idx: PrefixIndex) -> Dict[str, float]:
    """Bits-per-element of each representation (paper §4 'size' + Fig. 8)."""
    n = idx.n
    plain = 32.0
    un_scan = idx.storage_words() * 32 / n
    cidx = compress_lowbits(idx)
    low = cidx.storage_bits() / n
    gbits = gamma_encode(np.sort(idx.values))[1] / n
    dbits = delta_encode(np.sort(idx.values))[1] / n
    return {
        "plain_inverted": plain,
        "rangroupscan_uncompressed": un_scan,
        "rangroupscan_lowbits": low,
        "merge_gamma": gbits,
        "merge_delta": dbits,
    }
