"""Competitor algorithms from Section 4 — every baseline the paper compares.

All operate on sorted uint32 numpy arrays.  Where the algorithm is a
vectorizable C-speed primitive (Merge via sorted intersect, SvS via
galloping searchsorted, Lookup via bucketed searchsorted, Hash via a
C-backed hash container) the implementation is vectorized numpy, so
wall-clock comparisons against the (equally vectorized) paper algorithms
are meaningful.  SkipList, BaezaYates and BPP are inherently serial
pointer-walks; they are implemented faithfully (python loops) and, as in
the paper's own measurements, land at the bottom of every timing chart —
we report their operation counts alongside to keep the comparison honest.

Each function returns ``(result, stats_dict)``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "merge", "svs_gallop", "hash_lookup", "lookup_st", "baezayates",
    "skiplist", "bpp", "BASELINES",
]


def merge(sets: Sequence[np.ndarray]) -> Tuple[np.ndarray, Dict]:
    """Linear merge (parallel scan) — the inverted-index workhorse.

    np.intersect1d with assume_unique on pre-sorted inputs is the C
    equivalent of the branch-minimized scan the paper implements.
    """
    out = sets[0]
    comparisons = 0
    for s in sets[1:]:
        comparisons += len(out) + len(s)
        out = np.intersect1d(out, s, assume_unique=True)
        if len(out) == 0:
            break
    return out.astype(np.uint32), {"comparisons": comparisons}


def svs_gallop(sets: Sequence[np.ndarray]) -> Tuple[np.ndarray, Dict]:
    """SvS with galloping/binary search: intersect smallest-first by probing
    each candidate into the next list (Demaine et al. / standard SvS)."""
    order = sorted(sets, key=len)
    out = order[0]
    comparisons = 0
    for s in order[1:]:
        if len(out) == 0:
            break
        pos = np.searchsorted(s, out)
        comparisons += len(out) * max(1, int(math.ceil(math.log2(len(s) + 1))))
        found = (pos < len(s)) & (s[np.minimum(pos, len(s) - 1)] == out)
        out = out[found]
    return out.astype(np.uint32), {"comparisons": comparisons}


def hash_lookup(sets: Sequence[np.ndarray]) -> Tuple[np.ndarray, Dict]:
    """Hash: iterate the smallest set, probe hash tables of the others.

    numpy's np.isin with a dict-backed probe is not available; we use
    python sets (C hash table) — the per-probe indirection cost the paper
    describes is exactly what this measures.
    """
    order = sorted(sets, key=len)
    tables = [set(s.tolist()) for s in order[1:]]
    out = [x for x in order[0].tolist() if all(x in t for t in tables)]
    return (np.asarray(sorted(out), dtype=np.uint32),
            {"probes": len(order[0]) * len(tables)})


def lookup_st(sets: Sequence[np.ndarray], bucket: int = 32) -> Tuple[np.ndarray, Dict]:
    """Sanders/Transier two-level 'Lookup' (ALENEX'07): bucket doc-ids by
    id // B; per element of the smaller set, scan the matching bucket of the
    larger.  Vectorized: bucket boundaries via searchsorted, then a bounded
    per-bucket scan implemented as a clipped window equality test."""
    order = sorted(sets, key=len)
    out = order[0]
    touched = 0
    for s in order[1:]:
        if len(out) == 0:
            break
        # positions of each candidate's bucket in s; window must cover the
        # largest bucket for exactness
        b_lo = np.searchsorted(s, (out // bucket) * bucket)
        bounds = np.searchsorted(s, np.arange(0, int(s[-1]) + bucket + 1, bucket))
        width = max(1, int(np.diff(bounds).max())) if len(bounds) > 1 else len(s)
        idx = b_lo[:, None] + np.arange(width)[None, :]
        window = s[np.minimum(idx, len(s) - 1)]
        touched += window.size
        found = (window == out[:, None]).any(axis=1)
        out = out[found]
    return out.astype(np.uint32), {"elements_touched": touched}


def baezayates(sets: Sequence[np.ndarray]) -> Tuple[np.ndarray, Dict]:
    """Baeza-Yates divide & conquer (CPM'04), generalized to k sets by
    iterative pairwise application smallest-first (as in [5])."""
    stats = {"comparisons": 0}

    def by_pair(a: np.ndarray, b: np.ndarray, out: List[int]):
        # recursion on the median of the smaller list
        if len(a) == 0 or len(b) == 0:
            return
        if len(a) > len(b):
            a, b = b, a
        mid = len(a) // 2
        x = a[mid]
        pos = int(np.searchsorted(b, x))
        stats["comparisons"] += max(1, int(math.ceil(math.log2(len(b) + 1))))
        if pos < len(b) and b[pos] == x:
            out.append(int(x))
        by_pair(a[:mid], b[:pos], out)
        by_pair(a[mid + 1:], b[pos:], out)

    order = sorted(sets, key=len)
    cur = order[0]
    for s in order[1:]:
        acc: List[int] = []
        by_pair(cur, s, acc)
        cur = np.asarray(sorted(acc), dtype=np.uint32)
        if len(cur) == 0:
            break
    return cur, stats


class _SkipList:
    """Static skip list (Pugh cookbook): level-i pointers skip 2^i nodes.
    Built over a sorted array; supports seek(x) from a moving finger."""

    def __init__(self, arr: np.ndarray, p: int = 2):
        self.arr = arr
        self.levels: List[np.ndarray] = []
        step = p
        while step < len(arr):
            self.levels.append(np.arange(0, len(arr), step))
            step *= p

    def seek(self, x: int, start: int) -> int:
        """first index >= start with arr[idx] >= x; counts comparisons."""
        pos = start
        comps = 0
        for lvl in reversed(self.levels):
            # advance along this level while next skip target < x
            i = np.searchsorted(lvl, pos)
            while i < len(lvl) and self.arr[lvl[i]] < x:
                pos = int(lvl[i]); i += 1; comps += 1
        while pos < len(self.arr) and self.arr[pos] < x:
            pos += 1; comps += 1
        return pos, comps


def skiplist(sets: Sequence[np.ndarray]) -> Tuple[np.ndarray, Dict]:
    order = sorted(sets, key=len)
    base, rest = order[0], order[1:]
    lists = [_SkipList(s) for s in rest]
    fingers = [0] * len(rest)
    out = []
    comparisons = 0
    for x in base.tolist():
        ok = True
        for li, sl in enumerate(lists):
            pos, c = sl.seek(x, fingers[li])
            comparisons += c + 1
            fingers[li] = pos
            if pos >= len(sl.arr) or sl.arr[pos] != x:
                ok = False
                break
        if ok:
            out.append(x)
    return np.asarray(out, dtype=np.uint32), {"comparisons": comparisons}


def bpp(sets: Sequence[np.ndarray], w: int = 64) -> Tuple[np.ndarray, Dict]:
    """Bille-Pagh-Pagh (ISAAC'07), simplified as in the paper's Section 4:
    map elements through h to w/log^2(w)-bit packed approximations, AND the
    packed images, then verify candidates.  Implemented at the word level
    with numpy packing (the heavy bit-trickery is what makes it slow)."""
    logw2 = max(1, int(math.log2(w)) ** 2)
    field = max(2, w // logw2)  # bits per packed slot — 'small' by design
    nbuckets = 1 << 12
    order = sorted(sets, key=len)
    # hash into buckets; per bucket keep a field-bit signature word
    stats = {"words": 0}
    sigs = []
    for s in order:
        h = (s.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(64 - 12)
        sig = np.zeros(nbuckets, dtype=np.uint64)
        sub = (s.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F)) >> np.uint64(64 - 6)
        np.bitwise_or.at(sig, h.astype(np.int64),
                         np.uint64(1) << (sub % np.uint64(min(64, field * 8))))
        sigs.append(sig)
        stats["words"] += nbuckets
    mask = sigs[0]
    for sg in sigs[1:]:
        mask = mask & sg
    # verify: only elements whose bucket-signature bit survived
    def survives(s):
        h = (s.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(64 - 12)
        sub = (s.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F)) >> np.uint64(64 - 6)
        bit = np.uint64(1) << (sub % np.uint64(min(64, field * 8)))
        return (mask[h.astype(np.int64)] & bit) != 0
    cands = [s[survives(s)] for s in order]
    out, st2 = merge(cands)
    stats.update(st2)
    return out, stats


BASELINES = {
    "Merge": merge,
    "SvS": svs_gallop,
    "Hash": hash_lookup,
    "Lookup": lookup_st,
    "BaezaYates": baezayates,
    "SkipList": skiplist,
    "BPP": bpp,
}
