"""Word representations of small sets (Section 3.1), packed for the TPU.

The paper encodes a set ``A ⊆ [w]`` as one w-bit machine word.  On TPU the
natural "word" is a vector of 32-bit VPU lanes, so a w-bit representation is
``W = w // 32`` packed uint32 lanes.  ``w`` is configurable (64..512); the
default used by the engine is 256 (8 lanes), keeping the paper's load factor
``|group|/w = 1/sqrt(w)`` while widening the filter.

Host-side (numpy) helpers build the images during pre-processing; the same
code runs under jax.numpy for device-side image construction (e.g. the
constrained-decoding vocab masks built at serve time).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "num_lanes",
    "build_images",
    "popcount32",
    "bits_to_values",
    "any_nonzero",
]


def _xp(x):
    if isinstance(x, np.ndarray):
        return np
    import jax.numpy as jnp

    return jnp


def num_lanes(w: int) -> int:
    assert w % 32 == 0 and w & (w - 1) == 0
    return w // 32


def build_images(hashes, valid, w: int):
    """Pack per-element hash values into word-representation bitmaps.

    Args:
      hashes: (..., G, gmax, m) uint32 in [0, w) — hash of each element under
        each of the m functions (padding rows may hold arbitrary values).
      valid:  (..., G, gmax) bool — which elements are real.
      w: bitmap width in bits.

    Returns:
      (..., G, m, W) uint32 — the m word representations per group.
    """
    xp = _xp(hashes)
    W = num_lanes(w)
    lane = (hashes >> np.uint32(5)).astype(xp.int32)  # word index in [0, W)
    bit = xp.left_shift(xp.asarray(1, dtype=xp.uint32), (hashes & np.uint32(31)))
    # one-hot over lanes: (..., G, gmax, m, W)
    onehot = (lane[..., None] == xp.arange(W, dtype=xp.int32)).astype(xp.uint32)
    contrib = onehot * bit[..., None]
    contrib = contrib * valid[..., None, None].astype(xp.uint32)
    # OR-reduce over the elements of the group (same bit can repeat, so a
    # bitwise OR reduction — supported by the ufunc in both np and jnp).
    return xp.bitwise_or.reduce(contrib, axis=-3)


def build_images_chunked(hashes: np.ndarray, valid: np.ndarray, w: int,
                         chunk: int = 65536) -> np.ndarray:
    """Host-side chunked variant of :func:`build_images` (bounded temp memory)."""
    G = hashes.shape[0]
    out = np.zeros((G, hashes.shape[2], num_lanes(w)), dtype=np.uint32)
    for lo in range(0, G, chunk):
        hi = min(G, lo + chunk)
        out[lo:hi] = build_images(hashes[lo:hi], valid[lo:hi], w)
    return out


def popcount32(x):
    """Per-lane popcount of uint32 (SWAR — no special instructions needed)."""
    xp = _xp(x)
    x = xp.asarray(x, dtype=xp.uint32)
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2)) & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return (x * np.uint32(0x01010101)) >> np.uint32(24)


def any_nonzero(images, axis=-1):
    """True where the OR over ``axis`` lanes is non-zero (H != empty-set)."""
    xp = _xp(images)
    if xp is np:
        return np.bitwise_or.reduce(images, axis=axis) != 0
    return xp.max(images, axis=axis) != 0


def bits_to_values(word_rep: np.ndarray, w: int) -> np.ndarray:
    """Host-side: enumerate the set bits of a packed bitmap -> sorted values.

    Mirrors the paper's footnote-1 lowbit/NLZ scan; vectorized via unpackbits.
    """
    W = num_lanes(w)
    assert word_rep.shape[-1] == W
    le_bytes = word_rep.astype("<u4").view(np.uint8)
    bits = np.unpackbits(le_bytes, bitorder="little")
    return np.nonzero(bits)[0].astype(np.uint32)
