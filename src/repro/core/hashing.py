"""Hash families used by the paper's data structures.

Two kinds of hash functions appear in the paper:

* ``h : Sigma -> [w]`` — 2-universal hashes whose images are encoded as w-bit
  word representations (Section 3.1).  We use multiply-shift hashing
  (Dietzfelbinger et al.): ``h_{a,b}(x) = (a*x + b) >> (32 - log2 w)`` with a
  random odd 32-bit ``a`` — 2-universal on 32-bit keys and a single fused
  multiply-add on both CPUs and the TPU VPU.

* ``g : Sigma -> Sigma`` — a *random permutation* used for the randomized
  partitioning (Section 3.2): elements are ordered by ``g(x)`` and grouped by
  the ``t`` most significant bits ``g_t(x)``.  We realize ``g`` as an
  invertible bit-mixing permutation on uint32 (odd-multiply and xor-shift
  rounds, both bijections mod 2^32), so ``g`` is exactly a permutation —
  matching the paper's note that permutations (total order, negative
  dependence) and universal hashes are interchangeable here.

All functions accept numpy or jax arrays and stay in uint32 (the container
runs with jax x64 disabled; 32-bit keys cover the paper's universe sizes).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "HashFamily",
    "BitMixPermutation",
    "random_hash_family",
    "default_permutation",
]

_GOLDEN32 = np.uint32(0x9E3779B1)  # odd; 2^32 / golden ratio


def _xp(x):
    """Return the array namespace (numpy or jax.numpy) of ``x``."""
    if isinstance(x, np.ndarray) or np.isscalar(x):
        return np
    import jax.numpy as jnp

    return jnp


@dataclasses.dataclass(frozen=True)
class HashFamily:
    """``m`` independent 2-universal multiply-shift hashes Sigma -> [w].

    ``w`` must be a power of two; each hash returns values in ``[0, w)``.
    """

    a: np.ndarray  # (m,) uint32, odd
    b: np.ndarray  # (m,) uint32
    w: int

    def __post_init__(self):
        assert self.w & (self.w - 1) == 0, "w must be a power of two"
        assert np.all(self.a % 2 == 1), "multipliers must be odd"

    @property
    def m(self) -> int:
        return int(self.a.shape[0])

    @property
    def shift(self) -> int:
        return 32 - int(self.w).bit_length() + 1  # 32 - log2(w)

    def apply(self, x, j: int):
        """Hash values ``x`` (uint32 array) with the ``j``-th function -> [w)."""
        xp = _xp(x)
        a = xp.asarray(np.uint32(self.a[j]))
        b = xp.asarray(np.uint32(self.b[j]))
        x = xp.asarray(x, dtype=xp.uint32)
        return (a * x + b) >> np.uint32(self.shift)

    def apply_all(self, x):
        """Hash with every function: returns ``x.shape + (m,)`` in ``[0, w)``."""
        xp = _xp(x)
        x = xp.asarray(x, dtype=xp.uint32)
        a = xp.asarray(self.a.astype(np.uint32))
        b = xp.asarray(self.b.astype(np.uint32))
        return (x[..., None] * a + b) >> np.uint32(self.shift)


@dataclasses.dataclass(frozen=True)
class BitMixPermutation:
    """An invertible bit-mixing permutation g on uint32.

    Rounds of ``x *= odd`` (invertible mod 2^32) and ``x ^= x >> s``
    (invertible by iterated shifts).  ``prefix(x, t)`` returns the ``t`` most
    significant bits of ``g(x)`` — the paper's ``g_t(x)`` group id.
    """

    mults: tuple  # odd uint32 multipliers
    shifts: tuple  # xor-shift amounts

    def forward(self, x):
        xp = _xp(x)
        y = xp.asarray(x, dtype=xp.uint32)
        for mul, sh in zip(self.mults, self.shifts):
            y = y * np.uint32(mul)
            y = y ^ (y >> np.uint32(sh))
        return y

    def inverse(self, y):
        xp = _xp(y)
        x = xp.asarray(y, dtype=xp.uint32)
        for mul, sh in zip(reversed(self.mults), reversed(self.shifts)):
            # invert x ^= x >> sh by repeated application
            z = x
            s = sh
            while s < 32:
                z = x ^ (z >> np.uint32(sh))
                s += sh
            x = z
            # invert odd multiply via modular inverse mod 2^32
            inv = pow(int(mul), -1, 1 << 32)
            x = x * np.uint32(inv)
        return x

    def prefix(self, x, t: int):
        """g_t(x): the t most significant bits of g(x) (0 <= t <= 32)."""
        if t == 0:
            xp = _xp(x)
            return xp.zeros_like(xp.asarray(x, dtype=xp.uint32))
        return self.forward(x) >> np.uint32(32 - t)


def random_hash_family(m: int, w: int, seed: int = 0) -> HashFamily:
    rng = np.random.default_rng(seed)
    a = (rng.integers(0, 1 << 32, size=m, dtype=np.uint64).astype(np.uint32)
         | np.uint32(1))
    b = rng.integers(0, 1 << 32, size=m, dtype=np.uint64).astype(np.uint32)
    return HashFamily(a=a, b=b, w=w)


def default_permutation(seed: int = 0) -> BitMixPermutation:
    rng = np.random.default_rng(seed + 7)
    mults = tuple(
        int(v) | 1 for v in rng.integers(1, 1 << 32, size=3, dtype=np.uint64)
    )
    shifts = (16, 13, 17)
    return BitMixPermutation(mults=mults, shifts=shifts)


def identity_permutation() -> BitMixPermutation:
    """g = identity — handy for deterministic tests (sorted order == g-order)."""
    return BitMixPermutation(mults=(1,), shifts=())
