"""Observability for the serving stack: tracing, typed metrics, export.

One :class:`Obs` object bundles the four pieces every layer reports
through:

- ``obs.registry`` — :class:`~repro.obs.registry.MetricsRegistry` with
  the serving stack's standard instruments pre-registered (see below)
  and the legacy ``EXEC_COUNTERS`` dict subsumed as a collector under
  the ``exec_`` prefix, so one ``obs.registry.snapshot()`` is a
  consistent cut of *all* telemetry, typed and legacy alike.
- ``obs.tracer`` — :class:`~repro.obs.trace.Tracer`, **disabled by
  default**: tracing costs one sentinel call per site until switched on
  (``Obs(trace=True)`` or ``obs.tracer.enabled = True``).
- ``obs.profile`` — :class:`~repro.obs.profile.ProfileStore`, fed one
  ``(ShapeSig, batch, measured_us)`` record per collected bucket; the
  CostModel-residual source for ROADMAP item 5's calibration loop.
- ``obs.ring`` — :class:`~repro.obs.export.SnapshotRing`, filled by the
  async flusher every ``snapshot_every_s``.

Standard instruments (full inventory: ``docs/OBSERVABILITY.md``):

==========================  =========  =================================
name                        type       what
==========================  =========  =================================
``queue_wait_us``           Histogram  ticket submit → flush pickup
``collect_latency_us``      Histogram  bucket dispatch → collect return
``bucket_batch_size``       Histogram  rows per executed bucket (pow2)
``bucket_survivors``        Histogram  survivors per query row (pow2)
``dispatch_failures``       Counter    buckets whose dispatch/collect
                                       raised (balancer weight released)
``inflight_buckets``        Gauge      dispatched, not yet collected
``inflight_high_water``     Gauge      max of the above since reset
==========================  =========  =================================

Engines default to the process-global instance (:func:`get_obs`) so
``EXEC_COUNTERS``-era code and tests keep one shared telemetry world;
pass ``obs=Obs(...)`` to any engine for an isolated one.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.core.engine import EXEC_COUNTERS

from .export import (SnapshotRing, parse_json, parse_prometheus, to_json,
                     to_prometheus)
from .profile import ProfileStore, sig_label
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       default_latency_buckets, pow2_buckets)
from .trace import NULL_SPAN, NullSpan, Span, Tracer, format_trace

__all__ = [
    "Obs", "get_obs", "set_obs", "reset_obs",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "default_latency_buckets", "pow2_buckets",
    "Tracer", "Span", "NullSpan", "NULL_SPAN", "format_trace",
    "ProfileStore", "sig_label",
    "SnapshotRing", "to_prometheus", "to_json", "parse_prometheus",
    "parse_json",
]


def _exec_collector() -> Dict[str, float]:
    """The EXEC_COUNTERS compatibility shim: the legacy dict's atomic
    snapshot, re-keyed under ``exec_`` for the typed exposition."""
    return {f"exec_{k}": float(v)
            for k, v in EXEC_COUNTERS.snapshot().items()}


class Obs:
    """Bundle of registry + tracer + profile store + snapshot ring."""

    def __init__(self, trace: bool = False, max_finished_spans: int = 8192,
                 ring_size: int = 64, cost_model=None):
        self.registry = MetricsRegistry()
        self.tracer = Tracer(enabled=trace,
                             max_finished=max_finished_spans)
        self.profile = ProfileStore(cost_model=cost_model)
        self.ring = SnapshotRing(maxlen=ring_size)
        self.registry.register_collector(_exec_collector)
        r = self.registry
        self.queue_wait = r.histogram(
            "queue_wait_us", "ticket submit -> flush pickup, us")
        self.collect_latency = r.histogram(
            "collect_latency_us", "bucket dispatch -> collect return, us")
        self.batch_size = r.histogram(
            "bucket_batch_size", "query rows per executed bucket",
            buckets=pow2_buckets(1, 1 << 14))
        self.survivors = r.histogram(
            "bucket_survivors", "survivors per query row",
            buckets=pow2_buckets(1, 1 << 20))
        self.dispatch_failures = r.counter(
            "dispatch_failures",
            "buckets whose dispatch or collect raised")
        self.inflight = r.gauge(
            "inflight_buckets", "dispatched, not yet collected")
        self.inflight_high_water = r.gauge(
            "inflight_high_water", "max concurrent in-flight since reset",
            track_max=True)

    def snapshot(self) -> Dict:
        return self.registry.snapshot()

    def trace_dump(self, trace_id: Optional[int] = None,
                   limit: int = 50) -> str:
        """Span-tree pretty-print — the stuck-flight debugging surface."""
        return self.tracer.dump(trace_id=trace_id, limit=limit)

    def reset(self) -> None:
        """Zero registry metrics, spans, profile samples, and the ring.
        Does NOT reset ``EXEC_COUNTERS`` (separate ownership, as ever)."""
        self.registry.reset()
        self.tracer.reset()
        self.profile.reset()
        self.ring.clear()


_global_lock = threading.Lock()
_global_obs: Optional[Obs] = None


def get_obs() -> Obs:
    """The process-global default ``Obs`` (tracer disabled), created on
    first use — the observability analogue of ``EXEC_COUNTERS``."""
    global _global_obs
    with _global_lock:
        if _global_obs is None:
            _global_obs = Obs(trace=False)
        return _global_obs


def set_obs(obs: Obs) -> Obs:
    """Replace the process-global default (tests / embedders)."""
    global _global_obs
    with _global_lock:
        _global_obs = obs
        return obs


def reset_obs() -> None:
    """Reset the process-global instance and discard any ``set_obs``
    override — the next :func:`get_obs` returns a fresh disabled-tracer
    default.  Test hygiene, wired into ``tests/conftest.py`` next to the
    EXEC_COUNTERS reset (engines built before the reset keep their own
    reference; only the *global fallback* is replaced)."""
    global _global_obs
    with _global_lock:
        obs = _global_obs
        _global_obs = None
    if obs is not None:
        obs.reset()
