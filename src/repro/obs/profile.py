"""Per-signature execution profiling: CostModel residual attribution.

ROADMAP item 5 needs a calibration loop: the load harness's
``CostModel(per_bucket_us, per_query_us)`` predicts bucket flush cost,
real hardware disagrees, and the disagreement (the *residual*) is the
signal that retunes the model per backend.  This module is the collection
side of that loop: every collected ``InFlightBucket`` reports
``(ShapeSig, batch_size, measured_us)`` here, and — when a cost model is
attached — the predicted cost and residual are attributed per signature.

``fit_cost()`` closes the loop: a least-squares affine fit over the
accumulated samples yields fresh ``(per_bucket_us, per_query_us)``
coefficients, which ``serve.loadgen.calibrate_from_profile`` turns back
into a ``CostModel``.  Unlike ``calibrate_cost`` (which runs a synthetic
two-point probe), this fit comes from *production* buckets — whatever
mix of signatures the live workload actually executed.

Samples are bounded per signature (reservoir-free sliding window: the
most recent ``max_samples`` wins — recent behaviour is what calibration
wants anyway under compile warming and adaptive capacity drift).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["ProfileStore", "sig_label"]


def sig_label(sig) -> str:
    """Compact stable text key for a ShapeSig — used as the span attr and
    the JSON exposition key (ShapeSig itself is not JSON-serializable)."""
    parts = [f"k{sig.k}", f"t{'x'.join(str(t) for t in sig.ts)}",
             f"cap{sig.capacity_tier}"]
    if getattr(sig, "shards", 1) > 1:
        parts.append(f"s{sig.shards}")
    if getattr(sig, "replicas", 1) > 1:
        parts.append(f"r{sig.replicas}")
    if getattr(sig, "eshape", None):
        parts.append("expr")
    if getattr(sig, "cands", 0):
        parts.append(f"c{sig.cands}")
    return "/".join(parts)


class _SigProfile:
    """Accumulated samples for one signature (not thread-safe on its own;
    the owning store's lock guards all access)."""

    __slots__ = ("samples", "total_us", "total_queries", "buckets",
                 "pred_us")

    def __init__(self):
        self.samples: List[Tuple[int, float]] = []  # (batch, measured_us)
        self.total_us = 0.0
        self.total_queries = 0
        self.buckets = 0
        self.pred_us = 0.0


class ProfileStore:
    """Thread-safe per-``ShapeSig`` (batch, measured_us) accumulator with
    optional predicted-cost attribution.

    ``cost_model`` is duck-typed: anything with
    ``flush_cost_us(n_buckets, n_queries)`` (the
    ``serve.loadgen.CostModel`` surface) works — each observed bucket is
    predicted as ``flush_cost_us(1, n_queries)``.  It may be attached or
    swapped at any time; residuals are computed at observe time with
    whatever model is current, which is exactly the online-calibration
    semantics the loop wants.
    """

    def __init__(self, max_samples: int = 256, cost_model=None):
        self.max_samples = max(1, int(max_samples))
        self.cost_model = cost_model
        self._lock = threading.Lock()
        self._sigs: Dict = {}

    def observe(self, sig, n_queries: int, measured_us: float) -> None:
        """Record one executed bucket: ``n_queries`` rows took
        ``measured_us`` dispatch→collect."""
        model = self.cost_model
        pred = (float(model.flush_cost_us(1, n_queries))
                if model is not None else 0.0)
        with self._lock:
            prof = self._sigs.get(sig)
            if prof is None:
                prof = self._sigs[sig] = _SigProfile()
            prof.samples.append((int(n_queries), float(measured_us)))
            if len(prof.samples) > self.max_samples:
                del prof.samples[0]
            prof.total_us += float(measured_us)
            prof.total_queries += int(n_queries)
            prof.buckets += 1
            prof.pred_us += pred

    def signatures(self) -> List:
        with self._lock:
            return list(self._sigs)

    def residuals(self) -> Dict[str, Dict[str, float]]:
        """Per-signature attribution: measured vs predicted totals and
        the mean residual per bucket.  Keys are :func:`sig_label` strings
        (JSON-friendly); ``residual_us`` > 0 means the model
        underestimates that signature."""
        with self._lock:
            out: Dict[str, Dict[str, float]] = {}
            for sig, prof in self._sigs.items():
                n = prof.buckets
                out[sig_label(sig)] = {
                    "buckets": float(n),
                    "queries": float(prof.total_queries),
                    "measured_us": prof.total_us,
                    "predicted_us": prof.pred_us,
                    "residual_us": prof.total_us - prof.pred_us,
                    "mean_residual_us": (
                        (prof.total_us - prof.pred_us) / n if n else 0.0),
                }
            return out

    def fit_cost(self) -> Optional[Tuple[float, float]]:
        """Least-squares affine fit ``us ≈ per_bucket + per_query * B``
        over all samples, pooled across signatures.  Returns
        ``(per_bucket_us, per_query_us)`` clamped non-negative, or None
        with fewer than two distinct batch sizes (the affine system is
        singular — a single operating point can't split fixed from
        marginal cost)."""
        with self._lock:
            pts = [s for prof in self._sigs.values() for s in prof.samples]
        if not pts:
            return None
        xs = [float(b) for b, _ in pts]
        ys = [us for _, us in pts]
        n = float(len(pts))
        if len(set(xs)) < 2:
            return None
        mean_x = sum(xs) / n
        mean_y = sum(ys) / n
        var_x = sum((x - mean_x) ** 2 for x in xs)
        cov = sum((x - mean_x) * (y - mean_y) for x, y in zip(xs, ys))
        per_query = cov / var_x
        per_bucket = mean_y - per_query * mean_x
        return (max(0.0, per_bucket), max(0.0, per_query))

    def reset(self) -> None:
        with self._lock:
            self._sigs.clear()
