"""Typed metrics registry: Counter / Gauge / Histogram with atomic snapshots.

The serving stack's original telemetry is ``core.engine.EXEC_COUNTERS`` — a
process-global dict of flat integers.  That surface stays (every existing
``EXEC_COUNTERS["key"] += 1`` site keeps working, now tear-free — see the
lock added in ``ExecCounters``), but it can only count.  This module adds
the typed half the load-attribution work needs:

- :class:`Counter` — monotonic float/int accumulator.
- :class:`Gauge` — last-written value, plus ``track_max`` high-water mode.
- :class:`Histogram` — bucketed distribution; the default bucket lattice
  (:func:`default_latency_buckets`) is log-spaced 1-2-5 over µs so one
  shape covers queue waits (~10² µs) and collect latencies (~10⁵ µs)
  without per-metric tuning.

All metrics registered on one :class:`MetricsRegistry` share the
registry's single lock, so :meth:`MetricsRegistry.snapshot` is a *consistent
cut*: no metric advances while the copy is taken, and multi-metric
invariants (e.g. a histogram's ``sum``/``count`` pair, or two counters
always bumped together through one locked call) can never tear across a
snapshot.  The lock is uncontended in practice — metric updates happen per
bucket / per ticket, not per element — so "lock-cheap" holds: one acquire
per update, ~100 ns, noise next to a jit dispatch.

Registries also accept **collectors** — callbacks returning a flat
``{name: value}`` dict, read under the lock at snapshot time.  That is how
``EXEC_COUNTERS`` is subsumed without rewriting its ~50 write sites: the
default :class:`~repro.obs.Obs` registers ``EXEC_COUNTERS.snapshot`` as a
collector, so every legacy counter appears in the typed snapshot (and in
the Prometheus/JSON expositions) under the ``exec_`` prefix.
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, List, Optional, Sequence

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_latency_buckets", "pow2_buckets",
]


def default_latency_buckets(lo_us: float = 1.0,
                            hi_us: float = 1e7) -> List[float]:
    """Log-spaced 1-2-5 upper bounds in µs (1, 2, 5, 10, … up to
    ``hi_us``).  Wide enough for queue waits and whole-bucket collect
    latencies on CPU and accelerator backends alike; +Inf is implicit."""
    out: List[float] = []
    decade = lo_us
    while decade <= hi_us:
        for mult in (1.0, 2.0, 5.0):
            bound = decade * mult
            if lo_us <= bound <= hi_us:
                out.append(bound)
        decade *= 10.0
    return out


def pow2_buckets(lo: int = 1, hi: int = 1 << 20) -> List[float]:
    """Power-of-two upper bounds — the natural lattice for batch sizes and
    survivor counts (the executor's B-tiers and capacity tiers are pow2)."""
    out: List[float] = []
    b = lo
    while b <= hi:
        out.append(float(b))
        b <<= 1
    return out


class _Metric:
    """Base: a named metric bound to its registry's shared lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 lock: Optional[threading.Lock] = None):
        self.name = name
        self.help = help
        self._lock = lock if lock is not None else threading.Lock()


class Counter(_Metric):
    """Monotonic accumulator.  ``inc(n)`` with ``n < 0`` raises — use a
    Gauge for values that go down."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", lock=None):
        super().__init__(name, help, lock)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("Counter.inc is monotonic; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _read(self) -> float:  # caller holds the lock (snapshot path)
        return self._value

    def _reset(self) -> None:  # caller holds the lock
        self._value = 0.0


class Gauge(_Metric):
    """Last-written value.  With ``track_max`` the gauge keeps the largest
    value ever :meth:`set` since the last reset — the high-water idiom
    (``overlap_high_water``) as a first-class type."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", lock=None,
                 track_max: bool = False):
        super().__init__(name, help, lock)
        self.track_max = track_max
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            if self.track_max:
                self._value = max(self._value, v)
            else:
                self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _read(self) -> float:
        return self._value

    def _reset(self) -> None:
        self._value = 0.0


class Histogram(_Metric):
    """Fixed-bucket distribution: cumulative-compatible counts plus
    ``sum``/``count`` — the Prometheus histogram data model, kept as
    per-bucket (non-cumulative) counts internally and cumulated by the
    exposition writer.

    ``buckets`` are ascending upper bounds (``le``); observations above
    the last bound land in the implicit +Inf bucket.  ``observe`` is one
    ``bisect`` + two adds under the shared lock.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", lock=None,
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, lock)
        bounds = list(buckets if buckets is not None
                      else default_latency_buckets())
        assert bounds == sorted(bounds) and len(set(bounds)) == len(bounds), (
            "histogram buckets must be strictly ascending"
        )
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Approximate quantile from bucket upper bounds (the coarse but
        honest read: the true value is <= the returned bound)."""
        assert 0.0 <= q <= 1.0
        with self._lock:
            if self._count == 0:
                return 0.0
            target = q * self._count
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= target and c:
                    return (self.bounds[i] if i < len(self.bounds)
                            else float("inf"))
            return float("inf")

    def _read(self) -> Dict:
        return {
            "buckets": list(self.bounds),
            "counts": list(self._counts),
            "sum": self._sum,
            "count": self._count,
        }

    def _reset(self) -> None:
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0


class MetricsRegistry:
    """Named metrics + collectors behind ONE lock; atomic snapshots.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create (idempotent
    for the same name and kind; a kind clash raises — one name, one type).
    :meth:`snapshot` copies every metric and runs every collector while
    holding the lock, so the returned dict is a consistent point-in-time
    cut of the whole registry.  Collectors may take their own internal
    locks (``ExecCounters`` does); nothing in this module calls back into
    a registry from under a metric lock, so the ordering is acyclic.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._collectors: List[Callable[[], Dict[str, float]]] = []

    def _get_or_make(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind}, not {cls.kind}")
                return existing
            metric = cls(name, help, lock=self._lock, **kw)
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_make(Counter, name, help)

    def gauge(self, name: str, help: str = "",
              track_max: bool = False) -> Gauge:
        return self._get_or_make(Gauge, name, help, track_max=track_max)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_make(Histogram, name, help, buckets=buckets)

    def register_collector(self,
                           fn: Callable[[], Dict[str, float]]) -> None:
        """Register a ``() -> {name: value}`` callback, read under the
        registry lock at snapshot time (values export as gauges)."""
        with self._lock:
            self._collectors.append(fn)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict:
        """One consistent cut: ``{"counters": {...}, "gauges": {...},
        "histograms": {...}, "collected": {...}}``.  Taken entirely under
        the registry lock — no metric can advance mid-copy."""
        with self._lock:
            snap: Dict = {"counters": {}, "gauges": {}, "histograms": {},
                          "collected": {}}
            for name, m in self._metrics.items():
                if isinstance(m, Counter):
                    snap["counters"][name] = m._read()
                elif isinstance(m, Histogram):
                    snap["histograms"][name] = m._read()
                else:
                    snap["gauges"][name] = m._read()
            for fn in self._collectors:
                snap["collected"].update(fn())
            return snap

    def reset(self) -> None:
        """Zero every metric (test/benchmark hygiene between passes;
        collectors own their reset — ``EXEC_COUNTERS.reset()`` is
        separate, as it always was)."""
        with self._lock:
            for m in self._metrics.values():
                m._reset()
