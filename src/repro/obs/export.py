"""Exposition writers + parsers: Prometheus text format and JSON.

``to_prometheus`` / ``to_json`` serialize a ``MetricsRegistry.snapshot()``
dict; ``parse_prometheus`` / ``parse_json`` read them back.  The parsers
are deliberately tiny — enough to round-trip our own output and to let CI
validate an exposition without a real Prometheus binary in the container
(none is installed; nothing may be pip-installed).  The round-trip
``snapshot → text → parse`` is gated in ``BENCH_observability.json``.

Histograms follow the Prometheus data model: cumulative ``_bucket{le=}``
series, then ``_sum`` and ``_count``.  Collector-sourced values (the
``EXEC_COUNTERS`` shim) export as untyped gauges under their collected
names.

:class:`SnapshotRing` is the periodic-snapshot buffer the flusher feeds:
a bounded deque of ``(t_monotonic, snapshot)`` pairs so a stuck server can
be diagnosed from its last N consistent metric cuts (and rates computed
as deltas between adjacent entries).
"""
from __future__ import annotations

import json
import re
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

__all__ = ["to_prometheus", "to_json", "parse_prometheus", "parse_json",
           "SnapshotRing"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LINE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'          # metric name
    r'(?:\{([^}]*)\})?'                     # optional labels
    r'\s+([+-]?(?:[0-9.eE+-]+|[Ii]nf|NaN))$')  # value


def _sanitize(name: str) -> str:
    """Prometheus metric names are [a-zA-Z_:][a-zA-Z0-9_:]*."""
    name = _NAME_RE.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


def to_prometheus(snapshot: Dict, prefix: str = "repro_") -> str:
    """Render a registry snapshot as Prometheus text exposition v0.0.4."""
    lines: List[str] = []

    def emit(name: str, kind: str, value: float,
             labels: Optional[str] = None, typed: bool = True) -> None:
        full = _sanitize(prefix + name)
        if typed:
            lines.append(f"# TYPE {full} {kind}")
        lines.append(f"{full}{{{labels}}} {_fmt(value)}" if labels
                     else f"{full} {_fmt(value)}")

    for name in sorted(snapshot.get("counters", {})):
        emit(name, "counter", snapshot["counters"][name])
    for name in sorted(snapshot.get("gauges", {})):
        emit(name, "gauge", snapshot["gauges"][name])
    for name in sorted(snapshot.get("histograms", {})):
        h = snapshot["histograms"][name]
        full = _sanitize(prefix + name)
        lines.append(f"# TYPE {full} histogram")
        cum = 0
        for bound, c in zip(h["buckets"], h["counts"]):
            cum += c
            lines.append(f'{full}_bucket{{le="{_fmt(bound)}"}} {cum}')
        cum += h["counts"][len(h["buckets"])]
        lines.append(f'{full}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{full}_sum {_fmt(h['sum'])}")
        lines.append(f"{full}_count {h['count']}")
    for name in sorted(snapshot.get("collected", {})):
        emit(name, "gauge", snapshot["collected"][name])
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, Dict]:
    """Parse a text exposition back to
    ``{name: {"type": str, "value": float}}`` for scalar series and
    ``{name: {"type": "histogram", "buckets": [(le, cum)], "sum", "count"}}``
    for histograms.  Strict enough to catch a malformed exposition
    (bad line → ValueError), small enough to live in this repo."""
    types: Dict[str, str] = {}
    out: Dict[str, Dict] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        m = _LINE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line: {raw!r}")
        name, labels, value_s = m.group(1), m.group(2), m.group(3)
        value = float(value_s.replace("Inf", "inf"))
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and types.get(name[:-len(suffix)]) \
                    == "histogram":
                base = name[:-len(suffix)]
                break
        if types.get(base) == "histogram":
            h = out.setdefault(base, {"type": "histogram", "buckets": [],
                                      "sum": 0.0, "count": 0})
            if name.endswith("_bucket"):
                le_m = re.search(r'le="([^"]+)"', labels or "")
                if le_m is None:
                    raise ValueError(f"histogram bucket without le: {raw!r}")
                le = float(le_m.group(1).replace("+Inf", "inf"))
                h["buckets"].append((le, value))
            elif name.endswith("_sum"):
                h["sum"] = value
            else:
                h["count"] = int(value)
        else:
            out[name] = {"type": types.get(name, "untyped"),
                         "value": value}
    for h in out.values():
        if h.get("type") == "histogram":
            les = [le for le, _ in h["buckets"]]
            cums = [c for _, c in h["buckets"]]
            if les != sorted(les) or cums != sorted(cums):
                raise ValueError("histogram buckets not cumulative")
    return out


def to_json(snapshot: Dict, indent: Optional[int] = None) -> str:
    """JSON exposition — the snapshot dict is already JSON-shaped."""
    return json.dumps(snapshot, indent=indent, sort_keys=True)


def parse_json(text: str) -> Dict:
    snap = json.loads(text)
    for section in ("counters", "gauges", "histograms", "collected"):
        if section not in snap:
            raise ValueError(f"snapshot missing section {section!r}")
    for name, h in snap["histograms"].items():
        if len(h["counts"]) != len(h["buckets"]) + 1:
            raise ValueError(f"histogram {name!r}: counts/buckets mismatch")
        if sum(h["counts"]) != h["count"]:
            raise ValueError(f"histogram {name!r}: count != sum(counts)")
    return snap


class SnapshotRing:
    """Bounded ring of ``(t, snapshot)`` pairs — the flusher pushes one
    consistent cut every ``snapshot_every_s`` while serving, so the last
    N states survive for post-mortem even if the process is wedged."""

    def __init__(self, maxlen: int = 64):
        self._ring: deque = deque(maxlen=max(1, int(maxlen)))
        self._lock = threading.Lock()

    def push(self, t: float, snapshot: Dict) -> None:
        with self._lock:
            self._ring.append((t, snapshot))

    def entries(self) -> List[Tuple[float, Dict]]:
        with self._lock:
            return list(self._ring)

    def latest(self) -> Optional[Tuple[float, Dict]]:
        with self._lock:
            return self._ring[-1] if self._ring else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
