"""Request tracing: per-request trace IDs, lifecycle spans, span-tree dump.

The async serving path is concurrent three ways at once — tickets queue
per signature, the flusher dispatches buckets in an overlapped window, and
device execution runs under jax's async dispatch until collect blocks on
it.  Flat counters cannot show *where a particular request's time went*;
spans can.

Model (deliberately small — this is a serving-stack tracer, not an OTEL
client):

- A **trace** is one request: one ``submit()`` / ``suggest()`` call gets a
  fresh ``trace_id``.  Buckets get their own root trace (a bucket serves
  many requests; its span records the member trace ids as an attr rather
  than picking one parent).
- A **span** is a named interval with attrs.  Spans form trees via
  ``parent_id``.  The taxonomy used by the serving stack is documented in
  ``docs/OBSERVABILITY.md``: request → {plan, admission}; bucket →
  {dispatch, device, collect}.
- Clock is ``time.perf_counter`` scaled to µs (injectable for tests).

Lock-cheapness: the disabled tracer (the default) returns one shared
:data:`NULL_SPAN` sentinel from every call — no allocation, no lock, no
record; every instrumentation site costs one attribute load and one
``is_enabled`` branch.  The enabled tracer takes one lock acquire per span
start and one per end; finished spans go into a bounded ring so a
long-running server cannot leak memory.  Open spans are tracked by id —
``open_count()`` is the leak detector the bench and CI gate on.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer", "format_trace"]

_ids = itertools.count(1)


class Span:
    """One named interval.  ``end()`` is idempotent (first call wins) so
    belt-and-braces finally blocks can't double-close, and single-shot
    resolve paths keep the exactly-one-close invariant for free."""

    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name",
                 "start_us", "end_us", "attrs")

    enabled = True

    def __init__(self, tracer: "Tracer", trace_id: int, span_id: int,
                 parent_id: Optional[int], name: str, start_us: float):
        self.tracer = tracer
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.start_us = start_us
        self.end_us: Optional[float] = None
        self.attrs: Dict = {}

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def child(self, name: str, **attrs) -> "Span":
        return self.tracer.start(name, parent=self, **attrs)

    def end(self, **attrs) -> None:
        if self.end_us is not None:
            return
        if attrs:
            self.attrs.update(attrs)
        self.tracer._finish(self)

    @property
    def duration_us(self) -> float:
        if self.end_us is None:
            return 0.0
        return self.end_us - self.start_us

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc is not None and "error" not in self.attrs:
            self.attrs["error"] = repr(exc)
        self.end()

    def __repr__(self) -> str:
        state = (f"{self.duration_us:.0f}us" if self.end_us is not None
                 else "open")
        return (f"Span({self.name!r} trace={self.trace_id} "
                f"id={self.span_id} {state})")


class NullSpan:
    """The disabled-mode sentinel: every operation is a no-op returning
    the sentinel itself, so instrumentation sites never branch on mode."""

    __slots__ = ()

    enabled = False
    trace_id = 0
    span_id = 0
    parent_id = None
    name = ""
    start_us = 0.0
    end_us = 0.0
    duration_us = 0.0
    attrs: Dict = {}

    def set(self, **attrs) -> "NullSpan":
        return self

    def child(self, name: str, **attrs) -> "NullSpan":
        return self

    def end(self, **attrs) -> None:
        return None

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def __repr__(self) -> str:
        return "NullSpan()"


NULL_SPAN = NullSpan()


class Tracer:
    """Span factory + bounded finished-span store.

    ``enabled=False`` (the default for the process-global ``Obs``) makes
    every ``start()``/``span_at()`` return :data:`NULL_SPAN`: zero
    records, zero allocation — the <2% overhead contract in
    ``BENCH_observability.json`` gates the *enabled* mode; disabled mode
    is designed to be unmeasurable.

    Finished spans live in a ring of ``max_finished``; open spans are
    held by id until ended.  ``open_count()`` after a drained workload
    must be 0 — a nonzero value means an instrumentation site leaked a
    span (gated in CI).
    """

    def __init__(self, enabled: bool = True, max_finished: int = 8192,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.max_finished = max(1, int(max_finished))
        self._clock = clock
        self._lock = threading.Lock()
        self._open: Dict[int, Span] = {}
        self._finished: List[Span] = []
        self._dropped = 0

    def _now_us(self) -> float:
        return self._clock() * 1e6

    def new_trace_id(self) -> int:
        return next(_ids)

    def start(self, name: str, parent: Optional[Span] = None,
              trace_id: Optional[int] = None,
              start_us: Optional[float] = None, **attrs):
        """Open a span.  With ``parent`` the span joins the parent's
        trace; otherwise it is a root of a fresh (or given) trace.
        ``start_us`` backdates the span to work that began before the
        span object could be created (e.g. a bucket span opened after
        the dispatch it covers)."""
        if not self.enabled:
            return NULL_SPAN
        if parent is not None and parent.enabled:
            tid, pid = parent.trace_id, parent.span_id
        else:
            tid, pid = (trace_id if trace_id is not None
                        else self.new_trace_id()), None
        span = Span(self, tid, next(_ids), pid, name,
                    self._now_us() if start_us is None else start_us)
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._open[span.span_id] = span
        return span

    def span_at(self, name: str, start_us: float, end_us: float,
                parent: Optional[Span] = None, **attrs):
        """Record an already-elapsed interval as a closed span.  Used for
        stages whose boundaries are only known after the fact — e.g. the
        "device" span is the dispatch-end → collect-start window, bounded
        once collect returns."""
        if not self.enabled:
            return NULL_SPAN
        if parent is not None and parent.enabled:
            tid, pid = parent.trace_id, parent.span_id
        else:
            tid, pid = self.new_trace_id(), None
        span = Span(self, tid, next(_ids), pid, name, start_us)
        span.end_us = end_us
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._store(span)
        return span

    def _store(self, span: Span) -> None:  # caller holds the lock
        self._finished.append(span)
        if len(self._finished) > self.max_finished:
            drop = len(self._finished) - self.max_finished
            del self._finished[:drop]
            self._dropped += drop

    def _finish(self, span: Span) -> None:
        span.end_us = self._now_us()
        with self._lock:
            self._open.pop(span.span_id, None)
            self._store(span)

    def finished(self, name: Optional[str] = None) -> List[Span]:
        with self._lock:
            spans = list(self._finished)
        if name is not None:
            spans = [s for s in spans if s.name == name]
        return spans

    def open_count(self) -> int:
        with self._lock:
            return len(self._open)

    def open_spans(self) -> List[Span]:
        with self._lock:
            return list(self._open.values())

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def reset(self) -> None:
        with self._lock:
            self._open.clear()
            self._finished.clear()
            self._dropped = 0

    def dump(self, trace_id: Optional[int] = None, limit: int = 50) -> str:
        """Pretty span-tree text for the most recent ``limit`` traces (or
        one trace).  Open spans are included flagged ``[open]`` — the
        tool for debugging a stuck flight is ``print(tracer.dump())``."""
        with self._lock:
            spans = list(self._finished) + list(self._open.values())
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        return format_trace(spans, limit=limit)


def format_trace(spans: List[Span], limit: int = 50) -> str:
    """Render spans grouped by trace as indented trees, oldest first.

    Orphan children (parent evicted from the ring) print at root level
    with a ``parent=#id`` note rather than being dropped.
    """
    by_trace: Dict[int, List[Span]] = {}
    for s in spans:
        by_trace.setdefault(s.trace_id, []).append(s)
    trace_ids = sorted(by_trace,
                       key=lambda t: min(s.start_us for s in by_trace[t]))
    if limit and len(trace_ids) > limit:
        trace_ids = trace_ids[-limit:]
    lines: List[str] = []
    for tid in trace_ids:
        members = sorted(by_trace[tid], key=lambda s: s.start_us)
        ids = {s.span_id for s in members}
        children: Dict[Optional[int], List[Span]] = {}
        for s in members:
            key = s.parent_id if s.parent_id in ids else None
            children.setdefault(key, []).append(s)
        lines.append(f"trace {tid}:")

        def walk(parent_key: Optional[int], depth: int) -> None:
            for s in children.get(parent_key, []):
                dur = (f"{s.duration_us:.0f}us" if s.end_us is not None
                       else "[open]")
                extra = ""
                if parent_key is None and s.parent_id is not None:
                    extra = f" parent=#{s.parent_id}"
                attrs = ""
                if s.attrs:
                    pairs = ", ".join(f"{k}={v!r}"
                                      for k, v in sorted(s.attrs.items()))
                    attrs = f"  {{{pairs}}}"
                lines.append("  " * (depth + 1)
                             + f"{s.name} #{s.span_id} {dur}{extra}{attrs}")
                walk(s.span_id, depth + 1)

        walk(None, 0)
    return "\n".join(lines)
