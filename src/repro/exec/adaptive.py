"""Telemetry-driven adaptive capacity and deadline tuning.

The paper's O(n/sqrt(w) + kr) guarantee hinges on sizing the phase-2
candidate buffer to the *actual* survivor count: too small and the executor
pays an overflow re-run (a second jit execution of the bucket), too large
and phase 2 wastes work on padding.  The static ``default_capacity`` rule
(G/4 with a floor) is a prior, not a measurement — this module closes the
loop with two small controllers fed from execution telemetry:

- :class:`CapacityModel` records a per-signature histogram of observed
  survivor counts (``tuples_survived`` from the single-device bucket stats;
  ``max_shard_survivors * n_shards`` from the sharded and 2-D mesh paths,
  since the per-shard buffer is what overflows there) and learns a
  per-signature capacity tier: a high quantile of the observations times a
  safety margin, rounded up to a power of two.  ``plan_query`` consults it
  when building a ``ShapeSig`` and falls back to the static G/4 rule while
  the signature is cold (fewer than ``min_observations`` samples).  When
  the learned tier changes, the model bumps
  ``EXEC_COUNTERS["adaptive_promotions"]`` (tier grew) or
  ``["adaptive_demotions"]`` (tier shrank) and fires registered change
  hooks — the serving layer uses them to invalidate its result cache and
  re-warm the re-tiered executable deliberately, because a new
  ``capacity_tier`` is a new ``ShapeSig`` and therefore a new compiled
  executable.  Observations are **time-decayed** (``decay_s``): samples
  older than the horizon are pruned before the tier re-evaluates, so a
  tier inflated by a traffic burst shrinks back once the drift passes
  instead of being pinned by stale survivors that the bounded count
  window alone would only age out under sustained traffic.
- :class:`AdaptiveDeadline` adjusts per-signature flush budgets from the
  observed bucket-fill rate (an EWMA of submit inter-arrival gaps).  The
  deadline budget exists to bound how long a query waits for batch-mates;
  when a signature's arrival rate cannot fill a bucket within the default
  budget, waiting the full budget buys padding instead of batching, so the
  budget shrinks proportionally to the expected number of mates.  Hot
  signatures keep the full budget (their tier flush fires first anyway).

Keys: both models are keyed by :func:`adaptive_key` — the ShapeSig minus
its ``capacity_tier`` — because the capacity tier is the *output* of the
capacity model; keying on the full sig would give every learned tier its
own cold history.

Thread-safety: both controllers are observed from flusher/executor threads
and consulted from submitter threads, so all state is lock-protected.
Promotion hooks are fired *outside* the model lock — hooks re-plan (which
re-enters ``capacity_for``) and run device work (re-warming).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, Hashable, List, Optional, Tuple

from ..core.engine import (
    EXEC_COUNTERS, default_capacity, default_expr_capacity, expr_total_width,
)

__all__ = ["adaptive_key", "CapacityModel", "AdaptiveDeadline"]


def adaptive_key_parts(k: int, ts: Tuple[int, ...],
                       gmaxes: Tuple[int, ...], shards: int,
                       replicas: int = 1, eshape: Optional[Tuple] = None,
                       cands: int = 0) -> Tuple:
    """THE adaptive learning key, from raw signature parts.  Single source
    of truth: the planner builds the key from parts before a ``ShapeSig``
    exists, the model builds it from the executed sig — both MUST agree or
    learned tiers are consulted under a key nothing ever writes.
    ``replicas`` (the 2-D topology's data-parallel width) is part of the
    key: mesh-routed and single-device executions of the same shapes are
    different executables, so their survivor histories must not mix.
    ``eshape`` (the leaf-erased expression shape; ``None`` for flat
    conjunctions) is part of the key for the same reason — ``(a∪b)∩c``
    and ``(a∩b)∩c`` over the same leaves have very different survivor
    distributions, and each expression shape is its own executable.
    ``cands`` (the suggest candidate-axis tier; 0 otherwise) keeps
    count-only signatures out of the point-query keyspace — they have no
    survivor buffer, so the model never learns for them, but a shared key
    would let their (absent) history shadow a real one.  ``eshape`` stays
    the LAST element (tests and telemetry tooling read ``key[-1]``), so
    ``cands`` slots in before it."""
    return (k, ts, gmaxes, shards, replicas, cands, eshape)


def adaptive_key(sig) -> Tuple:
    """The learning key of a shape signature: everything *except* the
    capacity tier (which is what the model outputs).  Accepts any object
    with ``k`` / ``ts`` / ``gmaxes`` / ``shards`` (i.e. ``ShapeSig``)."""
    return adaptive_key_parts(sig.k, sig.ts, sig.gmaxes,
                              getattr(sig, "shards", 1),
                              replicas=getattr(sig, "replicas", 1),
                              eshape=getattr(sig, "eshape", None),
                              cands=getattr(sig, "cands", 0))


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


class CapacityModel:
    """Learn per-signature survivor-buffer (capacity) tiers from telemetry.

    ``observe_bucket(sig, stats_list)`` feeds one executed bucket's
    per-query stats; ``capacity_for(key, default)`` answers the planner.
    A signature stays on ``default`` (the static G/4 rule) until
    ``min_observations`` samples accumulate — the cold-start fallback —
    then gets ``pow2_ceil(quantile * margin)`` clamped to
    ``[64, G]``.  Tiers can move in both directions: *up* to absorb
    survivors the static rule overflowed on (eliminating re-runs), *down*
    when real survivor counts sit far below G/4 (shrinking the phase-2
    all-pairs work toward the paper's E[survivors] ideal).

    Every tier *increase* counts as one ``adaptive_promotions``, every
    *decrease* as one ``adaptive_demotions``; both fire the registered
    change hooks with ``(key, old_tier, new_tier)`` — demotion is fully
    symmetric to promotion (cache invalidation, re-warming) because a
    shrunk tier is just as much a new executable as a grown one.  An
    execution whose survivors exceeded the static default but fit the
    learned tier counts as ``adaptive_overflow_saved`` (a re-run the model
    eliminated).

    Drift handling is two-fold: the histogram is a bounded window
    (``window`` most recent samples per key) AND each sample carries a
    timestamp — samples older than ``decay_s`` are pruned before every
    tier re-evaluation, so a tier inflated by a past burst demotes once
    fresh traffic shows smaller survivors, even at arrival rates too low
    to push the burst out of the count window.  A key whose pruned window
    drops below ``min_observations`` keeps its current learned tier (no
    flapping back to the static rule on a traffic lull); the next
    ``min_observations`` fresh samples re-evaluate it.
    """

    def __init__(self, min_observations: int = 32, quantile: float = 0.99,
                 margin: float = 1.25, window: int = 1024,
                 floor: int = 64, decay_s: Optional[float] = 300.0,
                 clock: Callable[[], float] = time.monotonic):
        assert 0.0 < quantile <= 1.0 and margin >= 1.0
        assert decay_s is None or decay_s > 0.0
        self.min_observations = int(min_observations)
        self.quantile = float(quantile)
        self.margin = float(margin)
        self.window = int(window)
        self.floor = int(floor)
        self.decay_s = None if decay_s is None else float(decay_s)
        self.clock = clock
        self._lock = threading.Lock()
        # per-key deque of (timestamp, survivors) pairs
        self._survivors: Dict[Hashable, deque] = {}
        self._learned: Dict[Hashable, int] = {}
        self._hooks: List[Callable[[Hashable, int, int], None]] = []

    def on_promotion(self, hook: Callable[[Hashable, int, int], None]) -> None:
        """Register a callback fired (outside the model lock) after every
        learned-tier change — promotions AND demotions — with
        ``(key, old_tier, new_tier)``.  The serving layer hangs cache
        invalidation and re-warming here."""
        self._hooks.append(hook)

    def capacity_for(self, key: Hashable, default: int) -> int:
        """The capacity tier the planner should use for ``key``: the
        learned tier when warm, ``default`` (the static rule) when cold."""
        with self._lock:
            return self._learned.get(key, default)

    def observations(self, key: Hashable) -> int:
        with self._lock:
            window = self._survivors.get(key)
            if window is None:
                return 0
            self._prune(window, self.clock())
            return len(window)

    def learned_tiers(self) -> Dict[Hashable, int]:
        """Snapshot of every learned (non-cold) tier, for telemetry."""
        with self._lock:
            return dict(self._learned)

    @staticmethod
    def _effective_survivors(sig, stats: Dict) -> Optional[int]:
        """Whole-query-equivalent survivor count of one executed query.

        Sharded stats report ``max_shard_survivors``; the per-shard buffer
        is ``capacity_tier // n_shards``, so the binding whole-query
        requirement is ``max_shard_survivors * n_shards`` (the margin also
        covers shard imbalance).  Single-device stats report
        ``tuples_survived`` directly.
        """
        n_shards = stats.get("n_shards", 1)
        if n_shards > 1 and "max_shard_survivors" in stats:
            return int(stats["max_shard_survivors"]) * int(n_shards)
        if "tuples_survived" in stats:
            return int(stats["tuples_survived"])
        return None

    def _prune(self, window: deque, now: float) -> None:
        """Drop samples older than the decay horizon (caller holds the
        lock).  The time decay is what lets tiers *demote* after workload
        drift: without it a burst of huge survivors pins the quantile until
        sheer traffic volume pushes it out of the count window."""
        if self.decay_s is None:
            return
        horizon = now - self.decay_s
        while window and window[0][0] < horizon:
            window.popleft()

    def observe_bucket(self, sig, stats_list) -> None:
        """Feed one executed bucket's per-query stats dicts.

        Records each query's effective survivor count under
        ``adaptive_key(sig)``, credits ``adaptive_overflow_saved`` when the
        learned tier absorbed a would-be static overflow, prunes decayed
        samples, and re-evaluates the learned tier — promoting or demoting
        as the fresh window dictates.  Hooks fire after the lock is
        released.
        """
        if getattr(sig, "cands", 0):
            # count-only (suggest) buckets have no survivor buffer to size:
            # their capacity_tier is the top-K selection tier, fixed by the
            # request's k — nothing to learn, nothing to observe
            return
        key = adaptive_key(sig)
        if getattr(sig, "eshape", None) is not None:
            # expression buckets: the static prior and the hard ceiling are
            # the DAG's dense widths, not the largest leaf's group count
            static_cap = default_expr_capacity(sig.ts, sig.gmaxes)
            g = expr_total_width(sig.ts, sig.gmaxes)
        else:
            static_cap = default_capacity(sig.ts)
            g = 1 << sig.ts[-1]
        now = self.clock()
        changes: List[Tuple[Hashable, int, int]] = []
        with self._lock:
            window = self._survivors.setdefault(
                key, deque(maxlen=self.window))
            for stats in stats_list:
                surv = self._effective_survivors(sig, stats)
                if surv is None:
                    continue
                window.append((now, surv))
                if (sig.capacity_tier != static_cap
                        and static_cap < surv <= sig.capacity_tier):
                    EXEC_COUNTERS["adaptive_overflow_saved"] += 1
            self._prune(window, now)
            if len(window) >= self.min_observations:
                tier = self._tier_from_window(window, g)
                old = self._learned.get(key, static_cap)
                if tier != self._learned.get(key):
                    self._learned[key] = tier
                    if tier > old:
                        EXEC_COUNTERS["adaptive_promotions"] += 1
                        changes.append((key, old, tier))
                    elif tier < old:
                        EXEC_COUNTERS["adaptive_demotions"] += 1
                        changes.append((key, old, tier))
        for change in changes:
            for hook in self._hooks:
                hook(*change)

    def _tier_from_window(self, window, g: int) -> int:
        """quantile * margin, power-of-two ceiling, clamped to [floor, G]."""
        ordered = sorted(surv for _, surv in window)
        idx = min(len(ordered) - 1,
                  int(round(self.quantile * (len(ordered) - 1))))
        target = int(ordered[idx] * self.margin)
        return max(self.floor, min(g, _pow2_ceil(max(1, target))))

    def telemetry(self) -> Dict[str, Dict]:
        """One consistent snapshot of the model's learned state, keyed by
        ``str(adaptive_key)`` (registry collectors and exposition want
        string keys).  Per key: live (pruned) observation count, the
        learned tier if warm, and the current survivor-window max —
        enough to see *why* a tier is what it is without holding the
        lock yourself."""
        now = self.clock()
        with self._lock:
            out: Dict[str, Dict] = {}
            for key, window in self._survivors.items():
                self._prune(window, now)
                out[str(key)] = {
                    "observations": len(window),
                    "learned_tier": self._learned.get(key),
                    "window_max": (max(s for _, s in window)
                                   if window else None),
                }
            # learned tiers whose windows fully decayed still serve plans
            for key, tier in self._learned.items():
                out.setdefault(str(key), {
                    "observations": 0, "learned_tier": tier,
                    "window_max": None,
                })
            return out


class AdaptiveDeadline:
    """Learn per-signature flush budgets from observed bucket-fill rates.

    ``observe(key, now)`` records a submission (EWMA of inter-arrival
    gaps); ``budget_for(key, default_us)`` answers the admission path.  The
    policy: the default budget is worth waiting only if batch-mates are
    likely to arrive within it.  With an observed mean gap ``g`` the
    expected number of mates inside the budget is ``default / g``; when
    that falls below 1 the budget shrinks proportionally (clamped to
    ``min_fraction * default``), so a cold signature's lone query stops
    paying the full budget for padding it will never batch with.  Hot
    signatures (``default / g >= 1``) keep the full budget — their tier
    flush fires before the deadline anyway, so shrinking would only cut
    batching.

    Like :class:`CapacityModel`, cold keys (fewer than ``min_observations``
    gaps) use the default unchanged.
    """

    def __init__(self, min_observations: int = 8, alpha: float = 0.2,
                 min_fraction: float = 0.125):
        assert 0.0 < alpha <= 1.0 and 0.0 < min_fraction <= 1.0
        self.min_observations = int(min_observations)
        self.alpha = float(alpha)
        self.min_fraction = float(min_fraction)
        self._lock = threading.Lock()
        self._last_at: Dict[Hashable, float] = {}
        self._gap_ewma_us: Dict[Hashable, float] = {}
        self._counts: Dict[Hashable, int] = {}

    def observe(self, key: Hashable, now: float) -> None:
        """Record one submission of ``key`` at clock time ``now`` (s)."""
        with self._lock:
            last = self._last_at.get(key)
            self._last_at[key] = now
            if last is None:
                return
            gap_us = max(0.0, (now - last) * 1e6)
            prev = self._gap_ewma_us.get(key)
            self._gap_ewma_us[key] = (
                gap_us if prev is None
                else (1.0 - self.alpha) * prev + self.alpha * gap_us)
            self._counts[key] = self._counts.get(key, 0) + 1

    def expected_gap_us(self, key: Hashable) -> Optional[float]:
        with self._lock:
            if self._counts.get(key, 0) < self.min_observations:
                return None
            return self._gap_ewma_us.get(key)

    def budget_for(self, key: Hashable, default_us: float) -> float:
        """The flush budget the admission path should use for ``key``."""
        gap = self.expected_gap_us(key)
        if gap is None or gap <= 0.0:
            return default_us
        expected_mates = default_us / gap
        if expected_mates >= 1.0:
            return default_us
        return max(self.min_fraction * default_us,
                   default_us * expected_mates)

    def telemetry(self) -> Dict[str, Dict]:
        """Per-key arrival-rate state (``str(key)``-keyed): gap EWMA in
        µs, number of recorded gaps, and whether the key is warm enough
        (``>= min_observations``) for :meth:`budget_for` to shrink its
        budget."""
        with self._lock:
            return {
                str(key): {
                    "gap_ewma_us": self._gap_ewma_us.get(key),
                    "gaps": n,
                    "warm": n >= self.min_observations,
                }
                for key, n in self._counts.items()
            }
