"""Boolean expression DAG over preprocessed sets: ∩ / ∪ / ∖.

Bille–Pagh–Pagh ("Fast evaluation of union-intersection expressions",
arxiv 0708.3259) shows the linear-space set representations the paper
builds support worst-case-efficient evaluation of arbitrary ∪/∩
expression trees — this module is the front half of that: a small
expression algebra, a **canonicalizer**, and a **numpy oracle**.  The
back half (batched device evaluation) lives in ``kernels/setops.py`` +
``core/engine.py``; the glue (planning, bucketing, caching, serving) in
the rest of ``exec/`` and ``serve/``.

Node types
----------

``Term(t)`` — a leaf naming a preprocessed set; ``And(children)`` /
``Or(children)`` — n-ary ∩ / ∪; ``Diff(left, right)`` — ∖; plus the
``EMPTY`` sentinel (the ∅ result of an unresolvable or self-cancelling
expression).  All nodes are frozen/hashable, so canonical expressions
serve directly as cache keys.

Canonical form
--------------

:func:`canonicalize` rewrites a raw expression into a unique normal form
(per index — child ordering uses each leaf set's ``(t, n)`` metadata):

1. unknown terms become ``EMPTY``; ∅ propagates (``x∩∅ = ∅``,
   ``x∪∅ = x``, ``∅∖x = ∅``, ``x∖∅ = x``, ``x∖x = ∅``);
2. associative ops flatten (``(a∩b)∩c → a∩b∩c``), singletons collapse;
3. children sort by ``(t, n, term)`` for leaves / structural key for
   composites, then dedup — which absorbs ``x∩x → x`` and ``x∪x → x``;
4. differences push **down** through unions
   (``(a∪b)∖s → (a∖s)∪(b∖s)``) and hoist **out** of intersections
   (``(a∖s)∩b → (a∩b)∖s``), and cascades merge
   (``(a∖s)∖u → a∖(s∪u)``) — so in canonical form a ``Diff``'s left
   operand is always a ``Term`` or ``And``, and every ∖ in a query
   costs exactly one subtraction pass per containing ∪-branch.

The invariant that makes the refactor safe: a canonical form that is a
bare ``Term`` or an ``And`` of ``Term``s *is* a flat conjunction — the
planner detects that (:func:`flat_terms`) and takes the byte-identical
legacy path, so existing workloads see unchanged signatures,
executables, counters, and results.

Structural shape
----------------

:func:`expr_shape` erases leaf identities to a nested tuple (the
``ShapeSig.eshape`` component): two expressions with the same shape
stack into one ``(B, …)`` bucket and share a compile, exactly like flat
conjunctions with equal ``(k, ts, gmaxes)`` do today.  Leaf *sizes*
(``ts`` / ``gmaxes``) ride in the signature's existing tuple fields, in
:func:`leaf_terms` traversal order.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "Expr", "Term", "And", "Or", "Diff", "EMPTY",
    "canonicalize", "flat_terms", "leaf_terms", "expr_key", "expr_shape",
    "subexpr_keys", "composite_subexprs", "eval_host", "parse",
]


class Expr:
    """Base class for expression nodes (leaf ``Term`` or composite)."""

    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Term(Expr):
    """A leaf: the postings set of one term."""

    term: Any


@dataclasses.dataclass(frozen=True)
class And(Expr):
    """n-ary intersection of ``children``."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    """n-ary union of ``children``."""

    children: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class Diff(Expr):
    """Set difference ``left ∖ right``."""

    left: Expr
    right: Expr


@dataclasses.dataclass(frozen=True)
class _Empty(Expr):
    """The ∅ sentinel (singleton ``EMPTY``)."""


EMPTY = _Empty()


# ---------------------------------------------------------------------------
# structural keys / shapes
# ---------------------------------------------------------------------------

def expr_key(e: Expr) -> Tuple:
    """Hashable structural identity of a *canonical* expression.  Used as
    the (sub)expression result-cache key: two queries containing the same
    canonical subtree probe the same entry."""
    if isinstance(e, Term):
        return ("t", e.term)
    if isinstance(e, And):
        return ("and",) + tuple(expr_key(c) for c in e.children)
    if isinstance(e, Or):
        return ("or",) + tuple(expr_key(c) for c in e.children)
    if isinstance(e, Diff):
        return ("diff", expr_key(e.left), expr_key(e.right))
    return ("empty",)


def expr_shape(e: Expr) -> Tuple:
    """Leaf-erased structure (the ``ShapeSig.eshape`` component): leaves
    become ``"T"``; composites keep their operator and arity.  Leaf sizes
    live in the signature's ``ts`` / ``gmaxes``, in :func:`leaf_terms`
    order, so (shape, ts, gmaxes) fully keys the compiled evaluator."""
    if isinstance(e, Term):
        return "T"
    if isinstance(e, And):
        return ("&",) + tuple(expr_shape(c) for c in e.children)
    if isinstance(e, Or):
        return ("|",) + tuple(expr_shape(c) for c in e.children)
    if isinstance(e, Diff):
        return ("-", expr_shape(e.left), expr_shape(e.right))
    raise ValueError("EMPTY has no executable shape")


def leaf_terms(e: Expr) -> Tuple:
    """Leaf terms in deterministic preorder — THE traversal order shared
    by ``ShapeSig.ts`` / ``gmaxes``, plan ``terms``, and the evaluator's
    stacked leaf arrays.  Repeated terms appear once per occurrence."""
    out: List = []

    def walk(n: Expr) -> None:
        if isinstance(n, Term):
            out.append(n.term)
        elif isinstance(n, (And, Or)):
            for c in n.children:
                walk(c)
        elif isinstance(n, Diff):
            walk(n.left)
            walk(n.right)
        else:
            raise ValueError("EMPTY has no leaves")

    walk(e)
    return tuple(out)


def composite_subexprs(e: Expr) -> Tuple[Expr, ...]:
    """All composite *proper* subexpressions of a canonical expression, in
    **postorder, one entry per position** (duplicates retained — the
    device evaluator walks the leaf-erased shape and cannot dedup by
    identity; a repeated subtree just stores its identical value twice).
    These are the shareable units: the executor emits their value buffers
    in this exact order and the serving layer stores them in the result
    cache under :func:`expr_key`, so a later query containing the same
    subtree (``a∪b`` inside many queries) resolves host-side."""
    out: List[Expr] = []

    def walk(n: Expr, root: bool) -> None:
        if isinstance(n, Term) or isinstance(n, _Empty):
            return
        kids = (n.children if isinstance(n, (And, Or))
                else (n.left, n.right))
        for c in kids:
            walk(c, False)
        if not root:
            out.append(n)

    walk(e, True)
    return tuple(out)


def subexpr_keys(e: Expr) -> Tuple[Tuple, ...]:
    """``expr_key`` of every composite proper subexpression (postorder,
    per position) — the store/lookup keys for subexpression caching, in
    the exact order the device evaluator emits sub-buffers."""
    return tuple(expr_key(s) for s in composite_subexprs(e))


def flat_terms(e: Expr) -> Optional[Tuple]:
    """If a canonical expression is a flat conjunction — a bare ``Term``
    or an ``And`` of ``Term``s — return its term tuple, else None.  The
    planner routes these through the *legacy* flat path unchanged (same
    plans, signatures, executables, cache keys)."""
    if isinstance(e, Term):
        return (e.term,)
    if isinstance(e, And) and all(isinstance(c, Term) for c in e.children):
        return tuple(c.term for c in e.children)
    return None


# ---------------------------------------------------------------------------
# canonicalization
# ---------------------------------------------------------------------------

def _sort_key(e: Expr, index: Mapping) -> Tuple:
    """Deterministic child ordering: leaves by the shared ``(t, n, term)``
    set ordering (smallest set first — the same rule the flat planner
    uses), composites after leaves by structural key."""
    if isinstance(e, Term):
        s = index[e.term]
        return (0, s.t, s.n, repr(e.term))
    if isinstance(e, And):
        return (1, tuple(_sort_key(c, index) for c in e.children))
    if isinstance(e, Or):
        return (2, tuple(_sort_key(c, index) for c in e.children))
    return (3, _sort_key(e.left, index), _sort_key(e.right, index))


def _sorted_unique(kids: List[Expr], index: Mapping) -> List[Expr]:
    """Sort children canonically and drop structural duplicates — the
    ``x∩x → x`` / ``x∪x → x`` absorption."""
    kids = sorted(kids, key=lambda c: _sort_key(c, index))
    out: List[Expr] = []
    seen = set()
    for c in kids:
        k = expr_key(c)
        if k not in seen:
            seen.add(k)
            out.append(c)
    return out


def _make_or(kids: List[Expr], index: Mapping) -> Expr:
    """Canonical ∪ of already-canonical children: drop ∅, flatten nested
    ∪, sort + dedup, collapse singletons."""
    flat: List[Expr] = []
    for c in kids:
        if isinstance(c, _Empty):
            continue
        flat.extend(c.children if isinstance(c, Or) else [c])
    flat = _sorted_unique(flat, index)
    if not flat:
        return EMPTY
    if len(flat) == 1:
        return flat[0]
    return Or(tuple(flat))


def _make_diff(left: Expr, right: Expr, index: Mapping) -> Expr:
    """Canonical ``left ∖ right`` of already-canonical operands.

    Applies the ∖ normal-form rules: ``x∖x → ∅``; cascade merge
    ``(a∖s)∖u → a∖(s∪u)``; push-down ``(a∪b)∖s → (a∖s)∪(b∖s)``.  The
    result's ``Diff`` nodes (if any) have ``Term``/``And`` left operands.
    """
    if isinstance(left, _Empty):
        return EMPTY
    if isinstance(right, _Empty):
        return left
    if expr_key(left) == expr_key(right):
        return EMPTY
    if isinstance(left, Diff):
        return _make_diff(left.left, _make_or([left.right, right], index),
                          index)
    if isinstance(left, Or):
        return _make_or([_make_diff(c, right, index) for c in left.children],
                        index)
    if isinstance(right, Or) and any(expr_key(left) == expr_key(c)
                                     for c in right.children):
        return EMPTY  # a ∖ (… ∪ a ∪ …) = ∅
    return Diff(left, right)


def _make_and(kids: List[Expr], index: Mapping) -> Expr:
    """Canonical ∩ of already-canonical children: ∅ annihilates, nested ∩
    flatten, ∖ children hoist out (``(a∖s)∩b → (a∩b)∖s``, subtrahends
    merge via ∪), sort + dedup, collapse singletons."""
    flat: List[Expr] = []
    subtrahends: List[Expr] = []
    queue = list(kids)
    while queue:
        c = queue.pop(0)
        if isinstance(c, _Empty):
            return EMPTY
        if isinstance(c, And):
            queue[:0] = list(c.children)
        elif isinstance(c, Diff):
            subtrahends.append(c.right)
            queue[:0] = [c.left]
        else:
            flat.append(c)
    flat = _sorted_unique(flat, index)
    if not flat:
        return EMPTY
    base = flat[0] if len(flat) == 1 else And(tuple(flat))
    if subtrahends:
        return _make_diff(base, _make_or(subtrahends, index), index)
    return base


def canonicalize(e: Expr, index: Mapping) -> Expr:
    """Rewrite ``e`` into its canonical form against ``index`` (term ->
    set metadata with ``.t`` / ``.n``).  Idempotent: canonicalizing a
    canonical expression returns it unchanged (structurally).  Returns
    ``EMPTY`` when the expression is provably ∅ (unknown term under ∩,
    ``x∖x``, …)."""
    if isinstance(e, Term):
        return e if e.term in index else EMPTY
    if isinstance(e, _Empty):
        return EMPTY
    if isinstance(e, And):
        return _make_and([canonicalize(c, index) for c in e.children], index)
    if isinstance(e, Or):
        return _make_or([canonicalize(c, index) for c in e.children], index)
    if isinstance(e, Diff):
        return _make_diff(canonicalize(e.left, index),
                          canonicalize(e.right, index), index)
    raise TypeError(f"not an Expr: {e!r}")


# ---------------------------------------------------------------------------
# host numpy oracle
# ---------------------------------------------------------------------------

def eval_host(e: Expr, resolve: Callable[[Any], np.ndarray],
              _memo: Optional[Dict] = None) -> np.ndarray:
    """Exact host evaluation: sorted unique uint32 doc ids for every node
    type.  ``resolve(term)`` returns a term's postings (any order; dtype
    uint32).  This is THE oracle the device evaluator must match
    bit-for-bit — np.intersect1d / union1d / setdiff1d semantics."""
    memo: Dict = {} if _memo is None else _memo
    k = expr_key(e)
    if k in memo:
        return memo[k]
    if isinstance(e, _Empty):
        out = np.empty(0, dtype=np.uint32)
    elif isinstance(e, Term):
        out = np.unique(np.asarray(resolve(e.term), dtype=np.uint32))
    elif isinstance(e, And):
        out = eval_host(e.children[0], resolve, memo)
        for c in e.children[1:]:
            out = np.intersect1d(out, eval_host(c, resolve, memo))
    elif isinstance(e, Or):
        out = eval_host(e.children[0], resolve, memo)
        for c in e.children[1:]:
            out = np.union1d(out, eval_host(c, resolve, memo))
    elif isinstance(e, Diff):
        out = np.setdiff1d(eval_host(e.left, resolve, memo),
                           eval_host(e.right, resolve, memo))
    else:
        raise TypeError(f"not an Expr: {e!r}")
    out = out.astype(np.uint32)
    memo[k] = out
    return out


# ---------------------------------------------------------------------------
# parser: "(a | b) & (c | d) - e"  (also ∪ ∩ ∖)
# ---------------------------------------------------------------------------

_OPS = {"|": "|", "∪": "|", "&": "&", "∩": "&", "-": "-", "∖": "-"}


def _tokenize(s: str) -> List[str]:
    toks: List[str] = []
    i = 0
    while i < len(s):
        ch = s[i]
        if ch.isspace():
            i += 1
        elif ch in "()":
            toks.append(ch)
            i += 1
        elif ch in _OPS:
            toks.append(_OPS[ch])
            i += 1
        else:
            j = i
            while j < len(s) and not (s[j].isspace() or s[j] in "()"
                                      or s[j] in _OPS):
                j += 1
            toks.append(s[i:j])
            i = j
    return toks


def parse(s: str) -> Expr:
    """Parse ``"(a | b) & (c | d) - e"`` into a raw (un-canonicalized)
    expression.  Operators: ``|``/``∪`` (union), ``&``/``∩``
    (intersection), ``-``/``∖`` (difference); precedence ``- < | < &``
    with left associativity, parens override.  Bare integer tokens become
    int terms (the serving layer's term type), others stay strings."""
    toks = _tokenize(s)
    pos = [0]

    def peek() -> Optional[str]:
        return toks[pos[0]] if pos[0] < len(toks) else None

    def eat(tok: str) -> None:
        if peek() != tok:
            raise ValueError(f"expected {tok!r} at {pos[0]} in {toks}")
        pos[0] += 1

    def atom() -> Expr:
        t = peek()
        if t == "(":
            eat("(")
            e = diff_expr()
            eat(")")
            return e
        if t is None or t in ("|", "&", "-", ")"):
            raise ValueError(f"expected a term at {pos[0]} in {toks}")
        pos[0] += 1
        try:
            return Term(int(t))
        except ValueError:
            return Term(t)

    def and_expr() -> Expr:
        kids = [atom()]
        while peek() == "&":
            eat("&")
            kids.append(atom())
        return kids[0] if len(kids) == 1 else And(tuple(kids))

    def or_expr() -> Expr:
        kids = [and_expr()]
        while peek() == "|":
            eat("|")
            kids.append(and_expr())
        return kids[0] if len(kids) == 1 else Or(tuple(kids))

    def diff_expr() -> Expr:
        e = or_expr()
        while peek() == "-":
            eat("-")
            e = Diff(e, or_expr())
        return e

    e = diff_expr()
    if pos[0] != len(toks):
        raise ValueError(f"trailing tokens {toks[pos[0]:]} in {s!r}")
    return e
