"""2-D device-mesh topology: data-parallel replicas composed with z-sharding.

The paper's online stage is embarrassingly parallel along two independent
axes — across *queries* (every intersection is independent) and across the
*universe* (Theorem 3.7: partitioning every set by the same permutation
makes equal z-ranges self-contained).  The 1-D mesh of PR 3 exploits only
the second axis: a sharded bucket occupies every device, so device count
buys per-query latency but not concurrent-bucket throughput.  This module
adds the first axis as a proper subsystem:

  Mesh(("data", "shard")) — ``replicas`` rows x ``shards`` columns.

  - Each **row** is one replica: a full copy of every posting mirror,
    z-partitioned over the row's ``shards`` devices exactly as in the 1-D
    path (``DeviceSet.shard`` on the 2-D mesh replicates over ``data``
    for free — unnamed mesh axes replicate).
  - Mesh-routed buckets (huge G) split their **batch axis** over ``data``
    (``core.engine.intersect_mesh2d_batch``): every device works, but each
    query touches only ``1/replicas`` of them.
  - Single-device buckets (small G, where shard_map dispatch overhead
    dominates) are **spread across replicas** by the
    :class:`ReplicaBalancer`: each replica row keeps a plain per-row
    mirror and the executor dispatches each bucket to the least-loaded
    row.

This is the replicate-the-index / partition-the-universe split that lets
hash-partitioned distributed schemes scale ``n`` past one machine's
bandwidth while keeping the paper's O(n/sqrt(w) + kr) work bound per
replica: replication multiplies serving throughput, partitioning bounds
per-device memory and latency, and the 2-D mesh composes both without
either path paying for the other.

:class:`Topology` owns mesh construction (delegating to
``core.engine.make_mesh2d``), replica-aware placement helpers, and the
per-replica load accounting that routing decisions and telemetry read.
Engines accept ``topology=`` and thread it through the planner
(``ShapeSig.replicas``), the bucket executor, warming, and the adaptive
capacity model's keys.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional

from ..core.engine import DATA_AXIS, SHARD_AXIS, make_mesh2d

__all__ = ["DATA_AXIS", "SHARD_AXIS", "ReplicaBalancer", "Topology",
           "make_topology"]


class ReplicaBalancer:
    """Least-loaded replica selection with per-replica load accounting.

    Pure bookkeeping — no device state, thread-safe.  The executor
    :meth:`acquire`\\ s at *dispatch* and :meth:`release`\\ s at *collect*
    (``InFlightBucket._finish``), so a dispatched-but-uncollected bucket
    keeps its weight visible for the whole time it occupies a device —
    overlapping dispatches therefore spread across rows instead of piling
    onto one (before the async split, acquire/release bracketed a
    synchronous call and in-flight weight was never observable from
    outside).  ``weight`` is the bucket's estimated cost (the executor
    uses ``B * G``, the phase-1 row count).  :meth:`acquire` picks the
    replica with the least in-flight weight, breaking ties by least
    cumulative dispatched weight (so an idle, synchronous serving loop
    degenerates to weighted round-robin), then by replica id
    (deterministic).  A dispatch that *fails* releases immediately —
    nothing will ever collect it.

    :meth:`loads` snapshots the accounting — ``in_flight`` weight,
    cumulative ``dispatched`` bucket count, ``weight``, a per-row
    ``queued_weight`` histogram (power-of-two buckets over per-bucket
    acquired weight — the row's load *distribution*, not just its total),
    and ``failures`` (buckets released via ``release(..., failed=True)``
    because dispatch or collect raised) — for telemetry, benchmarks, and
    the distribution tests.
    """

    # pow2 upper bounds for the per-row acquired-weight histogram; weight
    # is B * G (phase-1 rows), so the lattice spans one tiny bucket to a
    # full-capacity giant.  Last bucket is the +Inf overflow.
    WEIGHT_BUCKETS = tuple(float(1 << i) for i in range(0, 32, 2))

    def __init__(self, n_replicas: int):
        assert n_replicas >= 1
        self.n_replicas = int(n_replicas)
        self._lock = threading.Lock()
        self._in_flight = [0.0] * self.n_replicas
        self._dispatched = [0] * self.n_replicas
        self._weight = [0.0] * self.n_replicas
        self._failures = [0] * self.n_replicas
        nb = len(self.WEIGHT_BUCKETS) + 1
        self._weight_hist = [[0] * nb for _ in range(self.n_replicas)]

    def acquire(self, weight: float = 1.0) -> int:
        """Pick the least-loaded replica and account ``weight`` to it."""
        weight = float(weight)
        b = bisect.bisect_left(self.WEIGHT_BUCKETS, weight)
        with self._lock:
            r = min(
                range(self.n_replicas),
                key=lambda i: (self._in_flight[i], self._weight[i], i),
            )
            self._in_flight[r] += weight
            self._dispatched[r] += 1
            self._weight[r] += weight
            self._weight_hist[r][b] += 1
            return r

    def release(self, replica: int, weight: float = 1.0,
                failed: bool = False) -> None:
        """Return ``weight`` of in-flight load on ``replica`` (bucket done).

        ``failed=True`` marks the release as a dispatch/collect failure:
        the weight comes back either way (nothing will ever collect the
        bucket), but the failure leaves a telemetry trace in
        ``loads()[r]["failures"]`` instead of vanishing.
        """
        with self._lock:
            self._in_flight[replica] = max(
                0.0, self._in_flight[replica] - float(weight))
            if failed:
                self._failures[replica] += 1

    def loads(self) -> List[Dict[str, float]]:
        """Per-replica accounting snapshot (index = replica id).  Taken
        under the balancer lock in one pass — rows are mutually
        consistent.  ``queued_weight`` is the cumulative histogram of
        per-bucket acquired weights: ``counts[i]`` buckets had weight <=
        ``buckets[i]`` (trailing count = above the last bound)."""
        with self._lock:
            out = []
            for r in range(self.n_replicas):
                cum, cumulative = 0, []
                for c in self._weight_hist[r]:
                    cum += c
                    cumulative.append(cum)
                out.append({
                    "in_flight": self._in_flight[r],
                    "dispatched": self._dispatched[r],
                    "weight": self._weight[r],
                    "failures": self._failures[r],
                    "queued_weight": {
                        "buckets": list(self.WEIGHT_BUCKETS),
                        "counts": cumulative,
                    },
                })
            return out

    def reset(self) -> None:
        """Zero all accounting (in-flight, dispatched, cumulative weight,
        failures, weight histograms).

        Benchmark/test hygiene between measured passes — never call it
        while buckets are in flight: their deferred :meth:`release` at
        collect time would subtract from the zeroed state (clamped at 0,
        but the rows' relative loads would be skewed until drained).
        """
        with self._lock:
            self._in_flight = [0.0] * self.n_replicas
            self._dispatched = [0] * self.n_replicas
            self._weight = [0.0] * self.n_replicas
            self._failures = [0] * self.n_replicas
            nb = len(self.WEIGHT_BUCKETS) + 1
            self._weight_hist = [[0] * nb for _ in range(self.n_replicas)]


class Topology:
    """A 2-D ``(data, shard)`` device mesh plus replica-aware placement.

    Thin, explicit ownership of everything layout-related that used to be
    implicit in "the 1-D mesh": the mesh itself, the axis names, which
    device anchors each replica row, and the load balancer.  Engines hold
    one Topology and derive all routing from it:

    - ``replicas`` / ``shards`` — the mesh shape; the planner stamps both
      into ``ShapeSig`` so 2-D-routed buckets never mix with others.
    - :meth:`replica_device` — the row's anchor device for balancer-
      dispatched single-device buckets (plain per-replica mirrors are
      committed there at index time).
    - ``balancer`` — :class:`ReplicaBalancer` spreading those buckets.

    Build one with :func:`make_topology` (or wrap an existing 2-D mesh).
    """

    def __init__(self, mesh, data_axis: str = DATA_AXIS,
                 shard_axis: str = SHARD_AXIS):
        assert data_axis in mesh.shape and shard_axis in mesh.shape, (
            f"mesh axes {tuple(mesh.shape)} must include "
            f"{data_axis!r} and {shard_axis!r}"
        )
        self.mesh = mesh
        self.data_axis = data_axis
        self.shard_axis = shard_axis
        self.balancer = ReplicaBalancer(self.replicas)
        self._row_meshes: Dict[int, object] = {}

    @property
    def replicas(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def shards(self) -> int:
        return self.mesh.shape[self.shard_axis]

    def replica_device(self, r: int):
        """Replica row ``r``'s anchor device (column 0): where the row's
        plain mirrors live and its single-device buckets execute."""
        return self.replica_devices(r)[0]

    def replica_devices(self, r: int) -> list:
        """All devices of replica row ``r``, in shard order."""
        devices = self.mesh.devices
        if self.mesh.axis_names.index(self.data_axis) == 0:
            return list(devices[r])
        return list(devices[:, r])

    def row_mesh(self, r: int):
        """Replica row ``r``'s 1-D z-sharding submesh (cached — Mesh
        identity keys the row's jit executables, so every call for the
        same row must return the same object).  The 2-D pipeline runs one
        1-D shard_map per row on these."""
        if r not in self._row_meshes:
            from jax.sharding import Mesh
            import numpy as np

            self._row_meshes[r] = Mesh(
                np.asarray(self.replica_devices(r)), (self.shard_axis,))
        return self._row_meshes[r]

    def describe(self) -> str:
        """``"RxS"`` layout label (e.g. ``"2x2"``), used in benchmark and
        telemetry output."""
        return f"{self.replicas}x{self.shards}"

    def load_snapshot(self) -> List[Dict[str, float]]:
        """The balancer's per-replica accounting (telemetry surface)."""
        return self.balancer.loads()


def make_topology(replicas: int, shards: Optional[int] = None,
                  data_axis: str = DATA_AXIS,
                  shard_axis: str = SHARD_AXIS) -> Topology:
    """Build a :class:`Topology` over the first ``replicas * shards`` local
    devices (``shards`` defaults to spending every device).  On CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
    jax call to get forced host devices to lay out."""
    return Topology(
        make_mesh2d(replicas, shards, data_axis=data_axis,
                    shard_axis=shard_axis),
        data_axis=data_axis, shard_axis=shard_axis,
    )
