"""Hashbin candidate pre-filter for the suggestion (set-similarity) path.

Scoring a probe against every corpus set on the device would make
``suggest`` O(corpus) in device work.  The paper's HashBin structure
(Section 3.3) already gives each set a w-bin occupancy signature for free:
hash ``h_0`` maps elements into ``[0, w)`` bins, and two sets sharing a
common element necessarily occupy the SAME bin under the same family — so
``popcount(bins(probe) & bins(candidate)) >= 1`` for every candidate with
non-empty intersection.  The pre-filter keeps exactly the candidates whose
shared-bin count clears ``min_shared_bins``; at the default threshold of 1
it can NEVER drop a true-overlap candidate (no false negatives — the
device's count pass stays exact over the kept set), while disjoint
candidates survive only by hash collision.  This is the same
signature-then-verify shape as cuckoo-filter pre-probing (Goodrich, arXiv
1708.09059): a cheap word-parallel host screen in front of the exact
device kernels.

Ranking and capping: kept candidates order by ``(-shared_bins, id)`` —
most plausible first, ties to the smallest id (the global suggest
tie-break) — so an optional ``max_candidates`` cap keeps the most
promising prefix.  A cap can drop true positives (shared bins only bound
the intersection from above by min(n_probe, n_cand) and below by
shared/m-ish collision noise), so exact-oracle callers leave it ``None``.

Counters: ``EXEC_COUNTERS["suggest_prefilter_in"]`` counts candidates
examined, ``["suggest_prefilter_kept"]`` candidates kept — the ratio is
the screen's selectivity, surfaced in benchmark stats.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.engine import EXEC_COUNTERS
from ..core.hashing import HashFamily

__all__ = ["CandidateIndex"]


class CandidateIndex:
    """Per-set hash-bin occupancy bitmaps + the shared-bin screen.

    Host-side numpy, append-only: :meth:`add` folds one set's values
    through the family's ``h_0`` into a packed ``w``-bit occupancy word
    row; :meth:`candidates` screens the whole corpus against one probe
    with a single vectorized AND + popcount.  The structure is the
    word-representation half of the paper's HashBin, pooled per *set*
    instead of per group — O(corpus * w / 8) bytes total.

    All sets must share one :class:`~repro.core.hashing.HashFamily` (the
    screen's soundness argument needs a common ``h_0``); the serving layer
    passes the same family its indexes use.
    """

    def __init__(self, family: HashFamily):
        self.family = family
        self.w = int(family.w)
        self.words = self.w // 32
        assert self.words * 32 == self.w, "w must be a multiple of 32"
        self._ids: List = []
        self._pos: Dict = {}
        self._rows: List[np.ndarray] = []
        self._matrix: Optional[np.ndarray] = None  # (n_sets, words) cache

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, set_id) -> bool:
        return set_id in self._pos

    def _signature(self, values: np.ndarray) -> np.ndarray:
        bins = np.asarray(
            self.family.apply(np.asarray(values, np.uint32), 0), np.uint32)
        row = np.zeros(self.words, np.uint32)
        np.bitwise_or.at(row, bins >> np.uint32(5),
                         np.uint32(1) << (bins & np.uint32(31)))
        return row

    def add(self, set_id, values: Sequence[int]) -> None:
        """Register (or refresh) one corpus set's occupancy signature."""
        row = self._signature(np.asarray(values, np.uint32))
        if set_id in self._pos:
            self._rows[self._pos[set_id]] = row
        else:
            self._pos[set_id] = len(self._ids)
            self._ids.append(set_id)
            self._rows.append(row)
        self._matrix = None  # stacked cache is stale

    def _stacked(self) -> np.ndarray:
        if self._matrix is None:
            self._matrix = (np.stack(self._rows) if self._rows
                            else np.zeros((0, self.words), np.uint32))
        return self._matrix

    def candidates(
        self,
        probe_values: Sequence[int],
        exclude=None,
        min_shared_bins: int = 1,
        max_candidates: Optional[int] = None,
    ) -> List:
        """Screen the corpus against one probe; returns kept set ids.

        Ordered by ``(-shared_bins, id)``.  ``exclude`` (typically the
        probe's own id) is never returned.  ``min_shared_bins=1`` is the
        no-false-negative setting — a common element occupies the same
        ``h_0`` bin in both signatures, so every true-overlap candidate
        shares at least one bin.  ``max_candidates`` truncates to the
        most-shared prefix (approximate — see module docstring).
        """
        matrix = self._stacked()
        EXEC_COUNTERS["suggest_prefilter_in"] += len(self._ids)
        if not len(self._ids):
            return []
        row = self._signature(np.asarray(probe_values, np.uint32))
        inter = matrix & row[None, :]
        shared = np.unpackbits(
            inter.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
        keep = np.nonzero(shared >= int(min_shared_bins))[0]
        kept = sorted(
            ((int(-shared[i]), self._ids[i]) for i in keep
             if self._ids[i] != exclude))
        if max_candidates is not None:
            kept = kept[:int(max_candidates)]
        EXEC_COUNTERS["suggest_prefilter_kept"] += len(kept)
        return [set_id for _, set_id in kept]
