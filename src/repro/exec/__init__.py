"""Batched multi-query execution subsystem.

Dataflow: :mod:`plan` normalizes raw queries into shape-keyed
:class:`~repro.exec.plan.QueryPlan`\\ s; :mod:`batch` groups plans by
signature and drives one jit execution per bucket through
``core.engine.intersect_device_batch``.
"""
from .plan import QueryPlan, ShapeSig, plan_query
from .batch import bucket_plans, execute_name_queries, execute_plan_buckets

__all__ = [
    "QueryPlan",
    "ShapeSig",
    "plan_query",
    "bucket_plans",
    "execute_name_queries",
    "execute_plan_buckets",
]
