"""Batched multi-query execution subsystem.

Dataflow: :mod:`plan` normalizes raw queries into shape-keyed
:class:`~repro.exec.plan.QueryPlan`\\ s; :mod:`batch` groups plans by
signature and drives one jit execution per bucket through
``core.engine.intersect_device_batch`` (:func:`~repro.exec.batch.
execute_bucket` is the single-bucket entry the async admission front-end
flushes into); :mod:`cache` remembers results of repeated normalized plans
so hits skip the device entirely.
"""
from .plan import QueryPlan, ShapeSig, plan_query
from .batch import (
    bucket_plans,
    execute_bucket,
    execute_name_queries,
    execute_plan_buckets,
)
from .cache import ResultCache

__all__ = [
    "QueryPlan",
    "ShapeSig",
    "plan_query",
    "bucket_plans",
    "execute_bucket",
    "execute_name_queries",
    "execute_plan_buckets",
    "ResultCache",
]
