"""Batched multi-query execution subsystem.

Dataflow: :mod:`plan` normalizes raw queries into shape-keyed
:class:`~repro.exec.plan.QueryPlan`\\ s; :mod:`batch` groups plans by
signature and drives one jit execution per bucket through
``core.engine.intersect_device_batch`` (:func:`~repro.exec.batch.
execute_bucket` is the single-bucket entry the async admission front-end
flushes into, and :func:`~repro.exec.batch.dispatch_bucket` /
:class:`~repro.exec.batch.InFlightBucket` its asynchronous split — issue
the jit call now, collect the transfer + overflow re-run later — so a
serving loop overlaps independent buckets); :mod:`cache` remembers
results of repeated normalized plans
so hits skip the device entirely; :mod:`adaptive` closes the telemetry
loop — learned capacity tiers from observed survivor counts and adaptive
flush budgets from observed arrival rates; :mod:`topology` owns the 2-D
``(data, shard)`` device mesh — replica placement, the per-replica load
balancer, and the layout the planner's ``(shards, replicas)`` routing
targets.

:mod:`expr` generalizes queries from flat conjunctions to canonicalized
boolean expression DAGs over ∩/∪/∖ — node types, the normalizer, the
``parse`` surface syntax, and the numpy oracle the device DAG evaluator
must match bit-for-bit.  Expression plans ride the same
plan → bucket → execute → scatter pipeline (``ShapeSig.eshape`` keys
their executables) and the result cache additionally remembers
canonicalized *sub*expressions so shared subtrees skip the device.
"""
from .expr import (
    EMPTY, And, Diff, Expr, Or, Term, canonicalize, eval_host, expr_key,
    expr_shape, flat_terms, leaf_terms, parse, subexpr_keys,
)
from .plan import QueryPlan, ShapeSig, plan_query
from .adaptive import AdaptiveDeadline, CapacityModel, adaptive_key
from .batch import (
    InFlightBucket,
    bucket_plans,
    dispatch_bucket,
    execute_bucket,
    execute_name_queries,
    execute_plan_buckets,
)
from .cache import ResultCache
from .topology import ReplicaBalancer, Topology, make_topology

__all__ = [
    "EMPTY",
    "And",
    "Diff",
    "Expr",
    "Or",
    "Term",
    "canonicalize",
    "eval_host",
    "expr_key",
    "expr_shape",
    "flat_terms",
    "leaf_terms",
    "parse",
    "subexpr_keys",
    "QueryPlan",
    "ShapeSig",
    "plan_query",
    "AdaptiveDeadline",
    "CapacityModel",
    "adaptive_key",
    "InFlightBucket",
    "bucket_plans",
    "dispatch_bucket",
    "execute_bucket",
    "execute_name_queries",
    "execute_plan_buckets",
    "ResultCache",
    "ReplicaBalancer",
    "Topology",
    "make_topology",
]
