"""LRU result cache keyed on the normalized plan.

Real query logs repeat themselves — Zipf term popularity means the same
conjunction arrives over and over — so the cheapest "execution" path of all
is remembering the answer.  The cache key is
:meth:`~repro.exec.plan.QueryPlan.cache_key` (routing algorithm + the
dedup'd, deterministically sorted term tuple), so every surface form of a
repeated query hits the same entry and a cached hit skips planning's
downstream entirely: no bucket, no device dispatch, no jit execution.

Hit/miss telemetry is folded into ``EXEC_COUNTERS``
(``result_cache_hits`` / ``result_cache_misses``) next to the jit-execution
counters, so a serving run can report "N queries = H cache hits + B bucket
executions" from one place.

The cache is policy-free about *what* is cacheable: callers decide (the
serving layer skips ``"empty"`` plans — a miss counter bumping on every
unresolvable query would skew hit-rate telemetry for no saved work).
Stored values are treated as immutable; callers must not mutate a returned
result's arrays.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional

from ..core.engine import EXEC_COUNTERS
from .plan import QueryPlan

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU mapping ``QueryPlan.cache_key() -> result``.

    ``get`` bumps ``EXEC_COUNTERS["result_cache_hits"]`` /
    ``["result_cache_misses"]``; ``put`` evicts least-recently-used entries
    past ``capacity``.  A ``capacity`` of 0 disables the cache (every
    ``get`` is a silent miss that touches no counter, so a disabled cache
    is telemetry-invisible).
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, plan: QueryPlan) -> Optional[Any]:
        """Return the cached result for ``plan``, or None (counted miss)."""
        if self.capacity <= 0:
            return None
        key = plan.cache_key()
        if key in self._entries:
            self._entries.move_to_end(key)
            EXEC_COUNTERS["result_cache_hits"] += 1
            return self._entries[key]
        EXEC_COUNTERS["result_cache_misses"] += 1
        return None

    def put(self, plan: QueryPlan, value: Any) -> None:
        """Insert/refresh ``plan``'s result; evict LRU past capacity."""
        if self.capacity <= 0:
            return
        key = plan.cache_key()
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
