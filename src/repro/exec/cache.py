"""LRU result cache keyed on the normalized plan.

Real query logs repeat themselves — Zipf term popularity means the same
conjunction arrives over and over — so the cheapest "execution" path of all
is remembering the answer.  The cache key is
:meth:`~repro.exec.plan.QueryPlan.cache_key` (routing algorithm + the
dedup'd, deterministically sorted term tuple), so every surface form of a
repeated query hits the same entry and a cached hit skips planning's
downstream entirely: no bucket, no device dispatch, no jit execution.

Hit/miss telemetry is folded into ``EXEC_COUNTERS``
(``result_cache_hits`` / ``result_cache_misses``) next to the jit-execution
counters, so a serving run can report "N queries = H cache hits + B bucket
executions" from one place.

The cache is policy-free about *what* is cacheable: callers decide (the
serving layer skips ``"empty"`` plans — a miss counter bumping on every
unresolvable query would skew hit-rate telemetry for no saved work).
Stored values are treated as immutable; callers must not mutate a returned
result's arrays.

Index mutation safety: the cache key is only ``(algorithm, terms)`` — it
cannot see that a term's postings changed underneath it.  Owners of a
mutable index therefore bump the cache's **generation** on every mutation
(``bump_generation``; the serving layer registers it as a
``BatchedEngine.on_mutate`` hook): entries stamped with an older
generation are treated as misses and evicted lazily on lookup, so a
repeated conjunction can never serve postings from before the mutation.
``invalidate()`` is the explicit everything-now hook.

Subexpression entries: with the expression DAG engine, the cache also
stores **canonicalized subexpression** results (``get_sub`` / ``put_sub``,
keyed on raw ``exec.expr.expr_key`` tuples under a ``"subexpr"``
namespace) so a subtree shared across queries — ``a∪b`` inside both
``(a∪b)∩c`` and ``(a∪b)∖d`` — resolves on the host without device work.
Sub entries share the LRU budget and the generation mechanics with plan
entries but count into separate telemetry
(``subexpr_cache_hits`` / ``subexpr_cache_misses`` /
``subexpr_cache_stores``), so the root hit-rate numbers stay comparable
with pre-expression serving runs.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Optional, Tuple

from ..core.engine import EXEC_COUNTERS
from .plan import QueryPlan

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU mapping ``QueryPlan.cache_key() -> result``, with a
    generation stamp per entry for index-mutation invalidation.

    ``get`` bumps ``EXEC_COUNTERS["result_cache_hits"]`` /
    ``["result_cache_misses"]``; ``put`` evicts least-recently-used entries
    past ``capacity``.  A ``capacity`` of 0 disables the cache (every
    ``get`` is a silent miss that touches no counter, so a disabled cache
    is telemetry-invisible).

    Entries are stamped with the cache's current ``generation`` at ``put``
    time; ``bump_generation()`` (called on every index mutation) makes all
    older entries stale — a stale lookup counts as a miss and evicts the
    entry, so invalidation is O(1) at mutation time and lazy thereafter.

    Thread-safety: all methods serialize on an internal lock.  The async
    front-end reads the cache from many submitter threads while the
    background flusher stores results from its own thread; unlocked
    ``move_to_end`` / ``del`` sequences would corrupt the OrderedDict under
    that interleaving.
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self.generation = 0
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, plan: QueryPlan) -> Optional[Any]:
        """Return the cached result for ``plan``, or None (counted miss).
        Entries from an older generation are stale: evicted, counted as a
        miss."""
        if self.capacity <= 0:
            return None
        key = plan.cache_key()
        with self._lock:
            if key in self._entries:
                gen, value = self._entries[key]
                if gen != self.generation:
                    del self._entries[key]
                else:
                    self._entries.move_to_end(key)
                    EXEC_COUNTERS["result_cache_hits"] += 1
                    return value
            EXEC_COUNTERS["result_cache_misses"] += 1
            return None

    def put(self, plan: QueryPlan, value: Any,
            generation: Optional[int] = None) -> None:
        """Insert/refresh ``plan``'s result; evict LRU past capacity.

        ``generation`` is the generation the result was computed *against*
        — callers capture it before executing and pass it here, so a result
        computed against a pre-mutation index but stored after a
        ``bump_generation`` is rejected instead of being stamped fresh
        (the flush-races-with-mutation hazard).  ``None`` means "computed
        just now" and uses the current generation.
        """
        if self.capacity <= 0:
            return
        key = plan.cache_key()
        with self._lock:
            stamp = self.generation if generation is None else generation
            if stamp != self.generation:
                return  # computed against a mutated-away index: never cache
            self._entries[key] = (stamp, value)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    # -- subexpression entries ---------------------------------------------
    # same LRU + generation machinery, namespaced keys, separate counters

    @staticmethod
    def _sub_key(key) -> Tuple[str, Any]:
        # namespace the raw expr_key so a subexpression entry can never
        # collide with a plan entry (whose key is (algorithm, terms/key))
        return ("subexpr", key)

    def get_sub(self, key) -> Optional[Any]:
        """Return the cached value for canonical subexpression ``key`` (a
        raw ``expr_key`` tuple), or None.  Counts
        ``subexpr_cache_hits`` / ``subexpr_cache_misses``; stale-generation
        entries evict as misses, exactly like plan entries."""
        if self.capacity <= 0:
            return None
        skey = self._sub_key(key)
        with self._lock:
            if skey in self._entries:
                gen, value = self._entries[skey]
                if gen != self.generation:
                    del self._entries[skey]
                else:
                    self._entries.move_to_end(skey)
                    EXEC_COUNTERS["subexpr_cache_hits"] += 1
                    return value
            EXEC_COUNTERS["subexpr_cache_misses"] += 1
            return None

    def put_sub(self, key, value: Any,
                generation: Optional[int] = None) -> None:
        """Insert/refresh a canonical subexpression value; same generation
        contract as :meth:`put` (a value computed against a mutated-away
        index is rejected).  Counts ``subexpr_cache_stores``."""
        if self.capacity <= 0:
            return
        skey = self._sub_key(key)
        with self._lock:
            stamp = self.generation if generation is None else generation
            if stamp != self.generation:
                return
            self._entries[skey] = (stamp, value)
            self._entries.move_to_end(skey)
            EXEC_COUNTERS["subexpr_cache_stores"] += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def bump_generation(self) -> None:
        """Mark every current entry stale (index mutated).  O(1): stale
        entries are evicted lazily by ``get``.  Registered as the engine's
        ``on_mutate`` hook by the serving layer."""
        with self._lock:
            self.generation += 1

    def invalidate(self) -> None:
        """Explicit hook: drop everything now AND advance the generation
        (so in-flight results whose callers captured the old generation
        are rejected by ``put`` instead of re-entering as fresh).  Also
        fired on adaptive capacity-tier promotions — the deliberate
        invalidation point when learned tiers re-key the executables."""
        with self._lock:
            self.generation += 1
            self._entries.clear()

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
