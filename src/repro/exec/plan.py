"""Query planner: normalize a raw query into a shape-keyed QueryPlan.

A query arrives as a bag of terms.  Planning does, in order:

  1. **Normalize** — drop duplicate terms (``[t, t]`` is ``[t]``), resolve
     terms against the index, and sort the survivors by ``(t, n, term)`` so
     prefix alignment (ascending t) and the base-set choice (smallest set
     first) are deterministic across the host and device paths.
  2. **Algorithm selection** — the paper's §3.4 online policy: two sets with
     an extreme size ratio go to HashBin (per-bin binary search beats the
     group machinery when n2/n1 is large); everything else runs
     RanGroupScan, on the device when one is attached.
  3. **Shape signature** — device-bound plans are keyed by
     ``ShapeSig(k, ts, gmaxes, capacity_tier, shards)``.  Two queries with
     the same signature stack into the same ``(B, …)`` arrays and share one
     compiled executable; real logs concentrate on a handful of signatures
     (68% of queries are 2-word, 23% 3-word — §4), which is what makes
     bucketed compilation pay.
  4. **Mesh routing** — with a device mesh attached (``mesh_shards > 1``
     or ``mesh_replicas > 1``), queries whose largest set has
     ``2^t_k >= shard_min_g`` group tuples route to the mesh pipeline:
     ``sig.shards = mesh_shards`` splits the z-prefix space with zero
     communication (Theorem 3.7 alignment) and — on a 2-D topology —
     ``sig.replicas = mesh_replicas`` splits the bucket's batch axis over
     the data-parallel replica rows.  Small queries stay single-device
     (``shards = replicas = 1``) where the shard_map dispatch overhead
     would dominate (on a multi-replica topology the *executor* then
     spreads their buckets over the replicas via the load balancer — a
     placement decision, not a shape, so it never appears in the
     signature), and so do queries whose smallest set doesn't split evenly
     over the z axis (``2^t_0 % mesh_shards != 0``) — the alignment
     precondition.

The planner only reads cheap per-set metadata (``t``, ``gmax``, ``n``), so
it works identically over host ``PrefixIndex`` objects and device
``DeviceSet`` mirrors.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from ..core.engine import (
    SHARD_MIN_G, default_capacity, default_expr_capacity, default_k_tier,
    gmax_tier, set_sort_key,
)
from .expr import (
    EMPTY, Expr, canonicalize, expr_key, expr_shape, flat_terms, leaf_terms,
    parse,
)

__all__ = ["SHARD_MIN_G", "ShapeSig", "QueryPlan", "plan_query",
           "plan_suggest"]


@dataclasses.dataclass(frozen=True)
class ShapeSig:
    """Static shape signature of a device execution — the jit cache key.

    ``shards`` is 1 for single-device buckets and the z-axis width for
    mesh-routed ones; ``replicas`` is 1 except on a 2-D topology, where
    mesh-routed buckets split their batch axis over ``replicas``
    data-parallel rows.  Both are part of the signature because each
    combination compiles a different executable (and must not mix in one
    stacked bucket).

    ``eshape`` is ``None`` for flat conjunctions (keeping their signatures
    byte-identical to the pre-expression planner) and the leaf-erased
    expression shape (``exec.expr.expr_shape``) for boolean-expression
    plans: two expressions with the same operator tree stack into one
    ``(B, …)`` bucket and share a compiled DAG executable, with ``ts`` /
    ``gmaxes`` carried per leaf in the expression's canonical traversal
    order rather than sorted.

    ``cands`` is 0 for point-query and expression plans, and the
    power-of-two candidate-axis tier (> 0) for count-only suggest plans —
    the third workload kind.  For suggest signatures ``ts`` / ``gmaxes``
    are the ``(probe, candidate-class)`` pair in that fixed order (NOT
    sorted — the count jit's alignment shift is direction-aware) and
    ``capacity_tier`` holds the top-K *selection* tier instead of a
    survivor-buffer size (the count path has no survivor buffer, so the
    field is free — see ``core.engine.default_k_tier``).
    """

    k: int
    ts: Tuple[int, ...]
    gmaxes: Tuple[int, ...]
    capacity_tier: int
    shards: int = 1
    replicas: int = 1
    eshape: Optional[Tuple] = None
    cands: int = 0


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A normalized, routed query.

    ``terms`` are deduped and (t, n, term)-sorted for flat conjunctions,
    and the canonical expression's leaf terms (traversal order, with
    multiplicity) when ``expr`` is set; ``algorithm`` is one of
    ``"device"`` (bucketed batch path), ``"hashbin"`` / ``"host"`` (host
    execution), or ``"empty"`` (a term has no postings — or the expression
    canonicalizes to ∅).  ``sig`` is set iff ``algorithm == "device"``;
    ``expr`` is the canonical :class:`~repro.exec.expr.Expr` for boolean
    expression plans and ``None`` for flat conjunctions (including
    expressions that *normalize* to a flat conjunction — those delegate to
    the legacy planner and produce byte-identical plans).
    """

    terms: Tuple
    algorithm: str
    sig: Optional[ShapeSig] = None
    expr: Optional[Expr] = None

    def cache_key(self) -> Tuple[str, Tuple]:
        """Canonical result-cache key for this plan.

        Because planning dedups terms and sorts them deterministically (by
        ``(t, n, term)``), every surface form of the same conjunction —
        ``[a, b]``, ``[b, a]``, ``[a, a, b]`` — normalizes to the same
        ``terms`` tuple, so one cache entry serves them all.  Expression
        plans key on ``expr_key(expr)`` of the *canonical* expression
        instead, so algebraically equal expressions (``(b|a)&c`` vs
        ``c&(a|b)``) share an entry; expressions that canonicalize to a
        flat conjunction carry ``expr=None`` and fall into the flat
        keyspace, sharing entries with term-list queries of the same
        conjunction.

        The routing algorithm is part of the key: host and device paths
        return identical values, but keying on it keeps an entry from
        outliving a routing change.  This matters more with canonical
        expression keys: the same query text re-planned after a device
        attach/detach yields the same canonical expression but a different
        algorithm, so the stale-routing entry can never be served — the
        (algorithm, key) pair misses and the fresh route repopulates it.
        """
        if self.sig is not None and self.sig.cands:
            # suggest plans: same terms as a flat conjunction would carry,
            # but a count-only execution — key them apart, and include the
            # selection tier so suggest(id, 8) never serves suggest(id, 64)
            return ("suggest", (self.terms, self.sig.capacity_tier))
        if self.expr is not None:
            return (self.algorithm, expr_key(self.expr))
        return (self.algorithm, self.terms)

    def query_spec(self):
        """What to re-plan to reproduce this plan: the canonical expression
        when one is set, else the flat term list.  The async flusher uses
        this for its dispatch-time staleness check and host fallback."""
        return self.expr if self.expr is not None else list(self.terms)


def plan_query(
    index: Mapping,
    terms: Sequence,
    hashbin_ratio: float = 100.0,
    device: bool = True,
    mesh_shards: int = 1,
    shard_min_g: int = SHARD_MIN_G,
    capacity_model=None,
    mesh_replicas: int = 1,
) -> QueryPlan:
    """Plan one query against ``index`` (term -> set with .t/.gmax/.n).

    ``terms`` may be a term sequence (flat conjunction — the legacy
    surface, planned exactly as before), an :class:`~repro.exec.expr.
    Expr` over ∩/∪/∖, or a :func:`~repro.exec.expr.parse` surface string
    (``"(1|2)&3-4"``).  Expressions are canonicalized first; an expression
    that normalizes to a bare conjunction (``a & b``, ``(a&b)&a`` …)
    delegates to the flat path and yields a byte-identical plan — same
    terms, algorithm, signature, and cache key as the equivalent term
    list.  Irreducible expressions become device plans with
    ``sig.eshape`` set (ts/gmaxes per leaf in canonical traversal order)
    and ``plan.expr`` carrying the canonical DAG; the §3.4 hashbin policy
    never applies to them (it is a 2-term conjunction special case).

    Pure metadata work — touches no arrays, runs no device code, and
    increments no ``EXEC_COUNTERS``.  For device-routed plans the returned
    ``sig.gmaxes`` are power-of-two tiers (``gmax_tier``) and
    ``sig.capacity_tier`` is ``default_capacity(ts)``, so the signature
    matches the static shapes the executor will stack into ``(B, …)``
    arrays exactly.  With ``mesh_shards > 1`` (and/or ``mesh_replicas >
    1``, the data-parallel width of a 2-D topology), huge-G queries
    (``2^t_k >= shard_min_g``) whose smallest set splits evenly over the
    z axis get ``sig.shards = mesh_shards`` / ``sig.replicas =
    mesh_replicas`` and execute on the mesh.

    With a ``capacity_model`` (``exec.adaptive.CapacityModel``) attached,
    ``capacity_tier`` is the model's learned tier for the signature's
    adaptive key — the telemetry-sized survivor buffer — falling back to
    the static ``default_capacity`` rule while the signature is cold.
    Consulting the model stays pure metadata work (a dict lookup under the
    model's lock).
    """
    if isinstance(terms, str):
        terms = parse(terms)
    if isinstance(terms, Expr):
        return _plan_expr(
            index, terms, device=device, mesh_shards=mesh_shards,
            shard_min_g=shard_min_g, capacity_model=capacity_model,
            mesh_replicas=mesh_replicas, hashbin_ratio=hashbin_ratio,
        )
    uniq = []
    seen = set()
    for term in terms:
        if term in seen:
            continue
        seen.add(term)
        uniq.append(term)
    if not uniq or any(t not in index for t in uniq):
        return QueryPlan(terms=tuple(uniq), algorithm="empty")
    # the shared (t, n) set ordering, with the term itself as a final
    # tie-break so equal-(t, n) sets still order deterministically
    uniq.sort(key=lambda t: (*set_sort_key(index[t]), t))
    ns = [index[t].n for t in uniq]
    if len(uniq) == 2 and max(ns) / max(1, min(ns)) > hashbin_ratio:
        return QueryPlan(terms=tuple(uniq), algorithm="hashbin")
    if not device:
        return QueryPlan(terms=tuple(uniq), algorithm="host")
    ts = tuple(index[t].t for t in uniq)
    gmaxes = tuple(gmax_tier(index[t].gmax) for t in uniq)
    shards, replicas = 1, 1
    if ((mesh_shards > 1 or mesh_replicas > 1)
            and (1 << ts[-1]) >= shard_min_g
            and (1 << ts[0]) % mesh_shards == 0):
        shards, replicas = mesh_shards, mesh_replicas
    capacity = default_capacity(ts)
    if capacity_model is not None:
        from .adaptive import adaptive_key_parts

        capacity = capacity_model.capacity_for(
            adaptive_key_parts(len(uniq), ts, gmaxes, shards,
                               replicas=replicas), capacity)
    sig = ShapeSig(
        k=len(uniq), ts=ts, gmaxes=gmaxes,
        capacity_tier=capacity, shards=shards, replicas=replicas,
    )
    return QueryPlan(terms=tuple(uniq), algorithm="device", sig=sig)


def _plan_expr(
    index: Mapping,
    raw: Expr,
    hashbin_ratio: float,
    device: bool,
    mesh_shards: int,
    shard_min_g: int,
    capacity_model,
    mesh_replicas: int,
) -> QueryPlan:
    """Expression arm of :func:`plan_query`.

    Canonicalization happens against the index (unknown terms become ∅
    and propagate algebraically), so by the time a plan exists every leaf
    resolves.  Mesh routing mirrors the flat rule but must hold for
    *every* leaf: each leaf's group axis is shard_mapped independently, so
    all ``2^t`` must split evenly over the z axis, and the largest leaf
    gates the ``shard_min_g`` threshold.
    """
    can = canonicalize(raw, index)
    if can is EMPTY:
        return QueryPlan(terms=(), algorithm="empty")
    flat = flat_terms(can)
    if flat is not None:
        # pure conjunction after normalization -> the legacy flat planner,
        # byte-identical plans (and shared cache entries) with term lists
        return plan_query(
            index, list(flat), hashbin_ratio=hashbin_ratio, device=device,
            mesh_shards=mesh_shards, shard_min_g=shard_min_g,
            capacity_model=capacity_model, mesh_replicas=mesh_replicas,
        )
    leaves = leaf_terms(can)
    if not device:
        return QueryPlan(terms=leaves, algorithm="host", expr=can)
    ts = tuple(index[t].t for t in leaves)
    gmaxes = tuple(gmax_tier(index[t].gmax) for t in leaves)
    eshape = expr_shape(can)
    shards, replicas = 1, 1
    if ((mesh_shards > 1 or mesh_replicas > 1)
            and (1 << max(ts)) >= shard_min_g
            and all((1 << t) % mesh_shards == 0 for t in ts)):
        shards, replicas = mesh_shards, mesh_replicas
    capacity = default_expr_capacity(ts, gmaxes)
    if capacity_model is not None:
        from .adaptive import adaptive_key_parts

        capacity = capacity_model.capacity_for(
            adaptive_key_parts(len(leaves), ts, gmaxes, shards,
                               replicas=replicas, eshape=eshape), capacity)
    sig = ShapeSig(
        k=len(leaves), ts=ts, gmaxes=gmaxes, capacity_tier=capacity,
        shards=shards, replicas=replicas, eshape=eshape,
    )
    return QueryPlan(terms=leaves, algorithm="device", sig=sig, expr=can)


def plan_suggest(
    index: Mapping,
    probe,
    candidates: Sequence,
    k: int,
    device: bool = True,
    mesh_shards: int = 1,
    mesh_replicas: int = 1,
    shard_min_g: int = SHARD_MIN_G,
) -> QueryPlan:
    """Plan one count-only suggest bucket row: ``probe`` scored against a
    uniform *class* of ``candidates``.

    Every candidate must share one ``(t, gmax_tier)`` shape class — the
    count matrix stacks them along the C axis, so mixed shapes cannot
    share an executable; the serving layer splits a query's pre-filtered
    candidates into classes and issues one plan per class, merging top-K
    lists on the host (exact: each bucket returns its own top
    ``min(k_tier, c_tier)`` which is >= the final k).

    The plan's ``terms`` are ``(probe, *candidates)`` with candidates
    sorted **ascending by term** — the tie-break contract: the count jit's
    ``lax.top_k`` prefers the lowest candidate index on equal counts, so
    ascending order makes that "smallest candidate id wins".  ``sig.ts`` /
    ``sig.gmaxes`` carry the ``(probe, candidate)`` pair in that order
    (direction matters: the prefix-alignment shift in the count kernel is
    asymmetric), ``sig.cands`` is the pow2 candidate-axis tier, and
    ``sig.capacity_tier`` the pow2 top-K selection tier
    (:func:`~repro.core.engine.default_k_tier`).

    Mesh routing mirrors the flat rule but must hold for *both* z axes —
    per-shard counting is exact only when ``2^t`` splits evenly over the
    shards for probe and candidates alike — and gates on the *deeper* of
    the two (``max(ts)``) clearing ``shard_min_g``.
    """
    if probe not in index or not candidates:
        return QueryPlan(terms=(probe, *candidates), algorithm="empty")
    cands = sorted(set(candidates))
    if any(c not in index for c in cands):
        return QueryPlan(terms=(probe, *cands), algorithm="empty")
    tp = index[probe].t
    gp = gmax_tier(index[probe].gmax)
    tc = index[cands[0]].t
    gc = gmax_tier(index[cands[0]].gmax)
    for c in cands[1:]:
        assert (index[c].t, gmax_tier(index[c].gmax)) == (tc, gc), (
            "plan_suggest candidates must share one (t, gmax_tier) class"
        )
    if not device:
        return QueryPlan(terms=(probe, *cands), algorithm="host")
    ts = (tp, tc)
    shards, replicas = 1, 1
    if ((mesh_shards > 1 or mesh_replicas > 1)
            and (1 << max(ts)) >= shard_min_g
            and (1 << tp) % mesh_shards == 0
            and (1 << tc) % mesh_shards == 0):
        shards, replicas = mesh_shards, mesh_replicas
    sig = ShapeSig(
        k=2, ts=ts, gmaxes=(gp, gc),
        capacity_tier=default_k_tier(k),
        shards=shards, replicas=replicas,
        cands=1 << max(0, (len(cands) - 1).bit_length()),
    )
    return QueryPlan(terms=(probe, *cands), algorithm="device", sig=sig)
