"""Query planner: normalize a raw query into a shape-keyed QueryPlan.

A query arrives as a bag of terms.  Planning does, in order:

  1. **Normalize** — drop duplicate terms (``[t, t]`` is ``[t]``), resolve
     terms against the index, and sort the survivors by ``(t, n, term)`` so
     prefix alignment (ascending t) and the base-set choice (smallest set
     first) are deterministic across the host and device paths.
  2. **Algorithm selection** — the paper's §3.4 online policy: two sets with
     an extreme size ratio go to HashBin (per-bin binary search beats the
     group machinery when n2/n1 is large); everything else runs
     RanGroupScan, on the device when one is attached.
  3. **Shape signature** — device-bound plans are keyed by
     ``ShapeSig(k, ts, gmaxes, capacity_tier, shards)``.  Two queries with
     the same signature stack into the same ``(B, …)`` arrays and share one
     compiled executable; real logs concentrate on a handful of signatures
     (68% of queries are 2-word, 23% 3-word — §4), which is what makes
     bucketed compilation pay.
  4. **Mesh routing** — with a device mesh attached (``mesh_shards > 1``
     or ``mesh_replicas > 1``), queries whose largest set has
     ``2^t_k >= shard_min_g`` group tuples route to the mesh pipeline:
     ``sig.shards = mesh_shards`` splits the z-prefix space with zero
     communication (Theorem 3.7 alignment) and — on a 2-D topology —
     ``sig.replicas = mesh_replicas`` splits the bucket's batch axis over
     the data-parallel replica rows.  Small queries stay single-device
     (``shards = replicas = 1``) where the shard_map dispatch overhead
     would dominate (on a multi-replica topology the *executor* then
     spreads their buckets over the replicas via the load balancer — a
     placement decision, not a shape, so it never appears in the
     signature), and so do queries whose smallest set doesn't split evenly
     over the z axis (``2^t_0 % mesh_shards != 0``) — the alignment
     precondition.

The planner only reads cheap per-set metadata (``t``, ``gmax``, ``n``), so
it works identically over host ``PrefixIndex`` objects and device
``DeviceSet`` mirrors.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from ..core.engine import (
    SHARD_MIN_G, default_capacity, gmax_tier, set_sort_key,
)

__all__ = ["SHARD_MIN_G", "ShapeSig", "QueryPlan", "plan_query"]


@dataclasses.dataclass(frozen=True)
class ShapeSig:
    """Static shape signature of a device execution — the jit cache key.

    ``shards`` is 1 for single-device buckets and the z-axis width for
    mesh-routed ones; ``replicas`` is 1 except on a 2-D topology, where
    mesh-routed buckets split their batch axis over ``replicas``
    data-parallel rows.  Both are part of the signature because each
    combination compiles a different executable (and must not mix in one
    stacked bucket).
    """

    k: int
    ts: Tuple[int, ...]
    gmaxes: Tuple[int, ...]
    capacity_tier: int
    shards: int = 1
    replicas: int = 1


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A normalized, routed query.

    ``terms`` are deduped and (t, n, term)-sorted; ``algorithm`` is one of
    ``"device"`` (bucketed batch path), ``"hashbin"`` / ``"host"`` (host
    execution), or ``"empty"`` (a term has no postings — result is ∅).
    ``sig`` is set iff ``algorithm == "device"``.
    """

    terms: Tuple
    algorithm: str
    sig: Optional[ShapeSig] = None

    def cache_key(self) -> Tuple[str, Tuple]:
        """Canonical result-cache key for this plan.

        Because planning dedups terms and sorts them deterministically (by
        ``(t, n, term)``), every surface form of the same conjunction —
        ``[a, b]``, ``[b, a]``, ``[a, a, b]`` — normalizes to the same
        ``terms`` tuple, so one cache entry serves them all.  The routing
        algorithm is part of the key: host and device paths return
        identical values, but keying on it keeps an entry from outliving a
        routing change (e.g. a device attaching between requests).
        """
        return (self.algorithm, self.terms)


def plan_query(
    index: Mapping,
    terms: Sequence,
    hashbin_ratio: float = 100.0,
    device: bool = True,
    mesh_shards: int = 1,
    shard_min_g: int = SHARD_MIN_G,
    capacity_model=None,
    mesh_replicas: int = 1,
) -> QueryPlan:
    """Plan one query against ``index`` (term -> set with .t/.gmax/.n).

    Pure metadata work — touches no arrays, runs no device code, and
    increments no ``EXEC_COUNTERS``.  For device-routed plans the returned
    ``sig.gmaxes`` are power-of-two tiers (``gmax_tier``) and
    ``sig.capacity_tier`` is ``default_capacity(ts)``, so the signature
    matches the static shapes the executor will stack into ``(B, …)``
    arrays exactly.  With ``mesh_shards > 1`` (and/or ``mesh_replicas >
    1``, the data-parallel width of a 2-D topology), huge-G queries
    (``2^t_k >= shard_min_g``) whose smallest set splits evenly over the
    z axis get ``sig.shards = mesh_shards`` / ``sig.replicas =
    mesh_replicas`` and execute on the mesh.

    With a ``capacity_model`` (``exec.adaptive.CapacityModel``) attached,
    ``capacity_tier`` is the model's learned tier for the signature's
    adaptive key — the telemetry-sized survivor buffer — falling back to
    the static ``default_capacity`` rule while the signature is cold.
    Consulting the model stays pure metadata work (a dict lookup under the
    model's lock).
    """
    uniq = []
    seen = set()
    for term in terms:
        if term in seen:
            continue
        seen.add(term)
        uniq.append(term)
    if not uniq or any(t not in index for t in uniq):
        return QueryPlan(terms=tuple(uniq), algorithm="empty")
    # the shared (t, n) set ordering, with the term itself as a final
    # tie-break so equal-(t, n) sets still order deterministically
    uniq.sort(key=lambda t: (*set_sort_key(index[t]), t))
    ns = [index[t].n for t in uniq]
    if len(uniq) == 2 and max(ns) / max(1, min(ns)) > hashbin_ratio:
        return QueryPlan(terms=tuple(uniq), algorithm="hashbin")
    if not device:
        return QueryPlan(terms=tuple(uniq), algorithm="host")
    ts = tuple(index[t].t for t in uniq)
    gmaxes = tuple(gmax_tier(index[t].gmax) for t in uniq)
    shards, replicas = 1, 1
    if ((mesh_shards > 1 or mesh_replicas > 1)
            and (1 << ts[-1]) >= shard_min_g
            and (1 << ts[0]) % mesh_shards == 0):
        shards, replicas = mesh_shards, mesh_replicas
    capacity = default_capacity(ts)
    if capacity_model is not None:
        from .adaptive import adaptive_key_parts

        capacity = capacity_model.capacity_for(
            adaptive_key_parts(len(uniq), ts, gmaxes, shards,
                               replicas=replicas), capacity)
    sig = ShapeSig(
        k=len(uniq), ts=ts, gmaxes=gmaxes,
        capacity_tier=capacity, shards=shards, replicas=replicas,
    )
    return QueryPlan(terms=tuple(uniq), algorithm="device", sig=sig)
