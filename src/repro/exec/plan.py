"""Query planner: normalize a raw query into a shape-keyed QueryPlan.

A query arrives as a bag of terms.  Planning does, in order:

  1. **Normalize** — drop duplicate terms (``[t, t]`` is ``[t]``), resolve
     terms against the index, and sort the survivors by ``(t, n, term)`` so
     prefix alignment (ascending t) and the base-set choice (smallest set
     first) are deterministic across the host and device paths.
  2. **Algorithm selection** — the paper's §3.4 online policy: two sets with
     an extreme size ratio go to HashBin (per-bin binary search beats the
     group machinery when n2/n1 is large); everything else runs
     RanGroupScan, on the device when one is attached.
  3. **Shape signature** — device-bound plans are keyed by
     ``ShapeSig(k, ts, gmaxes, capacity_tier)``.  Two queries with the same
     signature stack into the same ``(B, …)`` arrays and share one compiled
     executable; real logs concentrate on a handful of signatures (68% of
     queries are 2-word, 23% 3-word — §4), which is what makes bucketed
     compilation pay.

The planner only reads cheap per-set metadata (``t``, ``gmax``, ``n``), so
it works identically over host ``PrefixIndex`` objects and device
``DeviceSet`` mirrors.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from ..core.engine import default_capacity, gmax_tier

__all__ = ["ShapeSig", "QueryPlan", "plan_query"]


@dataclasses.dataclass(frozen=True)
class ShapeSig:
    """Static shape signature of a device execution — the jit cache key."""

    k: int
    ts: Tuple[int, ...]
    gmaxes: Tuple[int, ...]
    capacity_tier: int


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A normalized, routed query.

    ``terms`` are deduped and (t, n, term)-sorted; ``algorithm`` is one of
    ``"device"`` (bucketed batch path), ``"hashbin"`` / ``"host"`` (host
    execution), or ``"empty"`` (a term has no postings — result is ∅).
    ``sig`` is set iff ``algorithm == "device"``.
    """

    terms: Tuple
    algorithm: str
    sig: Optional[ShapeSig] = None

    def cache_key(self) -> Tuple[str, Tuple]:
        """Canonical result-cache key for this plan.

        Because planning dedups terms and sorts them deterministically (by
        ``(t, n, term)``), every surface form of the same conjunction —
        ``[a, b]``, ``[b, a]``, ``[a, a, b]`` — normalizes to the same
        ``terms`` tuple, so one cache entry serves them all.  The routing
        algorithm is part of the key: host and device paths return
        identical values, but keying on it keeps an entry from outliving a
        routing change (e.g. a device attaching between requests).
        """
        return (self.algorithm, self.terms)


def plan_query(
    index: Mapping,
    terms: Sequence,
    hashbin_ratio: float = 100.0,
    device: bool = True,
) -> QueryPlan:
    """Plan one query against ``index`` (term -> set with .t/.gmax/.n).

    Pure metadata work — touches no arrays, runs no device code, and
    increments no ``EXEC_COUNTERS``.  For device-routed plans the returned
    ``sig.gmaxes`` are power-of-two tiers (``gmax_tier``) and
    ``sig.capacity_tier`` is ``default_capacity(ts)``, so the signature
    matches the static shapes the executor will stack into ``(B, …)``
    arrays exactly.
    """
    uniq = []
    seen = set()
    for term in terms:
        if term in seen:
            continue
        seen.add(term)
        uniq.append(term)
    if not uniq or any(t not in index for t in uniq):
        return QueryPlan(terms=tuple(uniq), algorithm="empty")
    uniq.sort(key=lambda t: (index[t].t, index[t].n, t))
    ns = [index[t].n for t in uniq]
    if len(uniq) == 2 and max(ns) / max(1, min(ns)) > hashbin_ratio:
        return QueryPlan(terms=tuple(uniq), algorithm="hashbin")
    if not device:
        return QueryPlan(terms=tuple(uniq), algorithm="host")
    ts = tuple(index[t].t for t in uniq)
    gmaxes = tuple(gmax_tier(index[t].gmax) for t in uniq)
    sig = ShapeSig(
        k=len(uniq), ts=ts, gmaxes=gmaxes, capacity_tier=default_capacity(ts)
    )
    return QueryPlan(terms=tuple(uniq), algorithm="device", sig=sig)
