"""Bucketed batch executor: group QueryPlans by shape signature, stack their
DeviceSet rows into (B, …) arrays, and run each bucket in ONE jit execution.

The contract with the planner: every plan in a bucket shares
``ShapeSig(k, ts, gmaxes, capacity_tier, shards, replicas)``, so the
stacked arrays are shape-uniform and the whole bucket hits a single
compiled executable (``core.engine._intersect_k_batch``, its z-sharded
twin ``_intersect_k_sharded_batch`` when ``sig.shards > 1`` on a 1-D
mesh, or the 2-D ``_intersect_k_mesh2d_batch`` when a topology is
attached and the signature is mesh-routed).  Queries whose survivor count
exceeds the capacity tier raise per-query overflow flags; the engine
re-runs just the overflowing subset once at full capacity — a second
(rare) jit execution, not a recompile of the bucket.

With a 2-D topology, single-device buckets additionally get *placed*: the
executor asks the topology's :class:`~repro.exec.topology.ReplicaBalancer`
for the least-loaded replica row and resolves the bucket's sets against
that row's plain mirrors, so small-query traffic spreads across the
data-parallel axis instead of serializing on device 0.  Placement is not
part of the signature — the same bucket may run on any replica.

Per-query timing is amortized: each result's stats carry ``batch_us`` (the
bucket wall time divided by bucket size), which is the honest per-query
cost under heavy traffic.

Asynchronous dispatch: :func:`dispatch_bucket` is the non-blocking half of
:func:`execute_bucket` — it issues the bucket's first jit pass (routing,
balancer placement, lazy-mirror resolution) and returns an
:class:`InFlightBucket` whose :meth:`~InFlightBucket.collect` blocks for
the transfer, runs overflow re-runs, releases the balancer, and feeds the
capacity model.  JAX's async dispatch means the device computes while the
handle is held, so a caller that dispatches several buckets before
collecting overlaps them — across replica rows, and host post-processing
against device compute.  The module tracks the overlap in
``EXEC_COUNTERS``: ``inflight_dispatches`` per dispatched bucket,
``inflight_collects`` per one-shot teardown (collect completion or
failure — after a drain the two match, the no-lost-bucket invariant),
``overlap_high_water`` (max simultaneous in-flight buckets), and
``collect_us`` (cumulative blocking-collect time).
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import (
    Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple,
)

import numpy as np

from ..core.engine import (
    EXEC_COUNTERS, SHARD_AXIS, DeviceSet, PendingBatch,
    default_capacity_per_shard, default_expr_capacity_per_shard,
    dispatch_count_batch, dispatch_count_mesh2d_batch,
    dispatch_count_sharded_batch, dispatch_device_batch, dispatch_expr_batch,
    dispatch_expr_mesh2d_batch, dispatch_expr_sharded_batch,
    dispatch_mesh2d_batch, dispatch_sharded_batch, expr_total_width,
)
from ..obs.profile import sig_label
from .expr import subexpr_keys
from .plan import QueryPlan, ShapeSig, plan_query

__all__ = [
    "bucket_plans",
    "InFlightBucket",
    "dispatch_bucket",
    "execute_bucket",
    "execute_plan_buckets",
    "execute_name_queries",
]

# process-global in-flight gauge behind overlap_high_water: dispatch_bucket
# increments, InFlightBucket.collect decrements, and the high-water mark
# lands in EXEC_COUNTERS (counters themselves stay unlocked/approximate;
# the gauge gets a lock because overlap accounting is the one telemetry
# tests assert exactly across threads)
_inflight_lock = threading.Lock()
_inflight_now = 0


def _inflight_enter() -> None:
    global _inflight_now
    with _inflight_lock:
        _inflight_now += 1
        if _inflight_now > EXEC_COUNTERS["overlap_high_water"]:
            EXEC_COUNTERS["overlap_high_water"] = _inflight_now


def _inflight_exit() -> None:
    global _inflight_now
    with _inflight_lock:
        _inflight_now = max(0, _inflight_now - 1)


def bucket_plans(
    indexed_plans: Iterable[Tuple[int, QueryPlan]],
) -> Dict[ShapeSig, List[Tuple[int, QueryPlan]]]:
    """Group (query_index, plan) pairs by shape signature (insertion order).

    Accepts device plans only (asserts); pure bookkeeping, no counters.
    Each returned bucket is shape-uniform: stacking its rows yields
    ``(B, 2^t_i, …)`` arrays ready for one jit execution.
    """
    buckets: Dict[ShapeSig, List[Tuple[int, QueryPlan]]] = defaultdict(list)
    for qi, plan in indexed_plans:
        assert plan.algorithm == "device" and plan.sig is not None, (
            "only device plans can be bucketed"
        )
        buckets[plan.sig].append((qi, plan))
    return dict(buckets)


class InFlightBucket:
    """Handle for one dispatched-but-not-collected bucket.

    Created by :func:`dispatch_bucket`; holds the pipeline's
    :class:`~repro.core.engine.PendingBatch`, the bucket bookkeeping
    (items, signature, balancer placement), and finishes the job in
    :meth:`collect`.  The split is what lets a serving loop keep several
    buckets on the device at once: dispatch is cheap host work (routing +
    jit call issue), collect is where the blocking transfer lives.

    Balancer accounting: a balancer-placed bucket holds its replica's
    in-flight weight from dispatch until :meth:`collect` — so
    ``ReplicaBalancer.load_snapshot()["in_flight"]`` reflects work that is
    *actually on the device*, and least-loaded routing of the next
    dispatch sees it.  Release happens exactly once, even when collect
    raises.

    :meth:`collect` is idempotent (memoized) and thread-safe against
    double-release, but is meant to be called by one owner; ``is_ready()``
    is safe to poll from anywhere.
    """

    def __init__(self, sig: ShapeSig, items: Sequence[Tuple[int, QueryPlan]],
                 pending: PendingBatch, dispatched_at: float,
                 capacity_model=None, topology=None,
                 replica: Optional[int] = None, weight: float = 0.0,
                 obs=None):
        self.sig = sig
        self.items = list(items)
        self.pending = pending
        self.dispatched_at = dispatched_at
        self.dispatch_end_at = time.perf_counter()
        self.capacity_model = capacity_model
        self.topology = topology
        self.replica = replica
        self.weight = weight
        self.obs = obs
        self.span = None
        self._out: Optional[Dict[int, Tuple[np.ndarray, Dict]]] = None
        self._finished = False
        if obs is not None:
            obs.inflight.inc()
            obs.inflight_high_water.set(obs.inflight.value)
            if obs.tracer.enabled:
                # bucket root span, backdated to dispatch start; the
                # dispatch stage is already over, recorded retroactively
                self.span = obs.tracer.start(
                    "bucket", start_us=dispatched_at * 1e6,
                    sig=sig_label(sig), batch=len(self.items),
                    replica=replica)
                obs.tracer.span_at(
                    "dispatch", dispatched_at * 1e6,
                    self.dispatch_end_at * 1e6, parent=self.span)

    def is_ready(self) -> bool:
        """Non-blocking readiness peek: True when the first pass's device
        buffers have materialized (collect would only pay host work and
        any rare overflow re-run)."""
        return self.pending.is_ready()

    def _finish(self, failed: bool = False) -> None:
        """One-shot teardown: return the balancer weight and leave the
        in-flight gauge.  Runs on first collect completion OR failure.
        ``failed=True`` (dispatch/collect raised) additionally leaves the
        failure trace: balancer row ``failures``, the
        ``dispatch_failures`` counter in both telemetry worlds, and an
        ``error``-flagged bucket span."""
        if self._finished:
            return
        self._finished = True
        if self.replica is not None and self.topology is not None:
            self.topology.balancer.release(self.replica, self.weight,
                                           failed=failed)
        EXEC_COUNTERS["inflight_collects"] += 1
        if failed:
            EXEC_COUNTERS.bump("dispatch_failures")
        _inflight_exit()
        if self.obs is not None:
            self.obs.inflight.dec()
            if failed:
                self.obs.dispatch_failures.inc()
                if self.span is not None:
                    self.span.end(error=True)

    def collect(self) -> Dict[int, Tuple[np.ndarray, Dict]]:
        """Block for the bucket's results; returns {query_index: (values,
        stats)} exactly as :func:`execute_bucket` does.

        Performs the deferred ``jax.device_get``, the overflow re-run
        passes, balancer release, ``batch_us`` stamping (dispatch-to-
        collect wall over bucket size), and the capacity-model feedback.
        Needs no executor lock: re-runs resolve against the DeviceSet rows
        captured at dispatch (no lazy-mirror mutation), the balancer and
        the capacity model are internally locked.  Adds the blocking time
        to ``EXEC_COUNTERS["collect_us"]``.

        With ``obs`` attached: observes the dispatch→collect latency,
        batch-size, and per-row survivor histograms, feeds the per-
        signature :class:`~repro.obs.profile.ProfileStore`, and closes
        the bucket span (retroactive ``device`` + ``collect`` children).
        """
        if self._out is not None:
            return self._out
        c0 = time.perf_counter()
        try:
            results = self.pending.collect()
        except BaseException:
            self._finish(failed=True)
            raise
        else:
            self._finish()
        c1 = time.perf_counter()
        EXEC_COUNTERS["collect_us"] += int((c1 - c0) * 1e6)
        us = (c1 - self.dispatched_at) * 1e6
        out: Dict[int, Tuple[np.ndarray, Dict]] = {}
        for (qi, _), (values, stats) in zip(self.items, results):
            stats["batch_us"] = us / len(self.items)
            if self.replica is not None:
                stats["replica"] = self.replica
            out[qi] = (values, stats)
        if self.capacity_model is not None:
            self.capacity_model.observe_bucket(
                self.sig, [stats for _, stats in out.values()])
        if self.obs is not None:
            self.obs.collect_latency.observe(us)
            self.obs.batch_size.observe(len(self.items))
            for _, stats in out.values():
                if "r" in stats:
                    self.obs.survivors.observe(stats["r"])
            self.obs.profile.observe(self.sig, len(self.items), us)
            if self.span is not None:
                # device stage = dispatch issued -> collect entered (the
                # window jax's async dispatch computes under)
                self.obs.tracer.span_at(
                    "device", self.dispatch_end_at * 1e6, c0 * 1e6,
                    parent=self.span)
                self.obs.tracer.span_at(
                    "collect", c0 * 1e6, c1 * 1e6, parent=self.span)
                self.span.end()
        self._out = out
        return out


def dispatch_bucket(
    get_set: Callable[[object], DeviceSet],
    sig: ShapeSig,
    items: Sequence[Tuple[int, QueryPlan]],
    use_pallas="auto",
    mesh=None,
    shard_axis: str = SHARD_AXIS,
    get_sharded_set: Optional[Callable[[object], DeviceSet]] = None,
    capacity_model=None,
    topology=None,
    get_replica_set: Optional[Callable[[int, object], DeviceSet]] = None,
    obs=None,
) -> InFlightBucket:
    """Dispatch ONE same-signature bucket without blocking; returns an
    :class:`InFlightBucket` whose :meth:`~InFlightBucket.collect` yields
    {query_index: (values, stats)}.

    Routing is identical to :func:`execute_bucket` (which is now just
    ``dispatch_bucket(...).collect()``): 2-D topology-routed signatures go
    through ``dispatch_mesh2d_batch``, ``shards > 1`` through
    ``dispatch_sharded_batch`` on ``mesh``, and single-device buckets on a
    multi-replica topology are placed on the least-loaded replica row by
    the balancer — whose weight is now held until collect, so overlapping
    dispatches see each other's in-flight load.

    Caller contract: dispatch resolves terms through ``get_set`` /
    ``get_sharded_set`` / ``get_replica_set``, which on the engines build
    lazy per-row mirrors — serialize *dispatches* (the serving layer holds
    its exec lock here) but collect freely outside any lock.

    Counters: ``inflight_dispatches`` per bucket; ``overlap_high_water``
    tracks the max simultaneously dispatched-not-collected buckets;
    ``replica_dispatches`` per balancer placement; the per-pass pipeline
    counters are unchanged.  A dispatch that raises (any branch) bumps
    ``dispatch_failures`` once — balancer branches additionally mark the
    row's failure via ``release(..., failed=True)``.

    ``obs``: an optional :class:`repro.obs.Obs`.  When given, the bucket
    reports through it — in-flight gauge + high-water, dispatch→collect
    latency / batch-size / survivor histograms, the per-signature profile
    store, and (tracer enabled) a ``bucket`` span with retroactive
    ``dispatch`` / ``device`` / ``collect`` children.  ``None`` keeps the
    executor layer decoupled: only ``EXEC_COUNTERS`` is touched.
    """
    try:
        return _dispatch_bucket(
            get_set, sig, items, use_pallas=use_pallas, mesh=mesh,
            shard_axis=shard_axis, get_sharded_set=get_sharded_set,
            capacity_model=capacity_model, topology=topology,
            get_replica_set=get_replica_set, obs=obs,
        )
    except BaseException:
        EXEC_COUNTERS.bump("dispatch_failures")
        if obs is not None:
            obs.dispatch_failures.inc()
        raise


def _dispatch_bucket(
    get_set: Callable[[object], DeviceSet],
    sig: ShapeSig,
    items: Sequence[Tuple[int, QueryPlan]],
    use_pallas="auto",
    mesh=None,
    shard_axis: str = SHARD_AXIS,
    get_sharded_set: Optional[Callable[[object], DeviceSet]] = None,
    capacity_model=None,
    topology=None,
    get_replica_set: Optional[Callable[[int, object], DeviceSet]] = None,
    obs=None,
) -> InFlightBucket:
    shards = getattr(sig, "shards", 1)
    replicas = getattr(sig, "replicas", 1)
    t0 = time.perf_counter()
    replica: Optional[int] = None
    weight = 0.0
    eshape = getattr(sig, "eshape", None)
    if eshape is not None:
        # expression DAG bucket: same routing tree, expression executables.
        # Rows resolve in the plan's canonical traversal order (plan.terms
        # IS that order — never re-sorted), and each query ships its
        # canonical subexpression keys so collect can hand intermediate
        # node results to the subexpression cache.
        sub_keys = {qi: subexpr_keys(plan.expr) for qi, plan in items}
        queries = [[t for t in plan.terms] for _, plan in items]
        if topology is not None and (shards > 1 or replicas > 1):
            assert get_sharded_set is not None, (
                "2-D expression buckets resolve through the engine's "
                "ReplicatedDeviceSet mirrors (get_sharded_set)"
            )
            rows = [[get_sharded_set(t) for t in q] for q in queries]
            pending = dispatch_expr_mesh2d_batch(
                rows, eshape, topology,
                capacity_per_shard=default_expr_capacity_per_shard(
                    sig.ts, sig.gmaxes, shards, capacity=sig.capacity_tier),
                sub_keys=[sub_keys[qi] for qi, _ in items],
            )
        elif shards > 1:
            assert mesh is not None, "sharded bucket needs the engine's mesh"
            resolve = get_sharded_set or get_set
            rows = [[resolve(t) for t in q] for q in queries]
            pending = dispatch_expr_sharded_batch(
                rows, eshape, mesh, axis=shard_axis,
                capacity_per_shard=default_expr_capacity_per_shard(
                    sig.ts, sig.gmaxes, shards, capacity=sig.capacity_tier),
                sub_keys=[sub_keys[qi] for qi, _ in items],
            )
        elif (topology is not None and topology.replicas > 1
              and get_replica_set is not None):
            # balancer cost: the DAG's dense row width per query (the
            # analogue of the flat bucket's B * G phase-1 rows)
            weight = float(len(items) * expr_total_width(sig.ts, sig.gmaxes))
            replica = topology.balancer.acquire(weight)
            try:
                rows = [[get_replica_set(replica, t) for t in q]
                        for q in queries]
                pending = dispatch_expr_batch(
                    rows, eshape, capacity=sig.capacity_tier,
                    sub_keys=[sub_keys[qi] for qi, _ in items],
                )
            except BaseException:
                topology.balancer.release(replica, weight, failed=True)
                raise
            EXEC_COUNTERS["replica_dispatches"] += 1
        else:
            rows = [[get_set(t) for t in q] for q in queries]
            pending = dispatch_expr_batch(
                rows, eshape, capacity=sig.capacity_tier,
                sub_keys=[sub_keys[qi] for qi, _ in items],
            )
        EXEC_COUNTERS["inflight_dispatches"] += 1
        _inflight_enter()
        return InFlightBucket(
            sig, items, pending, t0, capacity_model=capacity_model,
            topology=topology, replica=replica, weight=weight, obs=obs,
        )
    cands = getattr(sig, "cands", 0)
    if cands > 0:
        # count-only (suggest) bucket: plan.terms is (probe, *candidates)
        # in tie-break order (candidates ascending), sig.capacity_tier is
        # the top-K selection tier.  Same routing tree as the point path,
        # but the dispatches are single-pass — no overflow re-run exists.
        k = sig.capacity_tier
        if topology is not None and (shards > 1 or replicas > 1):
            assert get_sharded_set is not None, (
                "2-D count buckets resolve through the engine's "
                "ReplicatedDeviceSet mirrors (get_sharded_set)"
            )
            rows = [(get_sharded_set(plan.terms[0]),
                     [get_sharded_set(t) for t in plan.terms[1:]])
                    for _, plan in items]
            pending = dispatch_count_mesh2d_batch(
                rows, k, topology, use_pallas=use_pallas)
        elif shards > 1:
            assert mesh is not None, "sharded bucket needs the engine's mesh"
            resolve = get_sharded_set or get_set
            rows = [(resolve(plan.terms[0]),
                     [resolve(t) for t in plan.terms[1:]])
                    for _, plan in items]
            pending = dispatch_count_sharded_batch(
                rows, k, mesh, axis=shard_axis, use_pallas=use_pallas)
        elif (topology is not None and topology.replicas > 1
              and get_replica_set is not None):
            # balancer cost: B * C * G count-matrix cells (the count path's
            # analogue of the flat bucket's B * G phase-1 rows)
            weight = float(len(items) * cands * (1 << max(sig.ts)))
            replica = topology.balancer.acquire(weight)
            try:
                rows = [(get_replica_set(replica, plan.terms[0]),
                         [get_replica_set(replica, t)
                          for t in plan.terms[1:]])
                        for _, plan in items]
                pending = dispatch_count_batch(
                    rows, k, use_pallas=use_pallas)
            except BaseException:
                topology.balancer.release(replica, weight, failed=True)
                raise
            EXEC_COUNTERS["replica_dispatches"] += 1
        else:
            rows = [(get_set(plan.terms[0]),
                     [get_set(t) for t in plan.terms[1:]])
                    for _, plan in items]
            pending = dispatch_count_batch(rows, k, use_pallas=use_pallas)
        EXEC_COUNTERS["inflight_dispatches"] += 1
        _inflight_enter()
        return InFlightBucket(
            sig, items, pending, t0, capacity_model=capacity_model,
            topology=topology, replica=replica, weight=weight, obs=obs,
        )
    if topology is not None and (shards > 1 or replicas > 1):
        assert get_sharded_set is not None, (
            "2-D buckets resolve through the engine's ReplicatedDeviceSet "
            "mirrors (get_sharded_set)"
        )
        resolve = get_sharded_set
        rows = [[resolve(t) for t in plan.terms] for _, plan in items]
        pending = dispatch_mesh2d_batch(
            rows, topology,
            capacity_per_shard=default_capacity_per_shard(
                sig.ts, shards, capacity=sig.capacity_tier),
            use_pallas=use_pallas,
        )
    elif shards > 1:
        assert mesh is not None, "sharded bucket needs the engine's mesh"
        resolve = get_sharded_set or get_set
        rows = [[resolve(t) for t in plan.terms] for _, plan in items]
        pending = dispatch_sharded_batch(
            rows, mesh, axis=shard_axis,
            capacity_per_shard=default_capacity_per_shard(
                sig.ts, shards, capacity=sig.capacity_tier),
            use_pallas=use_pallas,
        )
    elif (topology is not None and topology.replicas > 1
          and get_replica_set is not None):
        weight = float(len(items) * (1 << sig.ts[-1]))  # B * G rows
        replica = topology.balancer.acquire(weight)
        try:
            rows = [[get_replica_set(replica, t) for t in plan.terms]
                    for _, plan in items]
            pending = dispatch_device_batch(
                rows, capacity=sig.capacity_tier, use_pallas=use_pallas
            )
        except BaseException:
            # dispatch itself failed — there is no collect to release at
            topology.balancer.release(replica, weight, failed=True)
            raise
        EXEC_COUNTERS["replica_dispatches"] += 1
    else:
        rows = [[get_set(t) for t in plan.terms] for _, plan in items]
        pending = dispatch_device_batch(
            rows, capacity=sig.capacity_tier, use_pallas=use_pallas
        )
    EXEC_COUNTERS["inflight_dispatches"] += 1
    _inflight_enter()
    return InFlightBucket(
        sig, items, pending, t0, capacity_model=capacity_model,
        topology=topology, replica=replica, weight=weight, obs=obs,
    )


def execute_bucket(
    get_set: Callable[[object], DeviceSet],
    sig: ShapeSig,
    items: Sequence[Tuple[int, QueryPlan]],
    use_pallas="auto",
    mesh=None,
    shard_axis: str = SHARD_AXIS,
    get_sharded_set: Optional[Callable[[object], DeviceSet]] = None,
    capacity_model=None,
    topology=None,
    get_replica_set: Optional[Callable[[int, object], DeviceSet]] = None,
    obs=None,
) -> Dict[int, Tuple[np.ndarray, Dict]]:
    """Execute ONE same-signature bucket; returns {query_index: (values,
    stats)}.

    This is the partial-bucket flush path: the admission queue calls it
    directly with however many queries have accumulated under ``sig`` when
    a flush fires (full power-of-two tier reached, or the oldest query's
    deadline expired) — the executor pads B up to the next power-of-two
    tier, so a partial bucket reuses the same small family of compiled
    executables as a full one.  ``get_set`` resolves a planned term to its
    DeviceSet.

    Buckets whose signature carries ``shards > 1`` run through the
    z-sharded pipeline on ``mesh`` (required then), resolving terms via
    ``get_sharded_set`` (the engine's z-sharded mirrors; falls back to
    ``get_set``, at a per-call reshard cost).  The per-shard capacity is
    derived deterministically from the signature
    (``default_capacity_per_shard``), so ``(sig, B-tier)`` fully keys the
    sharded executable too.

    With a 2-D ``topology`` attached, mesh-routed signatures
    (``shards > 1`` or ``replicas > 1``) run through the 2-D pipeline on
    ``topology.mesh`` (same mirrors, same per-shard capacity derivation),
    and single-device buckets are dispatched to the least-loaded replica
    row: the balancer is asked with the bucket's estimated cost (``B *
    G``, the phase-1 row count), terms resolve via
    ``get_replica_set(replica, term)``, the in-flight load is released
    when the bucket completes, and each result's stats carry the executing
    ``replica``.  One ``EXEC_COUNTERS["replica_dispatches"]`` bump per
    balancer-dispatched bucket.

    Shapes: every plan in ``items`` must carry ``sig`` (the executor
    asserts signature uniformity); the bucket runs as one ``(B, …)`` jit
    execution plus a rare overflow re-run.  Counters: one
    ``EXEC_COUNTERS["batch_calls"]`` (or ``"sharded_calls"``) bump per pass
    (see ``core.engine``); each result's stats carry ``batch_us`` — bucket
    wall time divided by bucket size, the honest amortized per-query cost.

    ``sig.capacity_tier`` sizes the survivor buffer on both paths (the
    sharded per-shard buffer is derived from it via
    ``default_capacity_per_shard``), so a planner consulting a learned
    capacity model changes the executed shapes through the signature alone.
    With a ``capacity_model`` attached, the bucket's per-query survivor
    stats are fed back to it after execution — the telemetry loop the model
    learns from.

    The synchronous composition of :func:`dispatch_bucket` +
    :meth:`InFlightBucket.collect` — callers that can overlap buckets use
    the two halves directly.
    """
    return dispatch_bucket(
        get_set, sig, items, use_pallas=use_pallas, mesh=mesh,
        shard_axis=shard_axis, get_sharded_set=get_sharded_set,
        capacity_model=capacity_model, topology=topology,
        get_replica_set=get_replica_set, obs=obs,
    ).collect()


def execute_plan_buckets(
    get_set: Callable[[object], DeviceSet],
    indexed_plans: Iterable[Tuple[int, QueryPlan]],
    use_pallas="auto",
    mesh=None,
    shard_axis: str = SHARD_AXIS,
    get_sharded_set: Optional[Callable[[object], DeviceSet]] = None,
    capacity_model=None,
    topology=None,
    get_replica_set: Optional[Callable[[int, object], DeviceSet]] = None,
    max_inflight: int = 4,
    obs=None,
) -> Dict[int, Tuple[np.ndarray, Dict]]:
    """Execute device plans bucket-by-bucket; returns {query_index: (values,
    stats)}.

    Synchronous whole-batch entry: groups ``indexed_plans`` by shape
    signature and pipelines the buckets through :func:`dispatch_bucket` /
    :meth:`InFlightBucket.collect` with a bounded in-flight window — one
    jit execution per distinct signature (plus rare overflow re-runs),
    i.e. O(#signatures) device dispatches for the whole batch, with up to
    ``max_inflight`` buckets overlapped on the device (distinct-signature
    buckets are independent; on a multi-replica topology they also land on
    different rows).  All results are collected before returning, so the
    call is externally synchronous.  ``get_set`` resolves a planned term
    to its DeviceSet; sharded-signature buckets resolve via
    ``get_sharded_set`` and run on ``mesh`` (or on ``topology.mesh`` when
    a 2-D topology is attached, which also spreads single-device buckets
    over the replicas via ``get_replica_set``).
    """
    out: Dict[int, Tuple[np.ndarray, Dict]] = {}
    window: List[InFlightBucket] = []
    for sig, items in bucket_plans(indexed_plans).items():
        window.append(dispatch_bucket(
            get_set, sig, items, use_pallas=use_pallas, mesh=mesh,
            shard_axis=shard_axis, get_sharded_set=get_sharded_set,
            capacity_model=capacity_model, topology=topology,
            get_replica_set=get_replica_set, obs=obs,
        ))
        if len(window) >= max(1, max_inflight):
            out.update(window.pop(0).collect())
    for bucket in window:
        out.update(bucket.collect())
    return out


def execute_name_queries(
    sets: Mapping[str, DeviceSet],
    queries: Sequence[Sequence[str]],
    use_pallas="auto",
    mesh=None,
    shard_axis: str = SHARD_AXIS,
    shard_min_g: Optional[int] = None,
    sharded_sets: Optional[Mapping[str, DeviceSet]] = None,
    topology=None,
    get_sharded_set: Optional[Callable[[object], DeviceSet]] = None,
    get_replica_set: Optional[Callable[[int, object], DeviceSet]] = None,
) -> List[Tuple[np.ndarray, Dict]]:
    """BatchedEngine.query_many backend: plan -> bucket -> execute -> scatter.

    ``queries`` are lists of set names; unknown names raise KeyError (same
    contract as single-query ``BatchedEngine.query``).  Duplicate names
    within a query are deduped by the planner.  Results return in request
    order regardless of bucketing.  With a ``mesh``, huge-G plans route
    z-sharded per the planner's ``shard_min_g`` threshold, resolving
    mirrors via ``get_sharded_set`` (or a plain ``sharded_sets`` mapping);
    with a 2-D ``topology`` they route to the 2-D pipeline (the engine's
    lazy ``get_mesh_set`` / ``get_replica_set`` builders — a raw mapping
    won't do there, mirrors materialize on first dispatch) and
    single-device buckets spread over the replicas.  Counters: one
    ``batch_calls`` / ``sharded_calls`` / ``mesh2d_calls`` per distinct
    signature (plus ``*rerun_calls`` on overflow) via
    :func:`execute_bucket`.
    """
    for q in queries:
        for name in q:
            if name not in sets:
                raise KeyError(name)
    if topology is not None:
        mesh_shards, mesh_replicas = topology.shards, topology.replicas
    else:
        mesh_shards = mesh.shape[shard_axis] if mesh is not None else 1
        mesh_replicas = 1
    plan_kw = {} if shard_min_g is None else {"shard_min_g": shard_min_g}
    plans = [
        plan_query(sets, q, hashbin_ratio=float("inf"), device=True,
                   mesh_shards=mesh_shards, mesh_replicas=mesh_replicas,
                   **plan_kw)
        for q in queries
    ]
    # no sharded mirrors supplied -> let execute_bucket fall back to the
    # plain mirrors (correct, at a per-call reshard cost)
    if get_sharded_set is None and sharded_sets:
        get_sharded_set = lambda name: sharded_sets[name]
    by_index = execute_plan_buckets(
        lambda name: sets[name],
        [(i, p) for i, p in enumerate(plans) if p.algorithm == "device"],
        use_pallas=use_pallas,
        mesh=mesh,
        shard_axis=shard_axis,
        get_sharded_set=get_sharded_set,
        topology=topology,
        get_replica_set=get_replica_set,
    )
    # fresh objects per miss: callers annotate stats dicts in place
    return [
        by_index[i] if i in by_index else (np.empty(0, np.uint32),
                                           {"r": 0, "batch_size": 0})
        for i in range(len(queries))
    ]
