import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any real arrays:
  * proof the sharding config is coherent (lower().compile() succeeds);
  * per-device memory analysis (argument/temp/output bytes);
  * per-device HLO flops + bytes (cost_analysis);
  * collective bytes by collective type, parsed from the optimized HLO —
    the inputs to the roofline model in benchmarks/roofline.py.

Results are cached as JSON under benchmarks/artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import pathlib
import re
import time
import traceback
from typing import Any, Dict, Optional

ARTIFACTS = (pathlib.Path(__file__).resolve().parents[3]
             / "benchmarks" / "artifacts" / "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^=]*?\)|[a-z0-9\[\],{}/_.-]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of_type(typestr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, default_group: int) -> Dict[str, Any]:
    """Sum per-device wire bytes per collective type (ring estimates):
    all-gather/all-to-all: result bytes; reduce-scatter/permute: result
    bytes; all-reduce: 2x result bytes (reduce-scatter + all-gather)."""
    per_type: Dict[str, float] = {}
    count: Dict[str, int] = {}
    wire_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _bytes_of_type(m.group("rtype"))
        g = _GROUPS_RE.search(line)
        if g:
            gsize = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = int(gi.group(2)) if gi else default_group
        frac = (gsize - 1) / max(1, gsize)
        wire = nbytes * frac * (2.0 if op == "all-reduce" else 1.0)
        per_type[op] = per_type.get(op, 0.0) + wire
        count[op] = count.get(op, 0) + 1
        wire_total += wire
    return {"bytes_by_type": per_type, "count_by_type": count,
            "wire_bytes_per_device": wire_total}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: Optional[bool] = None, remat: bool = True,
             variant: str = "baseline") -> Dict[str, Any]:
    import jax
    from ..configs import get_config, shape_by_name
    from ..models.model import build_model
    from ..optim import adamw
    from ..parallel.sharding import batch_pspecs, shardings_of
    from ..train.step import (
        abstract_params, build_serve_decode, build_train_step,
    )
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "variant": variant,
        "params_B": cfg.param_count() / 1e9,
        "active_params_B": cfg.active_param_count() / 1e9,
    }

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec["status"] = "skip"
        rec["reason"] = ("pure full-attention arch: 524k dense decode is the "
                        "quadratic regime excluded by the shape suite (DESIGN.md §6)")
        return rec

    model = build_model(cfg)
    t0 = time.time()
    from jax.sharding import NamedSharding, PartitionSpec as P
    from .. import tuning
    knobs = tuning.parse(variant)
    rec["tuning"] = knobs

    with mesh, tuning.overrides(**knobs):
        if shape.kind == "train":
            from ..train.step import auto_microbatch
            micro = auto_microbatch(shape.global_batch, shape.seq_len, mesh)
            rec["microbatch"] = micro
            step, (p_specs, o_specs), opt_cfg = build_train_step(
                model, mesh, fsdp=fsdp, microbatch=micro)
            batch_abs = model.batch_spec(shape)
            b_specs = batch_pspecs(batch_abs, mesh)
            p_abs = abstract_params(model)
            o_abs = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), p_abs)
            in_sh = (shardings_of(p_abs, p_specs, mesh),
                     jax.tree_util.tree_map(lambda _, s: NamedSharding(mesh, s),
                                            o_abs, o_specs),
                     shardings_of(batch_abs, b_specs, mesh))
            metrics_sh = {k: NamedSharding(mesh, P()) for k in
                          ("grad_norm", "lr", "loss")}
            out_sh = (in_sh[0], in_sh[1], metrics_sh)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_abs, o_abs, batch_abs)
        elif shape.kind == "prefill":
            from ..train.step import build_serve_prefill
            from ..parallel.sharding import assign_spec, dp_axes
            fn, p_specs = build_serve_prefill(model, mesh)
            p_abs = abstract_params(model)
            batch_abs = model.batch_spec(shape)
            b_specs = batch_pspecs(batch_abs, mesh)
            logits_sh = NamedSharding(mesh, assign_spec(
                (shape.global_batch, cfg.vocab),
                [(dp_axes(mesh), -2), ("model", -1)], mesh))
            jitted = jax.jit(fn,
                             in_shardings=(shardings_of(p_abs, p_specs, mesh),
                                           shardings_of(batch_abs, b_specs, mesh)),
                             out_shardings=logits_sh)
            lowered = jitted.lower(p_abs, batch_abs)
        else:  # decode
            fn, p_specs, c_specs, cache_abs = build_serve_decode(
                model, mesh, shape.global_batch, shape.seq_len)
            p_abs = abstract_params(model)
            batch_abs = model.batch_spec(shape)
            tok_abs, pos_abs = batch_abs["tokens"], batch_abs["pos"]
            from ..parallel.sharding import assign_spec, dp_axes
            tok_spec = batch_pspecs({"tokens": tok_abs}, mesh)["tokens"]
            c_sh = shardings_of(cache_abs, c_specs, mesh)
            logits_sh = NamedSharding(mesh, assign_spec(
                (shape.global_batch, cfg.vocab),
                [(dp_axes(mesh), -2), ("model", -1)], mesh))
            jitted = jax.jit(
                fn,
                in_shardings=(shardings_of(p_abs, p_specs, mesh), c_sh,
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, P())),
                out_shardings=(logits_sh, c_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(p_abs, cache_abs, tok_abs, pos_abs)

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        rec["cost_analysis"] = {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        }
        rec["memory_analysis"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes_est": int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        }
        hlo = compiled.as_text()
        from .hlo_analysis import analyze_hlo
        rec["hlo_analysis"] = analyze_hlo(hlo, default_group=n_dev)
        rec["collectives_static"] = parse_collectives(hlo, default_group=n_dev)
        rec["hlo_lines"] = hlo.count("\n")
        rec["n_devices"] = int(n_dev)
        rec["status"] = "ok"
    return rec


ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=(None, "on", "off"))
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    from ..configs import ARCH_IDS

    archs = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = ALL_SHAPES if args.all or not args.shape else (args.shape,)
    meshes = (False, True) if (args.both_meshes or args.all) else (args.multi_pod,)
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch}__{shape}__{mesh_name}"
                if args.variant != "baseline":
                    safe = args.variant.replace("=", "").replace(";", "_")
                    tag += f"__{safe}"
                out = ARTIFACTS / f"{tag}.json"
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[skip-existing] {tag}")
                        continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, fsdp=fsdp,
                                   variant=args.variant)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                out.write_text(json.dumps(rec, indent=2))
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    ma = rec["memory_analysis"]
                    ha = rec["hlo_analysis"]
                    extra = (f" mem/dev={ma['peak_bytes_est']/2**30:.2f}GiB"
                             f" flops/dev={ha['flops_per_device']:.3g}"
                             f" hbm/dev={ha['hbm_bytes_per_device']:.3g}B"
                             f" wire/dev={ha['wire_bytes_per_device']:.3g}B"
                             f" compile={rec['compile_s']}s")
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
