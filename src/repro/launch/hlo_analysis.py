"""Trip-count-aware static analysis of optimized HLO text.

``compiled.cost_analysis()`` and a naive grep of ``compiled.as_text()`` both
count ops inside ``while`` loops (lax.scan layers, microbatch accumulation,
xent chunks) exactly once.  For a scanned 61-layer model that under-counts
flops and collective bytes by ~60x, which would poison the roofline.

This module parses the optimized HLO module into its computations, walks the
call graph from ENTRY, multiplies through `while` trip counts (recovered
from the loop-condition constant), and accumulates:

  * matmul flops (dot ops: 2 * numel(out) * contraction), trip-aware;
  * HBM traffic model: sum over op *boundaries* (operands + results) of
    non-aliasing ops — fusion internals stay on-chip and are not counted;
  * per-type collective wire bytes (ring estimates: all-reduce counts 2x).

All numbers are per-device (the module is the SPMD partitioned program).
Conditionals contribute the max over branches.  Known approximations are
recorded in the result dict under "notes".
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ARRAY_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64"
    r"|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.$-]+)\s*=\s*(.+?)\s+([\w-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.$-]+)\s+\((.*)\)\s*->")
_PARAM_RE = re.compile(r"([\w.$-]+):\s*((?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))")
_WHILE_RE = re.compile(r"condition=%?([\w.$-]+),\s*body=%?([\w.$-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.$-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(
    r"true_computation=%?([\w.$-]+),\s*false_computation=%?([\w.$-]+)")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS_RE = re.compile(r"%([\w.$-]+)")

_ALIAS_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_numel_bytes(typestr: str) -> Tuple[int, int]:
    total_b = 0
    total_n = 0
    for m in _ARRAY_RE.finditer(typestr):
        numel = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total_n += numel
        total_b += numel * _DTYPE_BYTES[m.group(1)]
    return total_n, total_b


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    symbols: Dict[str, str]  # %name -> type string


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
                # header params
                for pm in _PARAM_RE.finditer(m.group(3)):
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if raw.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(raw)
        dm = _DEF_RE.match(raw)
        if dm:
            cur.symbols[dm.group(1)] = dm.group(2)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond: Computation) -> int:
    consts = [int(m.group(1)) for line in cond.lines
              for m in _CONST_S32_RE.finditer(line)]
    return max(consts) if consts else 1


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, float] = dataclasses.field(default_factory=dict)
    notes: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult
        for n in other.notes:
            if n not in self.notes:
                self.notes.append(n)


class HloAnalyzer:
    def __init__(self, text: str, default_group: int):
        self.comps = parse_computations(text)
        self.default_group = default_group
        self._memo: Dict[Tuple[str, bool], Totals] = {}

    # -------------------------------------------------------- op helpers
    def _dot_flops(self, comp: Computation, line: str, out_type: str) -> float:
        out_n, _ = _shape_numel_bytes(out_type)
        cm = _CONTRACT_RE.search(line)
        contract = 1
        if cm is not None:
            dims = [int(d) for d in cm.group(1).split(",") if d]
            ops = _OPERANDS_RE.findall(line.split("dot(", 1)[1])
            lhs_type = comp.symbols.get(ops[0]) if ops else None
            if lhs_type is None:
                return 2.0 * out_n  # unresolvable operand; undercount, noted
            am = _ARRAY_RE.search(lhs_type)
            if am:
                shape = [int(d) for d in am.group(2).split(",") if d]
                for d in dims:
                    if d < len(shape):
                        contract *= shape[d]
        return 2.0 * out_n * contract

    def _operand_bytes(self, comp: Computation, line: str, op: str) -> float:
        try:
            args = line.split(op + "(", 1)[1]
        except IndexError:
            return 0.0
        args = args.split(")", 1)[0]
        total = 0.0
        for name in _OPERANDS_RE.findall(args):
            t = comp.symbols.get(name)
            if t:
                total += _shape_numel_bytes(t)[1]
        return total

    def _collective(self, line: str, op: str, out_type: str) -> Tuple[str, float]:
        _, nbytes = _shape_numel_bytes(out_type)
        g = _GROUPS_LIST_RE.search(line)
        if g:
            gsize = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = int(gi.group(1)) if gi else self.default_group
        frac = (gsize - 1) / max(1, gsize)
        wire = nbytes * frac * (2.0 if op.startswith("all-reduce") else 1.0)
        return op.replace("-start", ""), wire


    def _sliced_bytes(self, comp: Computation, line: str, op: str,
                      out_type: str) -> float:
        """Traffic model for slice-moving ops (the untouched bulk operand is
        aliased in place by XLA buffer assignment)."""
        _, ob = _shape_numel_bytes(out_type)
        try:
            args = line.split(op + "(", 1)[1].split(")", 1)[0]
        except IndexError:
            return 2.0 * ob
        names = _OPERANDS_RE.findall(args)
        def sz(i):
            t_ = comp.symbols.get(names[i]) if i < len(names) else None
            return _shape_numel_bytes(t_)[1] if t_ else 0.0
        if op == "dynamic-slice":
            return 2.0 * ob                      # read slice + write out
        if op == "dynamic-update-slice":
            return 2.0 * sz(1) + ob * 0.0        # read update + write slice
        if op == "gather":
            return 2.0 * ob + sz(1)              # read rows + indices + write
        # scatter: read updates + indices, write touched rows
        upd = sz(len(names) - 1)
        return 2.0 * upd + sz(1)

    # -------------------------------------------------------- recursion
    def totals(self, comp_name: str = "__entry__",
               flops_only: bool = False) -> Totals:
        key = (comp_name, flops_only)
        if key in self._memo:
            return self._memo[key]
        t = Totals()
        self._memo[key] = t  # break cycles defensively
        comp = self.comps.get(comp_name)
        if comp is None:
            return t
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            _, out_type, op = dm.groups()
            base = op.replace("-start", "").replace("-done", "")
            if op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    trip = _trip_count(
                        self.comps.get(wm.group(1), Computation("", [], {})))
                    body = self.totals(wm.group(2), flops_only)
                    cond = self.totals(wm.group(1), flops_only)
                    t.add(body, trip)
                    t.add(cond, trip)
                    if not flops_only:
                        # loop carry re-materialization is negligible; note it
                        pass
                continue
            if op == "conditional":
                branches: List[str] = []
                bm = _BRANCHES_RE.search(line)
                if bm:
                    branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                else:
                    tf = _TRUE_FALSE_RE.search(line)
                    if tf:
                        branches = [tf.group(1), tf.group(2)]
                if branches:
                    subs = [self.totals(b, flops_only) for b in branches]
                    best = max(subs, key=lambda s: s.flops + s.bytes)
                    t.add(best)
                    t.notes.append("conditional: counted max branch")
                continue
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                ctype, wire = self._collective(line, op, out_type)
                t.coll_bytes[ctype] = t.coll_bytes.get(ctype, 0.0) + wire
                t.coll_count[ctype] = t.coll_count.get(ctype, 0.0) + 1
                if not flops_only:
                    _, ob = _shape_numel_bytes(out_type)
                    t.bytes += ob + self._operand_bytes(comp, line, op)
                continue
            if op == "fusion" or op == "call" or op == "custom-call":
                cm = _CALLS_RE.search(line)
                if cm:
                    # flops inside fused/called computations still execute;
                    # bytes do not cross HBM (fusion boundary counted below)
                    t.add(self.totals(cm.group(1), flops_only=True))
                if not flops_only and op != "custom-call":
                    _, ob = _shape_numel_bytes(out_type)
                    t.bytes += ob + self._operand_bytes(comp, line, op)
                continue
            if op == "dot":
                t.flops += self._dot_flops(comp, line, out_type)
                if not flops_only:
                    _, ob = _shape_numel_bytes(out_type)
                    t.bytes += ob + self._operand_bytes(comp, line, op)
                continue
            if op in ("dynamic-slice", "dynamic-update-slice", "gather",
                      "scatter"):
                # XLA aliases the big operand in place; real traffic is the
                # moved slice/updates (+ indices), not the whole buffer.
                if not flops_only:
                    t.bytes += self._sliced_bytes(comp, line, op, out_type)
                continue
            if op in ("reduce", "reduce-window", "sort", "scatter", "gather",
                      "dynamic-slice", "dynamic-update-slice", "copy",
                      "convert", "broadcast", "iota", "reshape", "transpose",
                      "concatenate", "slice", "pad", "select", "compare",
                      "add", "multiply", "subtract", "divide", "exponential",
                      "rsqrt", "tanh", "maximum", "minimum", "convolution",
                      "select-and-scatter", "clamp", "reverse", "map",
                      "reduce-precision", "rng", "rng-bit-generator",
                      "cholesky", "triangular-solve", "and", "or", "xor",
                      "shift-left", "shift-right-logical", "negate", "abs",
                      "sign", "floor", "ceil", "log", "log-plus-one", "power",
                      "remainder", "atan2", "is-finite", "not", "sine",
                      "cosine", "sqrt", "cbrt", "round-nearest-afz",
                      "stochastic-convert", "dynamic-reshape", "erf",
                      "exponential-minus-one", "logistic", "popcnt", "clz",
                      "real", "imag", "complex", "expm1", "log1p"):
                if op == "convolution":
                    # not used by these models; rough: 2*out numel
                    on, _ = _shape_numel_bytes(out_type)
                    t.flops += 2.0 * on
                if not flops_only:
                    _, ob = _shape_numel_bytes(out_type)
                    t.bytes += ob + self._operand_bytes(comp, line, op)
                continue
            if base in _ALIAS_OPS or op.endswith("-done") or op.endswith("-start"):
                continue
            # unknown op: count boundary bytes, no flops
            if not flops_only:
                _, ob = _shape_numel_bytes(out_type)
                t.bytes += ob
        self._memo[key] = t
        return t


def analyze_hlo(text: str, default_group: int) -> Dict[str, object]:
    an = HloAnalyzer(text, default_group)
    t = an.totals()
    return {
        "flops_per_device": t.flops,
        "hbm_bytes_per_device": t.bytes,
        "collective_bytes_by_type": t.coll_bytes,
        "collective_count_by_type": t.coll_count,
        "wire_bytes_per_device": float(sum(t.coll_bytes.values())),
        "notes": t.notes,
    }
