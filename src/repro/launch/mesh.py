"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model) mesh — used by
    tests and the CPU examples (1x1 on this container)."""
    n = len(jax.devices())
    data = 1
    model = n
    return jax.make_mesh((data, model), ("data", "model"))
