"""Public jit'd wrappers over the Pallas kernels (with pure-jnp fallback).

``use_pallas`` selects the execution path:
  * "auto"   — Pallas compiled on TPU, Pallas interpret=True elsewhere for
               kernel-path fidelity in tests, unless the problem is tiny.
  * True     — always Pallas (interpret on non-TPU backends).
  * False    — pure-jnp reference (ref.py) — same semantics, used for
               oracle checks and for CPU-speed benchmarks where the python
               interpret loop would dominate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bitmap_filter import bitmap_filter_pallas
from .count import pair_count_pallas, pair_count_ref
from .group_intersect import group_match_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bitmap_filter(images: jnp.ndarray, use_pallas="auto") -> jnp.ndarray:
    """(k, G, m, W) stacked images -> (G,) survivor mask (bool).

    A leading batch axis — (B, k, G, m, W) -> (B, G) — runs B queries of
    identical static shape in one call (the exec subsystem's bucketed
    batches); the Pallas path folds the batch into the kernel grid.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        return bitmap_filter_pallas(images, interpret=not _on_tpu())
    return ref.bitmap_filter_ref(images)


def group_match(a_vals: jnp.ndarray, b_vals: jnp.ndarray,
                use_pallas="auto") -> jnp.ndarray:
    """(S, ga), (S, gb) sentinel-padded -> (S, ga) membership mask (bool).

    Leading batch axis supported: (B, S, ga) x (B, S, gb) -> (B, S, ga);
    the Pallas path flattens it onto the row grid.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        return group_match_pallas(a_vals, b_vals, interpret=not _on_tpu())
    return ref.group_match_ref(a_vals.astype(jnp.int32), b_vals.astype(jnp.int32))


def pair_count(a_vals: jnp.ndarray, b_vals: jnp.ndarray,
               use_pallas="auto") -> jnp.ndarray:
    """(S, ga), (S, gb) sentinel-padded -> (S,) int32 match counts.

    The count-only twin of :func:`group_match` — same broadcast-equality
    tile, reduced to one scalar per row, so the suggestion path never
    materializes survivor buffers.  Leading batch axes supported:
    (..., S, ga) x (..., S, gb) -> (..., S).
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        return pair_count_pallas(a_vals, b_vals, interpret=not _on_tpu())
    return pair_count_ref(a_vals.astype(jnp.int32), b_vals.astype(jnp.int32))


def vocab_mask_and(masks: jnp.ndarray, use_pallas="auto") -> jnp.ndarray:
    """Constrained-decoding mask intersection: (k, V//32) uint32 packed
    allowed-token bitmaps -> (V//32,) packed AND.

    This is Algorithm 2 line 1 at vocabulary scale — one group of size V,
    word representation of width V bits.  The AND itself is a trivial
    elementwise reduce; it reuses the same packed-lane layout as the filter
    kernel so serving code has a single bitmap convention.
    """
    out = masks[0]
    for i in range(1, masks.shape[0]):
        out = out & masks[i]
    return out


def unpack_vocab_mask(packed: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """(V//32,) packed uint32 -> (V,) bool allowed mask (lowest bit first)."""
    bits = (packed[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(-1)[:vocab].astype(bool)


def pack_vocab_mask(allowed: jnp.ndarray) -> jnp.ndarray:
    """(V,) bool -> (ceil(V/32),) packed uint32."""
    v = allowed.shape[0]
    vp = -(-v // 32) * 32
    a = jnp.pad(allowed.astype(jnp.uint32), (0, vp - v)).reshape(-1, 32)
    return (a << jnp.arange(32, dtype=jnp.uint32)).sum(axis=1, dtype=jnp.uint32)
