"""Pallas TPU kernel: batched word-representation AND filter (Alg. 5 line 3).

This is the perf-critical hot spot of the paper's online stage: for every
group tuple, AND the k sets' m hash images and test each of the m results
for non-emptiness.  Arithmetic intensity is ~0.25 ops/byte — firmly
memory-bound — so the kernel's job is purely to stream HBM at line rate
through VMEM with hardware-aligned tiles and no layout changes.

TPU-native layout: **groups live on the 128 lanes**, the m*W packed bitmap
words live on sublanes.  The wrapper reshapes the logical (k, G, m, W)
images to (k, F, G) with F = m*Wp (Wp = W padded so F is a multiple of 8,
the int32 sublane tile).  Each grid step processes one (F, 128) tile per
set: k-way AND on the VPU, OR-reduce over each image's Wp words, non-zero
test, AND-reduce over the m images — emitting 128 survivor flags per step.

Multi-query batching (the exec subsystem's bucketed execution) folds the
batch straight into the grid: a (B, k, G, m, W) input runs a (B, G/128)
grid where grid step (b, i) streams query b's i-th lane tile.  Queries in
a bucket share one static shape, so the whole bucket is a single
pallas_call — no vmap wrapper, no per-query dispatch.

VMEM working set per step: (k+1) * F * 128 * 4 bytes — for k=4, m=2, W=8
that is 40 KiB, far under the ~16 MiB VMEM budget, leaving headroom for
the double-buffered pipeline pallas_call builds automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8


def _filter_kernel(imgs_ref, out_ref, *, k: int, m: int, wp: int):
    """imgs_ref: (1, k, F, 128) int32 block; out_ref: (1, 8, 128) int32 block."""
    h = imgs_ref[0, 0]
    for i in range(1, k):                      # k is tiny & static: unroll
        h = h & imgs_ref[0, i]                 # (F, 128) VPU AND
    hw = h.reshape(m, wp, LANES)               # split images from words
    nonzero = (hw != 0).max(axis=1)            # OR over words -> (m, 128)
    passed = nonzero.min(axis=0)               # AND over images -> (128,)
    out_ref[...] = jnp.broadcast_to(passed.astype(jnp.int32), (1, SUBLANES, LANES))


def _pack(images: jnp.ndarray):
    """(B, k, G, m, W) -> (B, k, F, Gp) int32 with F = m*Wp, zero padding."""
    b, k, g, m, w = images.shape
    wp = w
    while (m * wp) % SUBLANES:
        wp += 1
    gp = -(-g // LANES) * LANES
    x = (jax.lax.bitcast_convert_type(images, jnp.int32)
         if images.dtype == jnp.uint32 else images.astype(jnp.int32))
    x = jnp.pad(x, ((0, 0), (0, 0), (0, gp - g), (0, 0), (0, wp - w)))
    x = x.reshape(b, k, gp, m * wp).transpose(0, 1, 3, 2)  # (B, k, F, Gp)
    return x, wp, gp


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_filter_pallas(images: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Survivor mask for (k, G, m, W) or (B, k, G, m, W) group-tuple images.

    Returns (G,) / (B, G) bool — see kernels.ref.bitmap_filter_ref for
    semantics.  A leading batch axis becomes the leading grid axis.
    """
    batched = images.ndim == 5
    if not batched:
        images = images[None]
    b, k, g, m, w = images.shape
    packed, wp, gp = _pack(images)
    f = m * wp
    kern = functools.partial(_filter_kernel, k=k, m=m, wp=wp)
    out = pl.pallas_call(
        kern,
        grid=(b, gp // LANES),
        in_specs=[
            pl.BlockSpec((1, k, f, LANES), lambda bi, i: (bi, 0, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, SUBLANES, LANES), lambda bi, i: (bi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((b, SUBLANES, gp), jnp.int32),
        interpret=interpret,
    )(packed)
    mask = out[:, 0, :g].astype(bool)
    return mask if batched else mask[0]
