"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match; every kernel test
sweeps shapes/dtypes and asserts allclose against these functions.
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL32 = jnp.int32(-1)  # 0xFFFFFFFF viewed as int32 — padding sentinel


def bitmap_filter_ref(images: jnp.ndarray) -> jnp.ndarray:
    """Word-representation AND filter (Alg. 5 line 3), batched over groups.

    Args:
      images: (k, G, m, W) or (B, k, G, m, W) uint32/int32 — for each of the
        k sets, the m packed hash images of the group aligned to each of the
        G tuples; an optional leading batch axis runs B independent queries.

    Returns:
      (G,) / (B, G) bool — True where the tuple SURVIVES the filter, i.e. for
      every j in [m] the k-way AND of the j-th images is non-zero.  (A tuple
      is *skipped* when any image-AND is all-zero — the paper's test.)
    """
    k_axis = images.ndim - 4                    # 0 unbatched, 1 batched
    imgs = jnp.moveaxis(images, k_axis, 0)
    h = imgs[0]
    for i in range(1, imgs.shape[0]):
        h = h & imgs[i]                         # (..., G, m, W)
    nonzero = (h != 0).any(axis=-1)             # (..., G, m)
    return nonzero.all(axis=-1)                 # (..., G)


def group_match_ref(a_vals: jnp.ndarray, b_vals: jnp.ndarray) -> jnp.ndarray:
    """All-pairs small-group intersection (TPU replacement for the linear
    merge in IntersectSmall): which elements of ``a`` occur in ``b``.

    Args:
      a_vals: (S, ga) int32 — survivor groups of set A, sentinel-padded (-1).
      b_vals: (S, gb) int32 — aligned survivor groups of set B.
        Both accept an optional leading batch axis: (B, S, ga) x (B, S, gb).

    Returns:
      (S, ga) / (B, S, ga) bool — True where a real element of ``a`` is
      present in ``b``.
    """
    eq = a_vals[..., :, None] == b_vals[..., None, :]
    return eq.any(axis=-1) & (a_vals != SENTINEL32)
