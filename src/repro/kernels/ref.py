"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match; every kernel test
sweeps shapes/dtypes and asserts allclose against these functions.
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL32 = jnp.int32(-1)  # 0xFFFFFFFF viewed as int32 — padding sentinel


def bitmap_filter_ref(images: jnp.ndarray) -> jnp.ndarray:
    """Word-representation AND filter (Alg. 5 line 3), batched over groups.

    Args:
      images: (k, G, m, W) uint32/int32 — for each of the k sets, the m
        packed hash images of the group aligned to each of the G tuples.

    Returns:
      (G,) bool — True where the tuple SURVIVES the filter, i.e. for every
      j in [m] the k-way AND of the j-th images is non-zero.  (A tuple is
      *skipped* when any image-AND is all-zero — the paper's test.)
    """
    h = images[0]
    for i in range(1, images.shape[0]):
        h = h & images[i]                       # (G, m, W)
    nonzero = (h != 0).any(axis=-1)             # (G, m)
    return nonzero.all(axis=-1)                 # (G,)


def group_match_ref(a_vals: jnp.ndarray, b_vals: jnp.ndarray) -> jnp.ndarray:
    """All-pairs small-group intersection (TPU replacement for the linear
    merge in IntersectSmall): which elements of ``a`` occur in ``b``.

    Args:
      a_vals: (S, ga) int32 — survivor groups of set A, sentinel-padded (-1).
      b_vals: (S, gb) int32 — aligned survivor groups of set B.

    Returns:
      (S, ga) bool — True where a real element of ``a`` is present in ``b``.
    """
    eq = a_vals[:, :, None] == b_vals[:, None, :]
    return eq.any(axis=-1) & (a_vals != SENTINEL32)
