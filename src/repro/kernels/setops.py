"""Batched dense sorted-set passes: union / difference / intersection.

The expression evaluator (``core/engine.py``) works on **dense value
buffers**: each leaf's ``(2^t, gmax)`` z-prefix group layout flattens to
one sorted uint32 row per query, and every DAG node is then a sort-merge
pass over its children's buffers.  This module holds those passes — pure
``jnp`` (XLA) implementations plus numpy references for unit tests.

Layout convention (shared with the intersection pipeline's packed
results): rows are **sorted uint32** with ``SENTINEL = 0xFFFFFFFF``
padding.  ``DeviceSet.from_host`` asserts real values stay below the
sentinel, and the int32 ``-1`` padding of device sets bitcasts to it, so
"sort ascending as uint32" puts padding last for free — that single
invariant is what makes every pass below a (concat →) sort → mask →
sort.

Why no hand-written Pallas here: unlike ``bitmap_filter`` /
``group_match`` (bit-twiddling the XLA fuser won't invent), these passes
are dominated by *sorting*, and ``jnp.sort`` already lowers to the
backend's tuned sort (TPU sort HLO / CUB on GPU).  A Pallas bitonic
network would re-implement that slower.  The passes still run inside the
same jit'd, bucketed ``(B, …)`` executables as the kernels, so they
inherit the batching/compile-amortization story unchanged.

All passes are shape-static: callers pick the output width
(``min(capacity, natural width)``) and get back ``(buffer, count)`` —
``count`` is the TRUE result size, so ``count > width`` is the per-query
overflow signal that triggers the executor's single enlarged re-run.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SENTINEL", "densify", "member_mask", "union_pass", "diff_pass",
    "intersect_pass", "densify_ref", "union_ref", "diff_ref",
    "intersect_ref",
]

# np scalar, not a jnp array: module import must stay trace-safe (a jnp
# constant created while some caller is tracing would leak that tracer
# into every later jit), and XLA folds the np scalar identically.
SENTINEL = np.uint32(0xFFFFFFFF)


def densify(vals: jnp.ndarray) -> jnp.ndarray:
    """(B, 2^t, gmax) int32 device-set values (uint32 bitcast, -1 padded)
    -> (B, 2^t * gmax) sorted uint32 dense rows, sentinel-padded.  The
    -1 padding bitcasts to the sentinel, which sorts last."""
    u = jax.lax.bitcast_convert_type(vals, jnp.uint32)
    return jnp.sort(u.reshape(u.shape[0], -1), axis=1)


def member_mask(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B, La) needles x (B, Lb) sorted haystacks -> (B, La) bool: needle
    present in its row's haystack.  Sentinel needles are never members.
    Needles may be unsorted (only the haystack feeds searchsorted)."""
    idx = jax.vmap(jnp.searchsorted)(b, a)
    idx = jnp.clip(idx, 0, b.shape[1] - 1)
    hit = jnp.take_along_axis(b, idx, axis=1) == a
    return hit & (a != SENTINEL)


def union_pass(bufs: Sequence[jnp.ndarray], width: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """n-ary ∪ of sorted sentinel-padded rows -> (out (B, width) sorted,
    count (B,) int32 = true union size).  concat → sort → adjacent-dup
    mask → re-sort → slice; ``count > width`` means truncation."""
    cat = jnp.sort(jnp.concatenate(list(bufs), axis=1), axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(cat[:, :1], dtype=bool), cat[:, 1:] == cat[:, :-1]],
        axis=1)
    uniq = jnp.where(dup, SENTINEL, cat)
    count = jnp.sum(uniq != SENTINEL, axis=1, dtype=jnp.int32)
    return jnp.sort(uniq, axis=1)[:, :width], count


def diff_pass(a: jnp.ndarray, b: jnp.ndarray, width: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """∖: drop ``a``'s members of ``b`` -> (out (B, width) sorted, count
    (B,) int32).  Both inputs sorted sentinel-padded rows."""
    out = jnp.where(member_mask(a, b), SENTINEL, a)
    count = jnp.sum(out != SENTINEL, axis=1, dtype=jnp.int32)
    return jnp.sort(out, axis=1)[:, :width], count


def intersect_pass(bufs: Sequence[jnp.ndarray], width: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """n-ary ∩ -> (out (B, width) sorted, count (B,) int32).  Folds
    membership onto the first (canonically smallest) buffer."""
    acc = bufs[0]
    for b in bufs[1:]:
        acc = jnp.where(member_mask(acc, b), acc, SENTINEL)
    count = jnp.sum(acc != SENTINEL, axis=1, dtype=jnp.int32)
    return jnp.sort(acc, axis=1)[:, :width], count


# ---------------------------------------------------------------------------
# numpy references (unit-test oracles for the passes themselves)
# ---------------------------------------------------------------------------

_SENT_NP = np.uint32(0xFFFFFFFF)


def _pad_rows(rows: List[np.ndarray], width: int) -> np.ndarray:
    out = np.full((len(rows), width), _SENT_NP, dtype=np.uint32)
    for i, r in enumerate(rows):
        out[i, :min(len(r), width)] = r[:width]
    return out


def densify_ref(vals: np.ndarray) -> np.ndarray:
    u = vals.astype(np.int64).reshape(vals.shape[0], -1)
    u = np.where(u < 0, int(_SENT_NP), u).astype(np.uint32)
    return np.sort(u, axis=1)


def union_ref(bufs: Sequence[np.ndarray], width: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    rows, counts = [], []
    for i in range(bufs[0].shape[0]):
        vals = np.concatenate([b[i][b[i] != _SENT_NP] for b in bufs])
        u = np.unique(vals)
        rows.append(u)
        counts.append(len(u))
    return _pad_rows(rows, width), np.asarray(counts, dtype=np.int32)


def diff_ref(a: np.ndarray, b: np.ndarray, width: int
             ) -> Tuple[np.ndarray, np.ndarray]:
    rows, counts = [], []
    for i in range(a.shape[0]):
        d = np.setdiff1d(a[i][a[i] != _SENT_NP], b[i][b[i] != _SENT_NP])
        rows.append(d)
        counts.append(len(d))
    return _pad_rows(rows, width), np.asarray(counts, dtype=np.int32)


def intersect_ref(bufs: Sequence[np.ndarray], width: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    rows, counts = [], []
    for i in range(bufs[0].shape[0]):
        out = bufs[0][i][bufs[0][i] != _SENT_NP]
        for b in bufs[1:]:
            out = np.intersect1d(out, b[i][b[i] != _SENT_NP])
        rows.append(out)
        counts.append(len(out))
    return _pad_rows(rows, width), np.asarray(counts, dtype=np.int32)
