"""Pallas TPU kernel: all-pairs match of survivor small groups.

The paper recovers the intersection of a surviving group pair by merging
h^{-1} linked lists — serial, branchy, perfect for a CPU, degenerate on a
TPU.  The TPU-native replacement: for each surviving tuple, compare every
element of group A against every element of group B in one (ga x gb)
broadcast-equality tile.  With the paper's group size ~sqrt(w) <= 32 the
tile is tiny, branch-free, and lane-parallel; 8 tuples are processed per
grid step so the compare tile is (8, ga, gb) — at ga=gb=128 that is 512 KiB
of bool in VMEM, still comfortably inside budget.

Padding uses the sentinel 0xFFFFFFFF (= -1 as int32); real universes exclude
it (asserted during pre-processing), so masks are implicit in the values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
SENTINEL = -1  # 0xFFFFFFFF as int32 — python literal so kernels don't capture arrays


def _match_kernel(a_ref, b_ref, out_ref):
    """a_ref: (8, gap) int32; b_ref: (8, gbp) int32; out_ref: (8, gap) int32."""
    a = a_ref[...]
    b = b_ref[...]
    eq = a[:, :, None] == b[:, None, :]          # (8, gap, gbp)
    hit = eq.max(axis=2)                          # any over b -> (8, gap)
    real = a != SENTINEL
    out_ref[...] = (hit & real).astype(jnp.int32)


def _pad_lanes(x: jnp.ndarray, fill) -> jnp.ndarray:
    s, g = x.shape
    gp = -(-g // LANES) * LANES
    sp = -(-s // SUBLANES) * SUBLANES
    return jnp.pad(x, ((0, sp - s), (0, gp - g)), constant_values=fill)


@functools.partial(jax.jit, static_argnames=("interpret",))
def group_match_pallas(a_vals: jnp.ndarray, b_vals: jnp.ndarray, *,
                       interpret: bool = True) -> jnp.ndarray:
    """(S, ga) x (S, gb) sentinel-padded int32 -> (S, ga) bool membership.

    A leading batch axis ((B, S, ga) x (B, S, gb) -> (B, S, ga)) folds into
    the row grid: every row is an independent tuple regardless of which
    query it came from, so the batch flattens onto the sublane axis and the
    kernel is unchanged.
    """
    if a_vals.ndim == 3:
        bsz, s, ga = a_vals.shape
        gb = b_vals.shape[-1]
        flat = group_match_pallas(
            a_vals.reshape(bsz * s, ga), b_vals.reshape(bsz * s, gb),
            interpret=interpret,
        )
        return flat.reshape(bsz, s, ga)
    s, ga = a_vals.shape
    _, gb = b_vals.shape
    a = _pad_lanes(a_vals.astype(jnp.int32), -1)
    # Pad B with a *different* sentinel (-2) so padded-A never matches padded-B;
    # real elements never equal either sentinel.
    b = _pad_lanes(b_vals.astype(jnp.int32), -2)
    sp, gap = a.shape
    _, gbp = b.shape
    out = pl.pallas_call(
        _match_kernel,
        grid=(sp // SUBLANES,),
        in_specs=[
            pl.BlockSpec((SUBLANES, gap), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, gbp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, gap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, gap), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:s, :ga].astype(bool)
