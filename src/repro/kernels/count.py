"""Pallas TPU kernel: per-tuple intersection *counts* (no survivor recovery).

The suggestion workload (set-similarity join) only needs |A ∩ B|, never the
elements themselves.  That deletes everything expensive about the point-query
pipeline: no phase-1 filter pass, no survivor compaction, no capacity buffer,
no overflow re-run.  Each (probe group, candidate group) tuple reduces to one
scalar — the number of probe elements present in the aligned candidate group —
and the per-pair cardinality is the plain sum of those scalars over all G
tuples (each common element x lives in exactly one tuple: the one indexed by
its full-depth prefix, so summing over tuples counts it exactly once).

The kernel is the counting twin of ``group_intersect``: the same (8, ga, gb)
broadcast-equality tile, but reduced to an (8,) count instead of an (8, ga)
membership mask.  Output rows broadcast the count across the lane axis so the
store stays lane-aligned; callers read lane 0.

Padding follows the repo convention: probe rows pad with -1 (0xFFFFFFFF),
candidate rows pad with -2 so padded probes never match padded candidates.
Real universes exclude both sentinels (asserted during pre-processing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
SENTINEL = -1  # 0xFFFFFFFF as int32 — python literal so kernels don't capture arrays


def pair_count_ref(a_vals: jnp.ndarray, b_vals: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle: per-row count of real ``a`` elements present in ``b``.

    Args:
      a_vals: (S, ga) int32, sentinel-padded (-1) probe groups.
      b_vals: (S, gb) int32, aligned candidate groups.  Both accept leading
        batch axes: (..., S, ga) x (..., S, gb) -> (..., S).

    Returns:
      (..., S) int32 — exact |a ∩ b| per row when each row's real elements
      are duplicate-free (group rows of a preprocessed set always are).
    """
    eq = a_vals[..., :, None] == b_vals[..., None, :]
    hit = eq.any(axis=-1) & (a_vals != jnp.int32(SENTINEL))
    return hit.sum(axis=-1, dtype=jnp.int32)


def _count_kernel(a_ref, b_ref, out_ref):
    """a_ref: (8, gap) int32; b_ref: (8, gbp) int32; out_ref: (8, LANES) int32."""
    a = a_ref[...]
    b = b_ref[...]
    eq = a[:, :, None] == b[:, None, :]          # (8, gap, gbp)
    hit = eq.max(axis=2)                          # any over b -> (8, gap)
    real = a != SENTINEL
    cnt = (hit & real).astype(jnp.int32).sum(axis=1)  # (8,)
    out_ref[...] = jnp.broadcast_to(cnt[:, None], out_ref.shape)


def _pad_lanes(x: jnp.ndarray, fill) -> jnp.ndarray:
    s, g = x.shape
    gp = -(-g // LANES) * LANES
    sp = -(-s // SUBLANES) * SUBLANES
    return jnp.pad(x, ((0, sp - s), (0, gp - g)), constant_values=fill)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_count_pallas(a_vals: jnp.ndarray, b_vals: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """(S, ga) x (S, gb) sentinel-padded int32 -> (S,) int32 match counts.

    Leading batch axes fold into the row grid exactly as in
    ``group_match_pallas``: every row is an independent tuple, so
    (..., S, ga) x (..., S, gb) -> (..., S) by flattening onto sublanes.
    """
    if a_vals.ndim > 2:
        lead = a_vals.shape[:-1]
        ga = a_vals.shape[-1]
        gb = b_vals.shape[-1]
        flat = pair_count_pallas(
            a_vals.reshape(-1, ga), b_vals.reshape(-1, gb),
            interpret=interpret,
        )
        return flat.reshape(lead)
    s, _ = a_vals.shape
    a = _pad_lanes(a_vals.astype(jnp.int32), -1)
    # Pad B with a *different* sentinel (-2) so padded-A never matches padded-B;
    # real elements never equal either sentinel.
    b = _pad_lanes(b_vals.astype(jnp.int32), -2)
    sp, gap = a.shape
    _, gbp = b.shape
    out = pl.pallas_call(
        _count_kernel,
        grid=(sp // SUBLANES,),
        in_specs=[
            pl.BlockSpec((SUBLANES, gap), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, gbp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, LANES), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:s, 0]
