"""Streaming binary ingestion for the suggestion corpus.

The suggestion service (:class:`repro.serve.search.SuggestEngine`) grows
its corpus incrementally — sets arrive over time from logs, crawls, or a
network feed, not as one in-memory dict.  This module defines a tiny
length-prefixed little-endian record format and a chunk-tolerant streaming
reader, so a corpus can be replayed from disk (or any byte iterator) and
folded into a live engine one set at a time:

    file   := MAGIC (4 bytes, b"RSI1") record*
    record := set_id:uint32  n:uint32  values:uint32[n]

Everything is little-endian uint32.  The reader consumes *byte chunks* of
arbitrary size (``stream_records``): a record split across a chunk
boundary is buffered and completed by the next chunk, so the format works
unchanged over sockets, mmap windows, or ``iter(lambda: f.read(1 << 16),
b"")``.  A truncated tail (stream cut mid-record) raises ``ValueError``
rather than silently dropping data.

Duplicate ``set_id`` records are replacements, last-writer-wins — the same
semantics as :meth:`SuggestEngine.add_set`, so replaying a log that
appends updated versions of a set converges to the latest snapshot.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Sequence, Tuple

import numpy as np

__all__ = [
    "MAGIC", "write_records", "read_records", "stream_records",
    "ingest_file",
]

MAGIC = b"RSI1"
_U32 = np.dtype("<u4")


def write_records(path_or_stream,
                  records: Iterable[Tuple[int, Sequence[int]]]) -> int:
    """Serialize ``(set_id, values)`` pairs; returns the record count.

    Accepts a filesystem path or any binary stream with ``write``.
    Values are cast to uint32 (the element domain of the whole repo);
    order inside a record is preserved verbatim — readers normalize.
    """
    own = not hasattr(path_or_stream, "write")
    stream = open(path_or_stream, "wb") if own else path_or_stream
    n_records = 0
    try:
        stream.write(MAGIC)
        for set_id, values in records:
            vals = np.asarray(values, _U32)
            header = np.asarray([set_id, vals.size], _U32)
            stream.write(header.tobytes())
            stream.write(vals.tobytes())
            n_records += 1
    finally:
        if own:
            stream.close()
    return n_records


def stream_records(chunks: Iterable[bytes]
                   ) -> Iterator[Tuple[int, np.ndarray]]:
    """Incrementally decode records from arbitrary-size byte chunks.

    The streaming half of the format: yields ``(set_id, values)`` as soon
    as each record is complete, holding only the unfinished tail between
    chunks (memory is O(largest record), not O(file)).  Raises
    ``ValueError`` on a bad magic or a truncated final record.
    """
    buf = b""
    seen_magic = False
    for chunk in chunks:
        buf += bytes(chunk)
        if not seen_magic:
            if len(buf) < len(MAGIC):
                continue
            if buf[:len(MAGIC)] != MAGIC:
                raise ValueError(
                    f"bad magic {buf[:len(MAGIC)]!r}; expected {MAGIC!r}")
            buf = buf[len(MAGIC):]
            seen_magic = True
        while len(buf) >= 8:
            set_id, n = np.frombuffer(buf, _U32, count=2)
            end = 8 + 4 * int(n)
            if len(buf) < end:
                break  # record straddles the chunk boundary — wait
            yield int(set_id), np.frombuffer(buf, _U32, count=int(n),
                                             offset=8).copy()
            buf = buf[end:]
    if not seen_magic and buf:
        raise ValueError(f"bad magic {buf[:len(MAGIC)]!r}; expected {MAGIC!r}")
    if buf:
        raise ValueError(f"truncated record: {len(buf)} trailing bytes")


def read_records(path, chunk_size: int = 1 << 16
                 ) -> Iterator[Tuple[int, np.ndarray]]:
    """Stream records from a file path in ``chunk_size``-byte reads."""
    with open(path, "rb") as f:
        yield from stream_records(iter(lambda: f.read(chunk_size), b""))


def ingest_file(path, engine, chunk_size: int = 1 << 16) -> int:
    """Fold a record file into a live suggestion engine, one set at a
    time (each record is queryable before the next is decoded).

    ``engine`` is anything with ``add_set(set_id, values)`` —
    :class:`~repro.serve.search.SuggestEngine` in practice.  Returns the
    number of records applied.  Empty-value records are skipped (an empty
    set can never be suggested and the index builder requires n >= 1).
    """
    n_applied = 0
    for set_id, values in read_records(path, chunk_size=chunk_size):
        if values.size:
            engine.add_set(set_id, values)
            n_applied += 1
    return n_applied
