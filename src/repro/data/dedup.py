"""Shingle-based near-duplicate detection via the paper's engine.

Each document is reduced to a set of token k-gram (shingle) hashes; a pair
of documents is a near-dup candidate when their shingle sets intersect in
more than ``threshold`` elements.  The candidate search is exactly a batch
of set intersections, executed with RanGroupScan — the word-representation
filter skips the (overwhelmingly common) empty-overlap pairs, which is the
paper's r << n regime in its purest form.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from ..core.hashing import default_permutation, random_hash_family
from ..core.intersect import rangroupscan
from ..core.partition import preprocess_prefix


def shingles(tokens: np.ndarray, k: int = 5) -> np.ndarray:
    """Token k-grams hashed to uint32 (sorted unique)."""
    if len(tokens) < k:
        return np.unique(tokens.astype(np.uint32))
    windows = np.lib.stride_tricks.sliding_window_view(tokens.astype(np.uint64), k)
    mix = np.uint64(0x100000001B3)
    h = np.zeros(len(windows), dtype=np.uint64)
    for i in range(k):
        h = (h ^ windows[:, i]) * mix & np.uint64(0xFFFFFFFFFFFFFFFF)
    return np.unique((h >> np.uint64(32)).astype(np.uint32))


class Deduplicator:
    def __init__(self, w: int = 256, m: int = 2, seed: int = 0):
        self.family = random_hash_family(m, w, seed=seed)
        self.perm = default_permutation(seed)
        self.w, self.m = w, m
        self.indexes = {}

    def add(self, doc_id: int, tokens: np.ndarray, k: int = 5) -> None:
        sh = shingles(tokens, k)
        self.indexes[doc_id] = preprocess_prefix(
            sh, w=self.w, m=self.m, family=self.family, perm=self.perm)

    def overlap(self, a: int, b: int) -> int:
        res, _ = rangroupscan([self.indexes[a], self.indexes[b]])
        return len(res)

    def near_dups(self, threshold: float = 0.5) -> List[Tuple[int, int, float]]:
        """All pairs with Jaccard >= threshold (quadratic candidate loop —
        the per-pair test is the engine's fast path; banding/LSH pre-filters
        are orthogonal and omitted)."""
        ids = sorted(self.indexes)
        out = []
        for i, a in enumerate(ids):
            for b in ids[i + 1:]:
                inter = self.overlap(a, b)
                union = self.indexes[a].n + self.indexes[b].n - inter
                j = inter / max(1, union)
                if j >= threshold:
                    out.append((a, b, j))
        return out
