"""Deterministic, stateless data pipeline (index-addressable batches).

``batch_at(step)`` is a pure function of (seed, step) — resume after a
restart is exact with no iterator state to persist beyond the step counter
(recorded in the checkpoint manifest).  Tokens come from a splitmix-style
integer hash, giving an unbounded, reproducible synthetic stream; a Zipf
corpus generator provides realistic document data for the dedup/search
substrates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    mask64 = np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & mask64
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & mask64
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        # Learnable-but-unbounded stream: within each 16-token run the next
        # token is the affine map (31*t + 7) mod V of the previous one; run
        # starts are splitmix-hashed (deterministic in (seed, step, index)).
        n = self.batch * (self.seq + 1)
        base = np.arange(n, dtype=np.uint64) + np.uint64(step) * np.uint64(n) \
            + (np.uint64(self.seed) << np.uint64(40))
        starts = (_splitmix(base) % np.uint64(self.vocab)).astype(np.int64)
        starts = starts.reshape(self.batch, self.seq + 1)
        toks = starts.copy()
        pos_in_run = np.arange(self.seq + 1) % 16
        for j in range(1, self.seq + 1):
            if pos_in_run[j] == 0:
                continue
            toks[:, j] = (toks[:, j - 1] * 31 + 7) % self.vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def zipf_corpus(n_docs: int, vocab: int = 50000, mean_len: int = 200,
                alpha: float = 1.2, seed: int = 0) -> List[np.ndarray]:
    """Documents as arrays of term-ids with a Zipf unigram distribution —
    produces realistically skewed posting-list lengths for the search
    engine (frequent terms -> long lists, as in the paper's Bing data)."""
    rng = np.random.default_rng(seed)
    docs = []
    lengths = rng.poisson(mean_len, size=n_docs).clip(min=8)
    for i in range(n_docs):
        terms = rng.zipf(alpha, size=lengths[i])
        docs.append(np.unique((terms - 1) % vocab).astype(np.uint32))
    return docs


def inverted_index(docs: Sequence[np.ndarray]) -> Dict[int, np.ndarray]:
    """term -> sorted array of doc ids."""
    from collections import defaultdict

    post = defaultdict(list)
    for doc_id, terms in enumerate(docs):
        for t in terms.tolist():
            post[t].append(doc_id)
    return {t: np.asarray(sorted(ids), dtype=np.uint32)
            for t, ids in post.items()}
