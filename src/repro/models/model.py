"""Unified model facade: one object per architecture family.

``build_model(cfg)`` returns a :class:`Model` exposing:
  * ``init(key) -> params``
  * ``loss(params, batch) -> scalar``           (training objective)
  * ``prefill(params, batch) -> last-token logits``  (inference prefill)
  * ``init_cache(batch, max_seq) -> cache``
  * ``decode(params, cache, tokens, pos) -> (logits, cache)``
  * ``batch_spec(shape) -> dict of ShapeDtypeStructs``  (for the dry-run)

Batches are dicts; extra modality inputs (frames / patch embeddings) appear
per family.  All functions are pure and jit/pjit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, moe, ssm, transformer, xlstm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    prefill: Callable
    init_cache: Callable
    decode: Callable

    def batch_spec(self, shape: ShapeConfig,
                   per_host_batch: Optional[int] = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the inputs of this (arch, shape)."""
        b = per_host_batch or shape.global_batch
        s = shape.seq_len
        cfg = self.cfg
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            spec = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.family == "encdec":
                spec["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder_seq, cfg.frontend_dim), cfg.activation_dtype)
            if cfg.frontend == "patch":
                spec["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_patches, cfg.frontend_dim), cfg.activation_dtype)
            if shape.kind == "prefill":
                spec.pop("labels")
            return spec
        # decode: one new token against a seq_len-deep cache
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }


def _dense_model(cfg: ArchConfig) -> Model:
    def prefill(params, batch):
        hidden = transformer.forward(params, cfg, batch["tokens"],
                                     batch.get("patch_embeds"))
        return transformer.logits_fn(params, cfg, hidden[:, -1])

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        loss=lambda params, batch: transformer.loss_fn(params, cfg, batch),
        prefill=prefill,
        init_cache=lambda b, s, dtype=None: transformer.init_cache(cfg, b, s, dtype),
        decode=lambda params, cache, tokens, pos: transformer.decode_step(
            params, cfg, cache, tokens, pos),
    )


def _moe_model(cfg: ArchConfig) -> Model:
    def prefill(params, batch):
        hidden, _ = moe.forward(params, cfg, batch["tokens"])
        return transformer.logits_fn(params, cfg, hidden[:, -1])

    return Model(
        cfg=cfg,
        init=lambda key: moe.init_params(key, cfg),
        loss=lambda params, batch: moe.loss_fn(params, cfg, batch),
        prefill=prefill,
        init_cache=lambda b, s, dtype=None: transformer.init_cache(cfg, b, s, dtype),
        decode=lambda params, cache, tokens, pos: moe.decode_step(
            params, cfg, cache, tokens, pos),
    )


def _ssm_model(cfg: ArchConfig) -> Model:
    def prefill(params, batch):
        hidden = ssm.forward(params, cfg, batch["tokens"])
        return transformer.logits_fn(params, cfg, hidden[:, -1])

    return Model(
        cfg=cfg,
        init=lambda key: ssm.init_params(key, cfg),
        loss=lambda params, batch: ssm.loss_fn(params, cfg, batch),
        prefill=prefill,
        init_cache=lambda b, s, dtype=None: ssm.init_cache(cfg, b, s, dtype),
        decode=lambda params, cache, tokens, pos: ssm.decode_step(
            params, cfg, cache, tokens, pos),
    )


def _xlstm_model(cfg: ArchConfig) -> Model:
    def prefill(params, batch):
        hidden = xlstm.forward(params, cfg, batch["tokens"])
        return transformer.logits_fn(params, cfg, hidden[:, -1])

    return Model(
        cfg=cfg,
        init=lambda key: xlstm.init_params(key, cfg),
        loss=lambda params, batch: xlstm.loss_fn(params, cfg, batch),
        prefill=prefill,
        init_cache=lambda b, s, dtype=None: xlstm.init_cache(cfg, b, s, dtype),
        decode=lambda params, cache, tokens, pos: xlstm.decode_step(
            params, cfg, cache, tokens, pos),
    )


def _encdec_model(cfg: ArchConfig) -> Model:
    def prefill(params, batch):
        enc_out = encdec.encode(params, cfg, batch["frames"])
        hidden = encdec.decode_train(params, cfg, batch["tokens"], enc_out)
        return transformer.logits_fn(params, cfg, hidden[:, -1])

    return Model(
        cfg=cfg,
        init=lambda key: encdec.init_params(key, cfg),
        loss=lambda params, batch: encdec.loss_fn(params, cfg, batch),
        prefill=prefill,
        init_cache=lambda b, s, dtype=None: encdec.init_cache(cfg, b, s, dtype),
        decode=lambda params, cache, tokens, pos: encdec.decode_step(
            params, cfg, cache, tokens, pos),
    )


_FAMILIES = {
    "dense": _dense_model,
    "vlm": _dense_model,
    "moe": _moe_model,
    "ssm_hybrid": _ssm_model,
    "xlstm": _xlstm_model,
    "encdec": _encdec_model,
}


def build_model(cfg: ArchConfig) -> Model:
    return _FAMILIES[cfg.family](cfg)
