"""Dense decoder-only transformer (qwen3 / starcoder2 / gemma3 / phi3-vision).

One implementation covers the whole dense family:
  * GQA attention with optional qk-norm (qwen3) and RoPE;
  * per-layer local/global attention pattern (gemma3's 5:1 sliding-window)
    expressed as a scanned per-layer window flag — shapes stay homogeneous
    so the layer stack is a single jax.lax.scan (small HLO, fast compile,
    remat-friendly);
  * optional patch-embedding frontend stub (phi-3-vision): precomputed patch
    embeddings are projected and prepended to the token sequence.

Params are stacked along a leading layer axis; `jax.checkpoint` wraps the
scan body (full remat of the layer — the baseline activation-checkpoint
policy; see EXPERIMENTS.md §Perf for the tuned policies).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .. import tuning
from .layers import (
    AttnSpec, attention, attention_decode, attn_init, chunked_xent,
    dense_init, mlp, mlp_init, rmsnorm, rmsnorm_init,
)

Params = Dict[str, Any]


def attn_spec(cfg: ArchConfig) -> AttnSpec:
    return AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.hd, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window,
    )


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer sliding-window sizes; 0 = full attention.

    gemma3: `local_global_ratio` local layers then 1 global, repeating.
    """
    if cfg.sliding_window is None:
        return jnp.zeros((cfg.n_layers,), dtype=jnp.int32)
    if not cfg.local_global_ratio:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, dtype=jnp.int32)
    r = cfg.local_global_ratio
    pattern = [(0 if (i % (r + 1)) == r else cfg.sliding_window)
               for i in range(cfg.n_layers)]
    return jnp.asarray(pattern, dtype=jnp.int32)


def init_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.p_dtype
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_init(ks[0], attn_spec(cfg), dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.mlp_variant),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    kemb, klayers, kfin, kpatch = jax.random.split(key, 4)
    dt = cfg.p_dtype
    layer_keys = jax.random.split(klayers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p: Params = {
        "embed": dense_init(kemb, cfg.vocab, cfg.d_model, dt),
        "layers": layers,
        "ln_f": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(kfin, cfg.vocab, cfg.d_model, dt)
    if cfg.frontend == "patch":
        p["patch_proj"] = dense_init(kpatch, cfg.frontend_dim, cfg.d_model, dt)
    return p


def _embed(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
           patch_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    from ..parallel import ctx as _ctx
    emb = _ctx.constrain(params["embed"].astype(cfg.activation_dtype),
                         ("model", None))
    x = emb[tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if patch_embeds is not None and "patch_proj" in params:
        proj = patch_embeds.astype(x.dtype) @ params["patch_proj"].astype(x.dtype)
        # patch tokens replace the first P positions (the prompt's image slots)
        pcount = proj.shape[1]
        x = jnp.concatenate([proj, x[:, pcount:]], axis=1)
    return x


def _layer_fwd(cfg: ArchConfig, x, layer_p, window, positions, q_chunk=512):
    from ..parallel import ctx as _ctx
    spec = attn_spec(cfg)
    h = rmsnorm(layer_p["ln1"], x)
    # window is a traced per-layer int32: 0 => full attention.  Both branches
    # share shapes so a jnp.where-free select via mask arithmetic suffices:
    # we pass the dynamic window into the mask directly.
    h = _attention_dyn(layer_p["attn"], spec, h, positions, window, q_chunk)
    x = x + h
    h = rmsnorm(layer_p["ln2"], x)
    x = x + mlp(layer_p["mlp"], h)
    if tuning.get("seq_shard_mlp"):
        # Megatron-SP-style: keep the residual stream sequence-sharded over
        # `model` between layers (XLA turns the TP psums into
        # reduce-scatter + all-gather pairs at 1/M volume each)
        x = _ctx.constrain(x, (_ctx.DP, "model", None))
    return x


def _attention_dyn(p, spec: AttnSpec, x, positions, window, q_chunk):
    """attention() with a *traced* window scalar (0 = unlimited)."""
    import math as _math

    b, s, d = x.shape
    from .layers import _qkv, _repeat_kv
    q_chunk = tuning.get("q_chunk")
    sdt = tuning.scores_dtype()

    q, k, v = _qkv(p, spec, x, positions)
    groups = spec.n_heads // spec.n_kv
    gqa_native = tuning.get("gqa_native") and groups > 1
    if not gqa_native:
        k = _repeat_kv(k, groups)
        v = _repeat_kv(v, groups)
    scale = 1.0 / _math.sqrt(spec.head_dim)
    kv_pos = jnp.arange(k.shape[1])
    q_chunk = min(q_chunk, s)
    n_chunks = max(1, s // q_chunk)
    if n_chunks * q_chunk != s:
        q_chunk, n_chunks = s, 1
    qs = q.reshape(b, n_chunks, q_chunk, spec.n_heads, spec.head_dim)
    pos_chunks = positions.reshape(b, n_chunks, q_chunk)
    eff_window = jnp.where(window > 0, window, jnp.int32(2 ** 30))

    neg = jnp.asarray(-30000.0 if sdt == jnp.bfloat16 else -1e30, sdt)

    def one_chunk(q_i, pos_i):
        # scale folded into q (tiny tensor); scores born in sdt directly
        # (no separate convert pass); softmax normalization applied to the
        # output, not the (c,S) probability tile.
        qs_ = q_i * jnp.asarray(scale, q_i.dtype)
        if gqa_native:
            # score einsum against the Kv heads directly: repeated K/V are
            # never materialized (reads Kv instead of H head planes)
            b_, c_, H_, D_ = qs_.shape
            qg = qs_.reshape(b_, c_, spec.n_kv, groups, D_)
            scores = jnp.einsum("bckgd,bskd->bkgcs", qg, k,
                                preferred_element_type=sdt)
            delta = pos_i[:, None, None, :, None] - kv_pos[None, None, None, None, :]
            cmask = (delta >= 0) & (delta < eff_window)
            scores = jnp.where(cmask, scores, neg)
            mx = jnp.max(scores, axis=-1, keepdims=True)
            ex = jnp.exp(scores - mx)
            den = jnp.sum(ex, axis=-1)                    # (B,Kv,G,c)
            o = jnp.einsum("bkgcs,bskd->bckgd", ex.astype(q_i.dtype), v)
            o = o / jnp.moveaxis(den, 3, 1)[..., None].astype(o.dtype)
            return o.reshape(b_, c_, H_, D_)
        scores = jnp.einsum("bchk,bshk->bhcs", qs_, k,
                            preferred_element_type=sdt)
        delta = pos_i[:, None, :, None] - kv_pos[None, None, None, :]
        cmask = (delta >= 0) & (delta < eff_window)
        scores = jnp.where(cmask, scores, neg)
        mx = jnp.max(scores, axis=-1, keepdims=True)
        ex = jnp.exp(scores - mx)
        den = jnp.sum(ex, axis=-1)                        # (B,H,c)
        o = jnp.einsum("bhcs,bshk->bchk", ex.astype(q_i.dtype), v)
        return o / jnp.swapaxes(den, 1, 2)[..., None].astype(o.dtype)

    if n_chunks == 1:
        o = one_chunk(qs[:, 0], pos_chunks[:, 0])[:, None]
    else:
        _, o = jax.lax.scan(
            lambda _, xs: (None, one_chunk(*xs)), None,
            (qs.transpose(1, 0, 2, 3, 4), pos_chunks.transpose(1, 0, 2)))
        o = o.transpose(1, 0, 2, 3, 4)
    o = o.reshape(b, s, spec.n_heads, spec.head_dim)
    from ..parallel import ctx as _ctx
    wo = _ctx.constrain(p["wo"].astype(o.dtype), ("model", None, None))
    return jnp.einsum("bshk,hkd->bsd", o, wo)


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            patch_embeds: Optional[jnp.ndarray] = None,
            q_chunk: int = 512, remat: bool = True) -> jnp.ndarray:
    """Token ids -> final hidden states (B, S, d)."""
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    windows = layer_windows(cfg)

    def body(x, xs):
        layer_p, win = xs
        return _layer_fwd(cfg, x, layer_p, win, positions, q_chunk), None

    if remat:
        body = tuning.remat_wrap(body)
    x, _ = jax.lax.scan(body, x, (params["layers"], windows))
    return rmsnorm(params["ln_f"], x)


def logits_fn(params: Params, cfg: ArchConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    from ..parallel import ctx as _ctx
    emb = params.get("unembed", params["embed"])
    emb = _ctx.constrain(emb.astype(hidden.dtype), ("model", None))
    return hidden @ emb.T


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            q_chunk: int = 512) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"], batch.get("patch_embeds"),
                     q_chunk=q_chunk)
    emb = params.get("unembed", params["embed"])
    return chunked_xent(hidden, emb, batch["labels"])


# ---------------------------------------------------------------- serving

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> Params:
    dt = dtype or cfg.activation_dtype
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt),
    }


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One-token decode: (B, 1) tokens at position `pos` -> (B, V) logits."""
    x = _embed(params, cfg, tokens)
    spec = attn_spec(cfg)
    windows = layer_windows(cfg)

    def body(x, xs):
        layer_p, ck, cv, win = xs
        h = rmsnorm(layer_p["ln1"], x)
        # traced per-layer window scalar; 0 = full attention
        w = jnp.where(win > 0, win, jnp.int32(2 ** 30))
        h, ck, cv = attention_decode(layer_p["attn"], spec, h, ck, cv, pos, window=w)
        x = x + h
        h = rmsnorm(layer_p["ln2"], x)
        x = x + mlp(layer_p["mlp"], h)
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows))
    x = rmsnorm(params["ln_f"], x)
    logits = logits_fn(params, cfg, x[:, 0])
    return logits, {"k": ck, "v": cv}
