"""xLSTM stack (sLSTM + mLSTM blocks) — xlstm-350m.

* mLSTM: matrix-memory cell in its parallel *chunked* form — a gated
  linear-attention contraction with per-step scalar forget decay (same
  two-level chunk structure as the Mamba2 SSD path: quadratic intra-chunk,
  scanned inter-chunk state (H, dk, dv)).
* sLSTM: scalar-memory cell with true hidden-to-gate recurrence — serial
  by construction, implemented as a lax.scan over time (this is the
  documented sequential bottleneck of the family; see DESIGN.md).

Block pattern: every ``slstm_every``-th block is an sLSTM, the rest are
mLSTM (grouped into rounds so the stack is two nested homogeneous scans).
Blocks are pre-LN residual with internal 2x up/down projection (pf=2),
matching the paper's block layout; no separate FFN (d_ff = 0).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .. import tuning
from ..configs.base import ArchConfig
from ..parallel import ctx
from .layers import chunked_xent, dense_init, rmsnorm, rmsnorm_init
from .transformer import _embed, logits_fn

Params = Dict[str, Any]


def _dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_up = 2 * cfg.d_model
    nh = cfg.n_heads
    hd = d_up // nh
    return d_up, nh, hd


# --------------------------------------------------------------- mLSTM

def mlstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_up, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 7)
    dt = cfg.p_dtype
    return {
        "ln": rmsnorm_init(d, dt),
        "w_up": dense_init(ks[0], d, 2 * d_up, dt),      # value path + output gate
        "wq": dense_init(ks[1], d, d_up, dt),
        "wk": dense_init(ks[2], d, d_up, dt),
        "w_if": dense_init(ks[3], d, 2 * nh, dt),        # input & forget gates
        "w_down": dense_init(ks[4], d_up, d, dt),
        "norm": rmsnorm_init(d_up, dt),
    }


def mlstm_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                  chunk: int = 256) -> jnp.ndarray:
    b, s, d = x.shape
    d_up, nh, hd = _dims(cfg)
    h = rmsnorm(p["ln"], x)
    up = h @ ctx.constrain(p["w_up"].astype(x.dtype), (None, "model"))
    v, og = jnp.split(up, 2, axis=-1)
    q = (h @ ctx.constrain(p["wq"].astype(x.dtype),
                           (None, "model"))).reshape(b, s, nh, hd)
    k = (h @ ctx.constrain(p["wk"].astype(x.dtype),
                           (None, "model"))).reshape(b, s, nh, hd) / math.sqrt(hd)
    v = v.reshape(b, s, nh, hd)
    gates = (h @ p["w_if"].astype(x.dtype)).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                  # (B, S, nh)
    logf = jax.nn.log_sigmoid(fg)
    i_gate = jnp.exp(jnp.minimum(ig, 0.0))                 # stabilized input gate

    chunk = min(chunk, s)
    nc = max(1, s // chunk)
    if nc * chunk != s:
        chunk, nc = s, 1
    c = chunk
    qc = q.reshape(b, nc, c, nh, hd)
    kc = k.reshape(b, nc, c, nh, hd)
    vc = v.reshape(b, nc, c, nh, hd)
    ic = i_gate.reshape(b, nc, c, nh)
    Fc = jnp.cumsum(logf.reshape(b, nc, c, nh), axis=2)    # within-chunk cum log decay

    # intra-chunk: D_ij = exp(F_i - F_j) * i_j, causal
    delta = Fc[:, :, :, None, :] - Fc[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(causal[None, None, :, :, None], jnp.exp(delta), 0.0)
    D = D * ic[:, :, None, :, :]                            # (B,nc,i,j,nh)
    scores = jnp.einsum("bnihd,bnjhd->bnijh", qc, kc)       # n = chunk index
    M = scores.astype(jnp.float32) * D
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", M.astype(x.dtype), vc)

    # inter-chunk state: S_n = sum_j exp(F_end - F_j) i_j k_j v_j^T
    end = Fc[:, :, -1:, :]
    wj = (jnp.exp(end - Fc) * ic).astype(x.dtype)
    states = jnp.einsum("bnjh,bnjhd,bnjhe->bnhde", wj, kc, vc)  # (B,nc,nh,hd,hd)
    cdecay = jnp.exp(end[:, :, 0, :])

    def scan_body(hprev, xs_):
        st, dec = xs_
        return hprev * dec[:, :, None, None] + st, hprev

    h0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    _, h_in = jax.lax.scan(scan_body, h0,
                           (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
                            cdecay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4).astype(x.dtype)

    y_inter = jnp.einsum("bnihd,bnhde->bnihe",
                         qc * jnp.exp(Fc).astype(x.dtype)[..., None], h_in)
    y = (y_intra + y_inter).reshape(b, s, d_up)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(og)
    w_down = ctx.constrain(p["w_down"].astype(x.dtype), ("model", None))
    return x + y @ w_down


def mlstm_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray, state: jnp.ndarray):
    """x: (B, 1, d); state: (B, nh, hd, hd) fp32."""
    b = x.shape[0]
    d_up, nh, hd = _dims(cfg)
    h = rmsnorm(p["ln"], x)
    up = h @ p["w_up"].astype(x.dtype)
    v, og = jnp.split(up, 2, axis=-1)
    q = (h @ p["wq"].astype(x.dtype)).reshape(b, nh, hd)
    k = (h @ p["wk"].astype(x.dtype)).reshape(b, nh, hd) / math.sqrt(hd)
    v = v.reshape(b, nh, hd)
    gates = (h @ p["w_if"].astype(x.dtype)).astype(jnp.float32)
    ig, fg = jnp.split(gates[:, 0], 2, axis=-1)
    f = jnp.exp(jax.nn.log_sigmoid(fg))
    i = jnp.exp(jnp.minimum(ig, 0.0))
    state = state * f[:, :, None, None] + (
        i[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v).astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), state).astype(x.dtype)
    y = y.reshape(b, 1, d_up)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(og)
    return x + y @ p["w_down"].astype(x.dtype), state


# --------------------------------------------------------------- sLSTM

def slstm_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 3)
    dt = cfg.p_dtype
    return {
        "ln": rmsnorm_init(d, dt),
        "w_x": dense_init(ks[0], d, 4 * d, dt),             # i, f, z, o from input
        "w_h": (jax.random.normal(ks[1], (nh, hd, 4 * hd)) /
                math.sqrt(hd)).astype(dt),                  # block-diag recurrence
        "w_down": dense_init(ks[2], d, d, dt),
    }


def _slstm_cell(p, cfg, xt, hprev, cprev):
    """xt: (B, 4d) pre-projected input; hprev/cprev: (B, nh, hd) fp32."""
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    rec = jnp.einsum("bhd,hde->bhe", hprev.astype(xt.dtype), p["w_h"].astype(xt.dtype))
    gates = xt.reshape(xt.shape[0], nh, 4 * hd) + rec
    i, f, z, o = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    c = jax.nn.sigmoid(f) * cprev + jnp.exp(jnp.minimum(i, 0.0)) * jnp.tanh(z)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def slstm_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    hin = rmsnorm(p["ln"], x)
    xproj = hin @ p["w_x"].astype(x.dtype)                  # (B, S, 4d)

    def body(carry, xt):
        h, c = carry
        h, c = _slstm_cell(p, cfg, xt, h, c)
        return (h, c), h

    h0 = jnp.zeros((b, nh, hd), jnp.float32)
    (_, _), hs = jax.lax.scan(body, (h0, h0), xproj.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    return x + y @ p["w_down"].astype(x.dtype)


def slstm_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray, h, c):
    hin = rmsnorm(p["ln"], x)
    xproj = (hin @ p["w_x"].astype(x.dtype))[:, 0]
    h, c = _slstm_cell(p, cfg, xproj, h, c)
    b, d = x.shape[0], cfg.d_model
    y = h.reshape(b, 1, d).astype(x.dtype)
    return x + y @ p["w_down"].astype(x.dtype), h, c


# --------------------------------------------------------------- stack

def rounds_of(cfg: ArchConfig) -> Tuple[int, int]:
    every = cfg.slstm_every or cfg.n_layers + 1
    if every > cfg.n_layers:
        return 1, cfg.n_layers            # all mLSTM, one round
    return cfg.n_layers // every, every - 1


def init_params(key, cfg: ArchConfig) -> Params:
    n_rounds, m_per = rounds_of(cfg)
    kemb, km, ks_ = jax.random.split(key, 3)
    dt = cfg.p_dtype
    mk = jax.random.split(km, n_rounds * m_per).reshape(n_rounds, m_per, 2)
    p: Params = {
        "embed": dense_init(kemb, cfg.vocab, cfg.d_model, dt),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
        "mlstm": jax.vmap(jax.vmap(lambda k: mlstm_init(k, cfg)))(mk),
    }
    if cfg.slstm_every and cfg.slstm_every <= cfg.n_layers:
        sk = jax.random.split(ks_, n_rounds)
        p["slstm"] = jax.vmap(lambda k: slstm_init(k, cfg))(sk)
    return p


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            remat: bool = True) -> jnp.ndarray:
    x = _embed(params, cfg, tokens)
    has_s = "slstm" in params

    def round_body(x, xs):
        def m_body(x, mp):
            return mlstm_forward(mp, cfg, x), None
        x, _ = jax.lax.scan(m_body, x, xs["m"])
        if has_s:
            x = slstm_forward(xs["s"], cfg, x)
        return x, None

    if remat:
        round_body = tuning.remat_wrap(round_body)
    scanned = {"m": params["mlstm"]}
    if has_s:
        scanned["s"] = params["slstm"]
    x, _ = jax.lax.scan(round_body, x, scanned)
    return rmsnorm(params["ln_f"], x)


def loss_fn(params: Params, cfg: ArchConfig,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"])
    return chunked_xent(hidden, params["embed"], batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> Params:
    n_rounds, m_per = rounds_of(cfg)
    d_up, nh, hd = _dims(cfg)
    shd = cfg.d_model // cfg.n_heads
    cache = {"m_state": jnp.zeros((n_rounds, m_per, batch, nh, hd, hd), jnp.float32)}
    if cfg.slstm_every and cfg.slstm_every <= cfg.n_layers:
        cache["s_h"] = jnp.zeros((n_rounds, batch, cfg.n_heads, shd), jnp.float32)
        cache["s_c"] = jnp.zeros((n_rounds, batch, cfg.n_heads, shd), jnp.float32)
    return cache


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    x = _embed(params, cfg, tokens)
    has_s = "s_h" in cache

    def round_body(x, xs):
        def m_body(x, mxs):
            mp, st = mxs
            x, st = mlstm_decode(mp, cfg, x, st)
            return x, st
        x, mst = jax.lax.scan(m_body, x, (xs["mp"], xs["mst"]))
        out = {"mst": mst}
        if has_s:
            x, h, c = slstm_decode(xs["sp"], cfg, x, xs["sh"], xs["sc"])
            out["sh"], out["sc"] = h, c
        return x, out

    scanned = {"mp": params["mlstm"], "mst": cache["m_state"]}
    if has_s:
        scanned.update(sp=params["slstm"], sh=cache["s_h"], sc=cache["s_c"])
    x, outs = jax.lax.scan(round_body, x, scanned)
    x = rmsnorm(params["ln_f"], x)
    logits = logits_fn(params, cfg, x[:, 0])
    new_cache = {"m_state": outs["mst"]}
    if has_s:
        new_cache["s_h"], new_cache["s_c"] = outs["sh"], outs["sc"]
    return logits, new_cache
