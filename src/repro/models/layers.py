"""Shared neural building blocks for every architecture in the pool.

Everything is written against plain pytrees (dicts of jnp arrays) — no
flax/haiku dependency — so parameter sharding specs can be attached by name
pattern in ``parallel/sharding.py`` and models scan cleanly over stacked
layer parameters.

Conventions:
  * params are created in ``param_dtype`` (fp32 by default) and cast to
    ``dtype`` (bf16 on TPU) at use — the usual mixed-precision recipe;
  * attention uses blockwise (memory-efficient) softmax over query chunks so
    (B, H, S, S) score tensors are never materialized at 32k sequence;
  * decode paths take a KV cache laid out (B, S_max, n_kv, head_dim) and a
    scalar position.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import tuning
from ..parallel import ctx

Params = Dict[str, Any]

# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    return _normal(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    if tuning.get("act_bf16") and dt == jnp.bfloat16:
        # f32 only inside the variance reduction (fusion boundary is the
        # tiny (B,S,1) stat); the normalize/scale applies in bf16 — avoids
        # materializing any f32 copy of the residual stream.
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return x * inv * p["scale"].astype(dt)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    # sliding window size; None = full attention.  Per-layer local/global
    # selection is handled by the caller via the `window` argument override.
    window: Optional[int] = None


def attn_init(key, spec: AttnSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, h, kvh, hd = spec.d_model, spec.n_heads, spec.n_kv, spec.head_dim
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype).reshape(d, h, hd),
        "wk": dense_init(ks[1], d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wv": dense_init(ks[2], d, kvh * hd, dtype).reshape(d, kvh, hd),
        "wo": dense_init(ks[3], h * hd, d, dtype).reshape(h, hd, d),
    }
    if spec.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _qkv(p: Params, spec: AttnSpec, x: jnp.ndarray, positions: jnp.ndarray):
    dt = x.dtype
    # ZeRO-3: gather FSDP-sharded weights at use, to their TP-only layout
    # (one layer's weights live gathered at a time inside the layer scan)
    wq = ctx.constrain(p["wq"].astype(dt), (None, "model", None))
    wk = ctx.constrain(p["wk"].astype(dt), (None, "model", None))
    wv = ctx.constrain(p["wv"].astype(dt), (None, "model", None))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if spec.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = rope(q, positions, spec.rope_theta)
    k = rope(k, positions, spec.rope_theta)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Kv, D) -> (B, S, Kv*groups, D) by repeat (GQA share)."""
    if groups == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kv, groups, d)).reshape(b, s, kv * groups, d)


def attention(
    p: Params,
    spec: AttnSpec,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    window: Optional[int] = None,
    q_chunk: int = 512,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Blockwise-softmax multi-head attention (training / prefill path).

    Scans over query chunks; each step materializes only a
    (B, H, q_chunk, S) score tile.  ``window`` enables sliding-window
    (local) masking; ``cross_kv`` switches to encoder-decoder cross
    attention (no causal mask, externally supplied K/V).
    """
    b, s, d = x.shape
    spec_window = window if window is not None else spec.window
    if cross_kv is None:
        q, k, v = _qkv(p, spec, x, positions)
    else:
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        if spec.qk_norm:
            q = rmsnorm(p["q_norm"], q)
        k, v = cross_kv
    groups = spec.n_heads // spec.n_kv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / math.sqrt(spec.head_dim)
    kv_pos = jnp.arange(k.shape[1])

    q_chunk = min(q_chunk, s)
    n_chunks = max(1, s // q_chunk)
    pad = n_chunks * q_chunk != s
    if pad:  # ragged tail: fall back to a single chunk
        q_chunk, n_chunks = s, 1

    assert positions.ndim == 2, "positions must be (B, S)"
    qs = q.reshape(b, n_chunks, q_chunk, spec.n_heads, spec.head_dim)
    pos_chunks = positions.reshape(b, n_chunks, q_chunk)

    def one_chunk(q_i, pos_i):
        # q_i: (B, c, H, D); scores vs all keys: (B, H, c, S)
        scores = jnp.einsum("bchk,bshk->bhcs", q_i, k).astype(jnp.float32) * scale
        if cross_kv is None and spec.causal:
            cmask = pos_i[:, None, :, None] >= kv_pos[None, None, None, :]
            if spec_window is not None:
                cmask &= (pos_i[:, None, :, None]
                          - kv_pos[None, None, None, :] < spec_window)
            scores = jnp.where(cmask, scores, -1e30)
        out = jax.nn.softmax(scores, axis=-1).astype(q_i.dtype)
        return jnp.einsum("bhcs,bshk->bchk", out, v)

    if n_chunks == 1:
        o = one_chunk(qs[:, 0], pos_chunks[:, 0])[:, None]
    else:
        def body(_, xs):
            q_i, pos_i = xs
            return None, one_chunk(q_i, pos_i)
        _, o = jax.lax.scan(
            body, None,
            (qs.transpose(1, 0, 2, 3, 4), pos_chunks.transpose(1, 0, 2)),
        )
        o = o.transpose(1, 0, 2, 3, 4)
    o = o.reshape(b, s, spec.n_heads, spec.head_dim)
    wo = ctx.constrain(p["wo"].astype(o.dtype), ("model", None, None))
    return jnp.einsum("bshk,hkd->bsd", o, wo)


def attention_decode(
    p: Params,
    spec: AttnSpec,
    x: jnp.ndarray,             # (B, 1, d)
    cache_k: jnp.ndarray,       # (B, S_max, n_kv, D)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,           # scalar int32 — current position
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token decode with KV-cache append.

    Default path: dense reduction over the cache (XLA partitions it).  With
    the ``flash_decode`` tuning knob and an active mesh, the sequence-
    sharded cache is handled by an explicit shard_map: per-shard partial
    (max, num, den) softmax stats combined with two tiny psums — the
    flash-decoding pattern — so the cache is NEVER all-gathered.
    """
    mesh = ctx.current_mesh()
    if (tuning.get("flash_decode") and mesh is not None
            and _flash_applicable(x, cache_k, mesh)):
        return _attention_decode_flash(p, spec, x, cache_k, cache_v, pos,
                                       window, mesh)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, spec, x, positions)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    groups = spec.n_heads // spec.n_kv
    k = _repeat_kv(cache_k.astype(x.dtype), groups)
    v = _repeat_kv(cache_v.astype(x.dtype), groups)
    scale = 1.0 / math.sqrt(spec.head_dim)
    scores = jnp.einsum("bchk,bshk->bhcs", q, k).astype(jnp.float32) * scale
    kv_pos = jnp.arange(k.shape[1])
    mask = kv_pos[None, None, None, :] <= pos
    w = window if window is not None else spec.window
    if w is not None:
        mask &= kv_pos[None, None, None, :] > pos - w
    scores = jnp.where(mask, scores, -1e30)
    # numerically-stable softmax, written as separable (max, lse) so the
    # reduction re-associates across sequence shards:
    mx = jnp.max(scores, axis=-1, keepdims=True)
    ex = jnp.exp(scores - mx)
    den = jnp.sum(ex, axis=-1, keepdims=True)
    probs = (ex / den).astype(x.dtype)
    o = jnp.einsum("bhcs,bshk->bchk", probs, v)
    wo = ctx.constrain(p["wo"].astype(o.dtype), ("model", None, None))
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return out, cache_k, cache_v




def _flash_applicable(x, cache_k, mesh) -> bool:
    m = mesh.shape.get("model", 1)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return (cache_k.shape[1] % m == 0 and x.shape[0] % dp == 0
            and "model" in mesh.axis_names)


def _attention_decode_flash(p, spec, x, cache_k, cache_v, pos, window, mesh):
    """shard_map flash-decoding: cache stays sequence-sharded over `model`;
    each shard computes masked partial softmax stats; two psums of
    (B, H)-sized stats produce the exact softmax.  The token's new K/V is
    written only by the owning shard."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    s_max = cache_k.shape[1]
    m_sz = mesh.shape["model"]
    s_loc = s_max // m_sz
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, spec, x, positions)
    groups = spec.n_heads // spec.n_kv
    scale = 1.0 / math.sqrt(spec.head_dim)
    w = window if window is not None else spec.window

    def body(q_l, kn_l, vn_l, ck_l, cv_l):
        # q_l: (B_l, 1, H, D); ck_l: (B_l, s_loc, K, D)
        sidx = jax.lax.axis_index("model")
        base = sidx * s_loc
        # owning shard writes the new token's K/V at local offset
        off = jnp.clip(pos - base, 0, s_loc - 1)
        owns = (pos >= base) & (pos < base + s_loc)
        upd_k = jax.lax.dynamic_update_slice(
            ck_l, kn_l.astype(ck_l.dtype), (0, off, 0, 0))
        upd_v = jax.lax.dynamic_update_slice(
            cv_l, vn_l.astype(cv_l.dtype), (0, off, 0, 0))
        ck_l = jnp.where(owns, upd_k, ck_l)
        cv_l = jnp.where(owns, upd_v, cv_l)
        k = _repeat_kv(ck_l.astype(q_l.dtype), groups)
        v = _repeat_kv(cv_l.astype(q_l.dtype), groups)
        kv_pos = base + jnp.arange(s_loc)
        scores = jnp.einsum("bchk,bshk->bhcs", q_l * jnp.asarray(scale, q_l.dtype), k,
                            preferred_element_type=jnp.float32)
        mask = kv_pos[None, None, None, :] <= pos
        if w is not None:
            mask &= kv_pos[None, None, None, :] > pos - w
        scores = jnp.where(mask, scores, -1e30)
        mx_l = jnp.max(scores, axis=-1)                      # (B,H,1)
        ex = jnp.exp(scores - mx_l[..., None])
        den_l = jnp.sum(ex, axis=-1)
        num_l = jnp.einsum("bhcs,bshk->bchk", ex.astype(q_l.dtype), v)
        # exact combine: rescale by exp(mx_l - global max), then psum
        mx_g = jax.lax.pmax(mx_l, "model")
        corr = jnp.exp(mx_l - mx_g)                          # (B,H,1)
        num = jax.lax.psum(
            num_l * jnp.swapaxes(corr, 1, 2)[..., None].astype(num_l.dtype),
            "model")
        den = jax.lax.psum(den_l * corr, "model")
        o = num / jnp.swapaxes(den, 1, 2)[..., None].astype(num.dtype)
        return o, ck_l, cv_l

    dps = dp if dp else None
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dps, None, None, None), P(dps, None, None, None),
                  P(dps, None, None, None), P(dps, "model", None, None),
                  P(dps, "model", None, None)),
        out_specs=(P(dps, None, None, None), P(dps, "model", None, None),
                   P(dps, "model", None, None)),
        check_rep=False,
    )
    o, cache_k, cache_v = fn(q, k_new, v_new, cache_k, cache_v)
    o = o.reshape(b, 1, spec.n_heads, spec.head_dim)
    wo = ctx.constrain(p["wo"].astype(o.dtype), ("model", None, None))
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return out, cache_k, cache_v


# --------------------------------------------------------------------------
# MLP (SwiGLU)
# --------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, dtype, variant: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    if variant == "gelu":
        return {
            "w_up": dense_init(ks[1], d, ff, dtype),
            "w_down": dense_init(ks[2], ff, d, dtype),
        }
    return {
        "w_gate": dense_init(ks[0], d, ff, dtype),
        "w_up": dense_init(ks[1], d, ff, dtype),
        "w_down": dense_init(ks[2], ff, d, dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    w_up = ctx.constrain(p["w_up"].astype(dt), (None, "model"))
    w_down = ctx.constrain(p["w_down"].astype(dt), ("model", None))
    if "w_gate" in p:  # SwiGLU
        w_gate = ctx.constrain(p["w_gate"].astype(dt), (None, "model"))
        gate = jax.nn.silu(x @ w_gate)
        return (gate * (x @ w_up)) @ w_down
    u = x @ w_up
    if tuning.get("act_bf16") and u.dtype == jnp.bfloat16:
        # dtype-clean tanh gelu (python-float constants stay weakly typed)
        h = 0.5 * u * (1.0 + jnp.tanh(0.7978845608 * (u + 0.044715 * u * u * u)))
    else:
        h = jax.nn.gelu(u)
    return h @ w_down


# --------------------------------------------------------------------------
# vocab-sharded, sequence-chunked softmax cross entropy
# --------------------------------------------------------------------------


@jax.custom_vjp
def _ct_cast_bf16(x):
    """Identity whose incoming cotangent is cast to bf16 — pins the whole
    backward residual chain to bf16 instead of the f32 the loss emits."""
    return x


def _ct_fwd(x):
    return x, None


def _ct_bwd(_, ct):
    return (ct.astype(jnp.bfloat16),)


_ct_cast_bf16.defvjp(_ct_fwd, _ct_bwd)


def chunked_xent(
    hidden: jnp.ndarray,      # (B, S, d)
    emb: jnp.ndarray,         # (V, d) — tied output embedding (vocab-sharded)
    labels: jnp.ndarray,      # (B, S) int32
    chunk: int = 256,
    z_loss: float = 1e-4,
) -> jnp.ndarray:
    """Mean next-token cross entropy without materializing (B, S, V).

    Scans over sequence chunks; within a chunk the (B, c, V) logits live
    only transiently and are vocab-sharded under pjit.  The small z-loss
    regularizes the softmax normalizer (production trick — keeps logits
    bounded in bf16 and gives XLA a second use of the lse so it fuses).
    """
    if tuning.get("grad_bf16") and hidden.dtype == jnp.bfloat16:
        hidden = _ct_cast_bf16(hidden)
    b, s, d = hidden.shape
    chunk = min(tuning.get("xent_chunk"), s)
    n = max(1, s // chunk)
    if n * chunk != s:
        chunk, n = s, 1
    emb = ctx.constrain(emb, ("model", None))
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: (B,c,V) never stored
    def body(carry, xs):
        h, l = xs
        logits = (h @ emb.astype(h.dtype).T).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = lse - true
        zl = z_loss * lse * lse
        return carry + jnp.sum(nll + zl), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    return total / (b * s)
