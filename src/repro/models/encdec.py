"""Encoder-decoder transformer backbone (whisper-base).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, T_enc, frontend_dim); the model
projects them to d_model.  Encoder = bidirectional self-attention stack;
decoder = causal self-attention + cross-attention.  RoPE is used in both
stacks (backbone fidelity only — whisper's learned/sinusoidal positions
are a frontend detail orthogonal to the systems work here).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import tuning
from ..configs.base import ArchConfig
from .layers import (
    AttnSpec, attention, attention_decode, attn_init, chunked_xent,
    dense_init, mlp, mlp_init, rmsnorm, rmsnorm_init,
)
from .transformer import attn_spec, logits_fn

Params = Dict[str, Any]


def _cross_spec(cfg: ArchConfig) -> AttnSpec:
    s = attn_spec(cfg)
    return AttnSpec(**{**s.__dict__, "causal": False})


def init_enc_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = cfg.p_dtype
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_init(ks[0], _cross_spec(cfg), dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.mlp_variant),
    }


def init_dec_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.p_dtype
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_init(ks[0], attn_spec(cfg), dt),
        "ln_x": rmsnorm_init(cfg.d_model, dt),
        "xattn": attn_init(ks[1], _cross_spec(cfg), dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt, cfg.mlp_variant),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    ke, kd, kemb, kfr = jax.random.split(key, 4)
    dt = cfg.p_dtype
    ek = jax.random.split(ke, cfg.encoder_layers)
    dk = jax.random.split(kd, cfg.n_layers)
    return {
        "frontend_proj": dense_init(kfr, cfg.frontend_dim, cfg.d_model, dt),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(ek),
        "enc_ln_f": rmsnorm_init(cfg.d_model, dt),
        "embed": dense_init(kemb, cfg.vocab, cfg.d_model, dt),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dk),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
    }


def encode(params: Params, cfg: ArchConfig, frames: jnp.ndarray,
           remat: bool = True) -> jnp.ndarray:
    """frames: (B, T_enc, frontend_dim) stub embeddings -> (B, T_enc, d)."""
    x = (frames.astype(cfg.activation_dtype)
         @ params["frontend_proj"].astype(cfg.activation_dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    spec = _cross_spec(cfg)

    def body(x, layer_p):
        h = rmsnorm(layer_p["ln1"], x)
        x = x + attention(layer_p["attn"], spec, h, positions)
        x = x + mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
        return x, None

    if remat:
        body = tuning.remat_wrap(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_ln_f"], x)


def _cross_kv(layer_p: Params, cfg: ArchConfig, enc_out: jnp.ndarray):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["xattn"]["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["xattn"]["wv"].astype(dt))
    return k, v


def decode_train(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray, remat: bool = True) -> jnp.ndarray:
    b, s = tokens.shape
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    self_spec = attn_spec(cfg)
    x_spec = _cross_spec(cfg)

    def body(x, layer_p):
        h = rmsnorm(layer_p["ln1"], x)
        x = x + attention(layer_p["attn"], self_spec, h, positions)
        h = rmsnorm(layer_p["ln_x"], x)
        kv = _cross_kv(layer_p, cfg, enc_out)
        x = x + attention(layer_p["xattn"], x_spec, h, positions, cross_kv=kv)
        x = x + mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
        return x, None

    if remat:
        body = tuning.remat_wrap(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return rmsnorm(params["ln_f"], x)


def loss_fn(params: Params, cfg: ArchConfig,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    enc_out = encode(params, cfg, batch["frames"])
    hidden = decode_train(params, cfg, batch["tokens"], enc_out)
    return chunked_xent(hidden, params["embed"], batch["labels"])


# ------------------------------------------------------------------ serving

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> Params:
    dt = dtype or cfg.activation_dtype
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt),
        # cross-attention KV, precomputed once from the encoder output
        "xk": jnp.zeros(
            (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt),
        "xv": jnp.zeros(
            (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dt),
    }


def prefill_cross(params: Params, cfg: ArchConfig, enc_out: jnp.ndarray,
                  cache: Params) -> Params:
    def per_layer(layer_p):
        return _cross_kv(layer_p, cfg, enc_out)
    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "xk": xk, "xv": xv}


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    self_spec = attn_spec(cfg)
    groups = cfg.n_heads // cfg.n_kv_heads

    def body(x, xs):
        layer_p, ck, cv, xk, xv = xs
        h = rmsnorm(layer_p["ln1"], x)
        h, ck, cv = attention_decode(layer_p["attn"], self_spec, h, ck, cv, pos)
        x = x + h
        # cross attention over the (static) encoder KV
        h = rmsnorm(layer_p["ln_x"], x)
        dt = h.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, layer_p["xattn"]["wq"].astype(dt))
        from .layers import _repeat_kv
        k = _repeat_kv(xk.astype(dt), groups)
        v = _repeat_kv(xv.astype(dt), groups)
        scores = jnp.einsum("bchk,bshk->bhcs", q, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(self_spec.head_dim))
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        o = jnp.einsum("bhcs,bshk->bchk", probs, v)
        x = x + jnp.einsum("bshk,hkd->bsd", o, layer_p["xattn"]["wo"].astype(dt))
        x = x + mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = rmsnorm(params["ln_f"], x)
    logits = logits_fn(params, cfg, x[:, 0])
    return logits, {**cache, "k": ck, "v": cv}
