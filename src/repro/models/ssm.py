"""Mamba2 (SSD) blocks and the zamba2-style hybrid stack.

Training uses the chunked *state-space dual* (SSD) form of Mamba2: the
sequence is split into chunks; within a chunk the output is a masked
quadratic (attention-like) contraction, across chunks a short lax.scan
carries the (H, P, N) state — O(S) work, parallel within chunks, and a
compile-friendly two-level loop instead of a length-S scan.

Decode carries the recurrent state explicitly: O(1) per token — this is
what makes the hybrid/ssm archs eligible for the 524k long-context shape.

zamba2: a stack of Mamba2 blocks with one *shared* GQA attention block
applied every `attn_every` layers (parameters shared across applications,
as in the paper) — the shared block's params live outside the scanned
stack, and the scan body applies it conditionally on the layer index.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import tuning
from ..configs.base import ArchConfig
from ..parallel import ctx
from .layers import (
    attention_decode, attn_init, chunked_xent, dense_init, mlp, mlp_init,
    rmsnorm, rmsnorm_init,
)
from .transformer import _attention_dyn, _embed, attn_spec, logits_fn

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def mamba_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, nh, ns = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = cfg.p_dtype
    return {
        # fused input projection -> [x, z, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * d_in + 2 * ns + nh, dt),
        "w_out": dense_init(ks[1], d_in, d, dt),
        "conv": (jax.random.normal(ks[2], (4, d_in)) * 0.2).astype(dt),
        "A_log": jnp.zeros((nh,), jnp.float32),           # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": rmsnorm_init(d_in, dt),
    }


def _mamba_proj(p: Params, cfg: ArchConfig, x: jnp.ndarray):
    d_in, nh, ns = mamba_dims(cfg)
    dt_ = x.dtype
    w_in = ctx.constrain(p["w_in"].astype(dt_), (None, "model"))
    zxbcdt = x @ w_in
    xs, z, B, C, dtv = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ns, 2 * d_in + 2 * ns], axis=-1)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])  # (..., nh)
    return xs, z, B, C, dtv


def _causal_conv(p: Params, xs: jnp.ndarray) -> jnp.ndarray:
    """Depthwise width-4 causal conv over sequence (B, S, d_in)."""
    w = p["conv"].astype(xs.dtype)          # (4, d_in)
    pad = jnp.pad(xs, ((0, 0), (3, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * w[i] for i in range(4))
    return jax.nn.silu(out)


def mamba_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                  chunk: int = 256) -> jnp.ndarray:
    """Chunked SSD forward. x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    d_in, nh, ns = mamba_dims(cfg)
    hp = d_in // nh
    xs, z, B, C, dtv = _mamba_proj(p, cfg, x)
    xs = _causal_conv(p, xs)
    xh = xs.reshape(b, s, nh, hp)
    A = -jnp.exp(p["A_log"])                                   # (nh,)
    dA = dtv * A                                               # (B, S, nh) <= 0

    chunk = min(chunk, s)
    nc = max(1, s // chunk)
    if nc * chunk != s:
        chunk, nc = s, 1
    c = chunk

    def resh(t, feat):
        return t.reshape(b, nc, c, *feat)

    xh_c = resh(xh, (nh, hp))
    B_c = resh(B, (ns,))
    C_c = resh(C, (ns,))
    dA_c = resh(dA, (nh,))
    dt_c = resh(dtv, (nh,))

    # cumulative within-chunk log decay: L[i] = sum_{j<=i} dA
    seg = jnp.cumsum(dA_c, axis=2)                             # (B, nc, c, nh)

    # ---- intra-chunk (quadratic) term:
    # Y_intra[i] = sum_{j<=i} C_i.B_j * exp(seg_i - seg_j) * dt_j * x_j
    CB = jnp.einsum("bnis,bnjs->bnij", C_c, B_c)   # (B,nc,c,c); n = chunk idx
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nc,c,c,nh): i-j
    causal = jnp.tril(jnp.ones((c, c), bool))
    gate = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0)
    M = (CB[..., None] * gate * dt_c[:, :, None, :, :]).astype(x.dtype)  # (B,nc,i,j,nh)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", M, xh_c)

    # ---- chunk states: S_n = sum_j exp(seg_end - seg_j) dt_j B_j x_j^T
    end = seg[:, :, -1:, :]                                    # (B,nc,1,nh)
    w_j = (jnp.exp(end - seg) * dt_c).astype(x.dtype)          # (B,nc,c,nh)
    states = jnp.einsum("bnjh,bnjs,bnjhp->bnhsp", w_j, B_c,
                        xh_c)                                  # (B,nc,nh,ns,hp)

    # ---- inter-chunk scan: h_{n} = exp(sum dA_n) h_{n-1} + S_n
    chunk_decay = jnp.exp(end[:, :, 0, :])                     # (B,nc,nh)

    def scan_body(hprev, xs_):
        st, dec = xs_
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, nh, ns, hp), jnp.float32)
    _, h_in = jax.lax.scan(
        scan_body, h0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4).astype(x.dtype)       # state entering chunk n

    # ---- inter-chunk contribution: Y_inter[i] = C_i . (exp(seg_i) h_in)
    y_inter = jnp.einsum("bnis,bnhsp,bnih->bnihp",
                         C_c, h_in, jnp.exp(seg).astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, s, nh, hp)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    w_out = ctx.constrain(p["w_out"].astype(x.dtype), ("model", None))
    return y @ w_out


def mamba_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                 state: jnp.ndarray, conv_state: jnp.ndarray):
    """O(1) recurrent step. x: (B, 1, d); state: (B, nh, ns, hp);
    conv_state: (B, 4, d_in) rolling window."""
    b = x.shape[0]
    d_in, nh, ns = mamba_dims(cfg)
    hp = d_in // nh
    xs, z, B, C, dtv = _mamba_proj(p, cfg, x)
    conv_state = jnp.concatenate([conv_state[:, 1:], xs], axis=1)  # (B,4,d_in)
    xs = jax.nn.silu(
        jnp.einsum("bwd,wd->bd", conv_state, p["conv"].astype(x.dtype)))[:, None]
    xh = xs.reshape(b, nh, hp)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtv[:, 0] * A)                                # (B, nh)
    Bv = B[:, 0]                                               # (B, ns)
    upd = jnp.einsum("bh,bs,bhp->bhsp", dtv[:, 0].astype(x.dtype), Bv, xh)
    state = state * dA[:, :, None, None].astype(state.dtype) + upd.astype(state.dtype)
    y = jnp.einsum("bs,bhsp->bhp", C[:, 0], state.astype(x.dtype))
    y = y + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, d_in)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    w_out = ctx.constrain(p["w_out"].astype(x.dtype), ("model", None))
    return y @ w_out, state, conv_state


# --------------------------------------------------------------------------
# zamba2 hybrid stack
# --------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig) -> Params:
    # zamba2-style: the per-layer block is Mamba2 only; the MLP lives in the
    # parameter-shared transformer block applied every `attn_every` layers.
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg.p_dtype),
        "mamba": mamba_init(key, cfg),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    kemb, klayers, kattn = jax.random.split(key, 3)
    dt = cfg.p_dtype
    lk = jax.random.split(klayers, cfg.n_layers)
    p: Params = {
        "embed": dense_init(kemb, cfg.vocab, cfg.d_model, dt),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(lk),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.attn_every:
        ka, km = jax.random.split(kattn)
        p["shared_attn"] = attn_init(ka, attn_spec(cfg), dt)
        p["shared_ln"] = rmsnorm_init(cfg.d_model, dt)
        p["shared_ln2"] = rmsnorm_init(cfg.d_model, dt)
        p["shared_mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, dt, cfg.mlp_variant)
    return p


def _chunk_layout(cfg: ArchConfig):
    """(n_outer, inner) chunking: shared attn applied once per outer chunk.

    Expressed as two nested scans (no lax.cond) so static HLO analysis is
    exact and the shared block's cost appears exactly n_outer times.
    """
    every = cfg.attn_every
    L = cfg.n_layers
    if every and every <= L and L % every == 0:
        return L // every, every
    return 0, L  # no shared attention


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            remat: bool = True, q_chunk: int = 512) -> jnp.ndarray:
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    spec = attn_spec(cfg)
    win = jnp.int32(cfg.sliding_window or 0)
    n_outer, inner = _chunk_layout(cfg)

    def mamba_block(x, lp):
        h = rmsnorm(lp["ln1"], x)
        return x + mamba_forward(lp["mamba"], cfg, h), None

    if n_outer == 0:
        body = tuning.remat_wrap(mamba_block) if remat else mamba_block
        x, _ = jax.lax.scan(body, x, params["layers"])
        return rmsnorm(params["ln_f"], x)

    layers = jax.tree_util.tree_map(
        lambda a: a.reshape(n_outer, inner, *a.shape[1:]), params["layers"])

    def outer(x, chunk_p):
        x, _ = jax.lax.scan(mamba_block, x, chunk_p)
        h = rmsnorm(params["shared_ln"], x)
        x = x + _attention_dyn(params["shared_attn"], spec, h, positions,
                               win, q_chunk)
        x = x + mlp(params["shared_mlp"], rmsnorm(params["shared_ln2"], x))
        return x, None

    if remat:
        outer = tuning.remat_wrap(outer)
    x, _ = jax.lax.scan(outer, x, layers)
    return rmsnorm(params["ln_f"], x)


def loss_fn(params: Params, cfg: ArchConfig,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"])
    return chunked_xent(hidden, params["embed"], batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> Params:
    d_in, nh, ns = mamba_dims(cfg)
    hp = d_in // nh
    dt = dtype or cfg.activation_dtype
    cache: Params = {
        "ssm": jnp.zeros((cfg.n_layers, batch, nh, ns, hp), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, 4, d_in), dt),
    }
    if _chunk_layout(cfg)[0]:
        # shared attention block: one rolling KV cache (window-bounded when a
        # sliding window is configured; otherwise full-depth)
        wlen = min(max_seq, cfg.sliding_window or max_seq)
        cache["k"] = jnp.zeros((batch, wlen, cfg.n_kv_heads, cfg.hd), dt)
        cache["v"] = jnp.zeros((batch, wlen, cfg.n_kv_heads, cfg.hd), dt)
    return cache


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    x = _embed(params, cfg, tokens)
    spec = attn_spec(cfg)
    n_outer, inner = _chunk_layout(cfg)

    def mamba_block(x, xs):
        lp, st, cs = xs
        h = rmsnorm(lp["ln1"], x)
        y, st, cs = mamba_decode(lp["mamba"], cfg, h, st, cs)
        return x + y, (st, cs)

    if n_outer == 0:
        x, (st, cs) = jax.lax.scan(
            mamba_block, x, (params["layers"], cache["ssm"], cache["conv"]))
        x = rmsnorm(params["ln_f"], x)
        return logits_fn(params, cfg, x[:, 0]), {"ssm": st, "conv": cs}

    resh = lambda a: a.reshape(n_outer, inner, *a.shape[1:])
    layers = jax.tree_util.tree_map(resh, params["layers"])
    ssm_c = resh(cache["ssm"])
    conv_c = resh(cache["conv"])
    wlen = cache["k"].shape[1]

    def outer(carry, xs):
        x, ck, cv = carry
        lp, st_in, cs_in = xs
        x, (st, cs) = jax.lax.scan(mamba_block, x, (lp, st_in, cs_in))
        h = rmsnorm(params["shared_ln"], x)
        wpos = jnp.minimum(pos, wlen - 1)  # saturating rolling window
        h, ck, cv = attention_decode(params["shared_attn"], spec, h, ck, cv,
                                     wpos)
        x = x + h
        x = x + mlp(params["shared_mlp"], rmsnorm(params["shared_ln2"], x))
        return (x, ck, cv), (st, cs)

    (x, ck, cv), (st, cs) = jax.lax.scan(
        outer, (x, cache["k"], cache["v"]), (layers, ssm_c, conv_c))
    x = rmsnorm(params["ln_f"], x)
    logits = logits_fn(params, cfg, x[:, 0])
    unsh = lambda a: a.reshape(cfg.n_layers, *a.shape[2:])
    return logits, {"ssm": unsh(st), "conv": unsh(cs), "k": ck, "v": cv}
