"""Mixture-of-Experts transformer (deepseek-moe-16b, kimi-k2-1t).

Fine-grained MoE with shared experts, implemented with the
capacity-bucketed sort-dispatch pattern:

  1. router (fp32) -> top-k experts per token, renormalized weights;
  2. flatten (token, slot) pairs, sort by expert id (stable), rank within
     expert, drop beyond capacity C = ceil(T*k/E * capacity_factor);
  3. scatter tokens into an (E, C, d) buffer — under pjit this re-shards
     from token-sharded to expert-sharded layout (the all_to_all);
  4. batched expert SwiGLU einsum (E sharded over the `model` axis = EP);
  5. gather back, unsort, combine with router weights;
  6. shared experts run as an always-on dense MLP in parallel.

The dispatch tensors are O(T*k*d) — no (T, E, C) one-hots — so the pattern
scales to kimi's 384 experts at trillion-parameter size.  A Switch-style
load-balance auxiliary loss is returned alongside.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import tuning
from ..configs.base import ArchConfig
from ..parallel import ctx
from .layers import (
    attention_decode, attn_init, chunked_xent, dense_init, mlp, mlp_init,
    rmsnorm, rmsnorm_init,
)
from .transformer import (
    _attention_dyn, _embed, attn_spec, init_cache, layer_windows, logits_fn,
)

Params = Dict[str, Any]


def moe_ffn_init(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = cfg.p_dtype
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * scale_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * scale_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, ff)) * scale_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, ff, d)) * scale_out).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts, dt)
    return p


def moe_ffn(p: Params, cfg: ArchConfig,
            x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    With an active mesh this takes the GShard-style shard_map path: local
    dispatch per data shard, explicit all_to_all over `model` (EP), local
    expert matmuls, reverse all_to_all, local combine.  Without a mesh
    (CPU smoke tests) the single-device dispatch below runs unchanged.
    """
    mesh = ctx.current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        return _moe_ffn_shardmap(p, cfg, x, mesh)
    return _moe_ffn_local(p, cfg, x)


def _moe_ffn_local(p: Params, cfg: ArchConfig,
                   x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                                  # (T, k)
    topv = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)

    # ---- load-balance aux (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                               # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- sort-dispatch
    flat_e = topi.reshape(-1)                                             # (T*k,)
    flat_w = topv.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = rank < cap
    rank_c = jnp.minimum(rank, cap - 1)

    gathered = xf[flat_tok[order]] * keep[:, None].astype(xf.dtype)
    gathered = ctx.constrain(gathered, (ctx.DP, None))
    buf = jnp.zeros((e, cap, d), xf.dtype).at[se, rank_c].set(
        gathered, mode="drop")                                            # (E, C, d)
    # EP x DP: experts over `model`, capacity slots over the data axes —
    # the reshard from token layout to (E, C) layout is the all_to_all.
    buf = ctx.constrain(buf, ("model", ctx.DP, None))

    # ---- expert SwiGLU (EP: E sharded over `model`)
    dt = xf.dtype
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"].astype(dt))
    out_buf = ctx.constrain(out_buf, ("model", ctx.DP, None))

    # ---- return + combine
    vals = out_buf[se, rank_c] * keep[:, None].astype(dt)                 # (T*k, d)
    vals = ctx.constrain(vals, (ctx.DP, None))
    contrib = jnp.zeros((t, d), dt).at[flat_tok[order]].add(
        vals * flat_w[order, None].astype(dt))
    contrib = ctx.constrain(contrib, (ctx.DP, None))
    if "shared" in p:
        contrib = contrib + mlp(p["shared"], xf)
    return contrib.reshape(b, s, d), aux


def _moe_ffn_shardmap(p: Params, cfg: ArchConfig, x: jnp.ndarray, mesh
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-pattern expert parallelism with explicit collectives.

    Tokens are data-sharded (replicated over `model`); experts are sharded
    over `model`.  Each shard dispatches its local tokens into an
    (E, C_local, d) capacity buffer, all_to_all's it so each device holds
    the slots of its own E/M experts, runs the expert SwiGLU locally, and
    reverses the exchange.  FSDP-sharded expert weights are all-gathered at
    entry by shard_map's in_specs (ZeRO-3 semantics)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    m_sz = mesh.shape["model"]
    e_l = e // m_sz
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_sz = 1
    for a in dp:
        dp_sz *= mesh.shape[a]
    b_l = max(1, b // dp_sz)
    t_l = b_l * s
    # Activations are replicated across `model`; each model-peer dispatches
    # its own 1/M slice of the local tokens (all_gather rebuilds the row at
    # the end).  Tiny decode batches skip the slicing (redundant dispatch is
    # cheaper than a ragged slice).
    slice_tokens = t_l % m_sz == 0 and t_l >= m_sz
    t_loc = t_l // m_sz if slice_tokens else t_l
    cf = tuning.get("capacity_factor") or cfg.capacity_factor
    if t_loc * k <= 512:
        cap = t_loc * k                     # decode: no-drop tiny buffer
    else:
        cap = int(math.ceil(t_loc * k / e * cf))
        cap = max(8, -(-cap // 8) * 8)

    def body(xl, router, wg, wu, wd):
        # xl: (b_l, s, d); wg/wu/wd: (e_l, ...) local experts
        xf = xl.reshape(t_l, d)
        if slice_tokens:
            midx = jax.lax.axis_index("model")
            xf = jax.lax.dynamic_slice_in_dim(xf, midx * t_loc, t_loc, axis=0)
        tl = t_loc
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, k)
        topv = topv / (topv.sum(axis=-1, keepdims=True) + 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (tl * k)
        aux = e * jnp.sum(me * ce)
        aux_axes = dp + (("model",) if slice_tokens else ())
        if aux_axes:
            aux = jax.lax.pmean(aux, axis_name=aux_axes)

        flat_e = topi.reshape(-1)
        flat_w = topv.reshape(-1)
        flat_tok = jnp.arange(tl * k, dtype=jnp.int32) // k
        order = jnp.argsort(flat_e, stable=True)
        se = flat_e[order]
        starts = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = jnp.arange(tl * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
        keep = rank < cap
        rank_c = jnp.minimum(rank, cap - 1)
        gathered = xf[flat_tok[order]] * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((e, cap, d), xf.dtype).at[se, rank_c].set(
            gathered, mode="drop")
        # ---- dispatch a2a over the model axis (split==concat so the VJP is
        # the mirror-image all_to_all): block j -> peer j, receive block m
        buf = buf.reshape(m_sz, e_l, cap, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=False)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_l, m_sz * cap, d)
        dt = xf.dtype
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt)))
        up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
        out_buf = jnp.einsum("ecf,efd->ecd", gate * up, wd.astype(dt))
        # ---- return a2a: (e_l, M, C, d) -> (M, e_l, C, d) -> (E, C, d)
        out_buf = out_buf.reshape(e_l, m_sz, cap, d).transpose(1, 0, 2, 3)
        out_buf = jax.lax.all_to_all(out_buf, "model", split_axis=0,
                                     concat_axis=0, tiled=False)
        out_buf = out_buf.reshape(e, cap, d)
        vals = out_buf[se, rank_c] * keep[:, None].astype(dt)
        contrib = jnp.zeros((tl, d), dt).at[flat_tok[order]].add(
            vals * flat_w[order, None].astype(dt))
        if slice_tokens:  # rebuild the full data-row (replicated over model)
            contrib = jax.lax.all_gather(contrib, "model", axis=0, tiled=True)
        return contrib.reshape(xl.shape), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(), P("model",),
                  P("model",), P("model",)),
        out_specs=(P(dp if dp else None, None, None), P()),
        check_rep=False,
    )
    out, aux = fn(x, p["router"].astype(jnp.float32),
                  p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        xf = x.reshape(b * s, d)
        out = out + mlp(p["shared"], xf).reshape(b, s, d)
    return out, aux


def init_moe_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.p_dtype
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_init(ks[0], attn_spec(cfg), dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "moe": moe_ffn_init(ks[1], cfg),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    from .transformer import init_layer  # dense first block(s)

    kemb, kdense, kmoe, kfin = jax.random.split(key, 4)
    dt = cfg.p_dtype
    n_moe = cfg.n_layers - cfg.first_dense_layers
    p: Params = {
        "embed": dense_init(kemb, cfg.vocab, cfg.d_model, dt),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.first_dense_layers:
        dk = jax.random.split(kdense, cfg.first_dense_layers)
        p["dense_layers"] = jax.vmap(lambda k: init_layer(k, cfg))(dk)
    mk = jax.random.split(kmoe, n_moe)
    p["moe_layers"] = jax.vmap(lambda k: init_moe_layer(k, cfg))(mk)
    return p


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            q_chunk: int = 512, remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    spec = attn_spec(cfg)
    zero_win = jnp.int32(0)

    if cfg.first_dense_layers:
        def dense_body(x, layer_p):
            h = rmsnorm(layer_p["ln1"], x)
            h = _attention_dyn(layer_p["attn"], spec, h, positions, zero_win, q_chunk)
            x = x + h
            x = x + mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
            return x, None
        if remat:
            dense_body = tuning.remat_wrap(dense_body)
        x, _ = jax.lax.scan(dense_body, x, params["dense_layers"])

    def moe_body(carry, layer_p):
        x, aux = carry
        h = rmsnorm(layer_p["ln1"], x)
        h = _attention_dyn(layer_p["attn"], spec, h, positions, zero_win, q_chunk)
        x = x + h
        h, a = moe_ffn(layer_p["moe"], cfg, rmsnorm(layer_p["ln2"], x))
        x = x + h
        if tuning.get("seq_shard_mlp"):
            x = ctx.constrain(x, (ctx.DP, "model", None))
        return (x, aux + a), None

    if remat:
        moe_body = tuning.remat_wrap(moe_body)
    (x, aux), _ = jax.lax.scan(moe_body, (x, jnp.float32(0.0)), params["moe_layers"])
    return rmsnorm(params["ln_f"], x), aux / max(1, cfg.n_layers)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            q_chunk: int = 512, aux_weight: float = 0.01) -> jnp.ndarray:
    hidden, aux = forward(params, cfg, batch["tokens"], q_chunk=q_chunk)
    emb = params["embed"]
    return chunked_xent(hidden, emb, batch["labels"]) + aux_weight * aux


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One-token MoE decode; caches are (L, B, S, Kv, D) across *all* layers
    (dense first, then MoE layers, in order)."""
    x = _embed(params, cfg, tokens)
    spec = attn_spec(cfg)
    nd = cfg.first_dense_layers
    ck_all, cv_all = cache["k"], cache["v"]

    new_k, new_v = [], []
    if nd:
        def dense_body(x, xs):
            layer_p, ck, cv = xs
            h = rmsnorm(layer_p["ln1"], x)
            h, ck, cv = attention_decode(layer_p["attn"], spec, h, ck, cv, pos)
            x = x + h
            x = x + mlp(layer_p["mlp"], rmsnorm(layer_p["ln2"], x))
            return x, (ck, cv)
        x, (dk, dv) = jax.lax.scan(
            dense_body, x, (params["dense_layers"], ck_all[:nd], cv_all[:nd]))
        new_k.append(dk); new_v.append(dv)

    def moe_body(x, xs):
        layer_p, ck, cv = xs
        h = rmsnorm(layer_p["ln1"], x)
        h, ck, cv = attention_decode(layer_p["attn"], spec, h, ck, cv, pos)
        x = x + h
        h, _ = moe_ffn(layer_p["moe"], cfg, rmsnorm(layer_p["ln2"], x))
        return x + h, (ck, cv)

    x, (mk, mv) = jax.lax.scan(
        moe_body, x, (params["moe_layers"], ck_all[nd:], cv_all[nd:]))
    new_k.append(mk); new_v.append(mv)
    x = rmsnorm(params["ln_f"], x)
    logits = logits_fn(params, cfg, x[:, 0])
    return logits, {"k": jnp.concatenate(new_k), "v": jnp.concatenate(new_v)}
