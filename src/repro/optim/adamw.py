"""Sharded AdamW with optional reduced-precision state + grad clipping.

Pure-function optimizer (init/update) over parameter pytrees.  Optimizer
state inherits each parameter's PartitionSpec (m, v are elementwise), so
ZeRO-style sharding falls out of the parameter FSDP specs for free.

``state_dtype="bfloat16"`` halves optimizer memory — required to fit
kimi-k2 (1T params) on 512 chips; the update math still runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    sdt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=sdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0.0)))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
           ) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule(cfg, step.astype(jnp.float32))
    sdt = jnp.dtype(cfg.state_dtype)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
