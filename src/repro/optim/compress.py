"""Gradient compression with error feedback (int8 row-scaled quantization).

For cross-pod gradient synchronization the wire, not HBM, is the
bottleneck (the `pod` axis rides DCI, ~an order of magnitude slower than
ICI).  Quantizing the pod-level all-reduce payload to int8 cuts that
traffic 4x vs fp32 / 2x vs bf16; the residual (quantization error) is fed
back into the next step's gradient so the *accumulated* update is unbiased
(error-feedback SGD, Seide et al. / Karimireddy et al.).

Usage inside a step function:
    q, scale = quantize(grad)
    # all-reduce q (int8) + scale (f32 per row) instead of the raw grad
    g_hat = dequantize(q, scale)
    residual = grad - g_hat       # carried to the next step per leaf
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-scaled symmetric int8: scale = max|g| per leading row."""
    gf = g.astype(jnp.float32)
    flat = gf.reshape(gf.shape[0], -1) if gf.ndim > 1 else gf.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(g.shape if g.ndim > 1 else (-1,)), scale.squeeze(-1)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, like_shape=None) -> jnp.ndarray:
    qf = q.astype(jnp.float32)
    if qf.ndim > 1:
        flat = qf.reshape(qf.shape[0], -1) * scale[:, None]
        return flat.reshape(q.shape)
    return qf * scale


def compress_tree(grads: Any, residuals: Any) -> Tuple[Any, Any, Any]:
    """Error-feedback compression over a gradient pytree.

    Returns (quantized payloads, scales, new residuals).  The caller
    transports (q, scale) over the slow axis and applies `decompress_tree`
    on the other side; residuals stay local.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize(corrected)
        g_hat = dequantize(q, s)
        return q, s, corrected - g_hat

    qs, ss, rs = [], [], []
    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = treedef.flatten_up_to(residuals)
    for g, r in zip(flat, rflat):
        q, s, nr = one(g, r)
        qs.append(q); ss.append(s); rs.append(nr)
    un = treedef.unflatten
    return un(qs), un(ss), un(rs)


def decompress_tree(qs: Any, ss: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda q, s: dequantize(q, s), qs, ss,
        is_leaf=lambda x: isinstance(x, jnp.ndarray) and x.dtype == jnp.int8)


def zero_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(grads: Any) -> float:
    """Wire-byte ratio of (int8 payload + f32 row scales) vs raw fp32."""
    leaves = jax.tree_util.tree_leaves(grads)
    numel = sum(x.size for x in leaves)
    q_bytes = numel  # int8
    s_bytes = sum((x.shape[0] if x.ndim > 1 else 1) * 4 for x in leaves)
    return (q_bytes + s_bytes) / max(1, numel * 4)
