"""gemma3-12b [hf:google/gemma-3 family; unverified]: 5:1 local:global, 128k.

Sub-quadratic: 5 of 6 layers use a 1024-token sliding window, so the arch is
eligible for the long_500k decode shape (global layers decode O(S) per token).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144, qk_norm=True, rope_theta=1e6,
    sliding_window=1024, local_global_ratio=5, sub_quadratic=True,
    tie_embeddings=True,
)
