"""Architecture configuration schema + the assigned input-shape suite."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm_hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # attention pattern
    sliding_window: Optional[int] = None
    local_global_ratio: Optional[int] = None   # N local layers per 1 global
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0                # deepseek/kimi: dense first block(s)
    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0                # zamba2: shared attn every N mamba blocks
    # xLSTM
    slstm_every: int = 0                       # 1 sLSTM per N blocks (rest mLSTM)
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 1500                    # whisper: 30s of 20ms frames
    # modality frontend stub
    frontend: Optional[str] = None             # "audio" | "patch" | None
    frontend_dim: int = 0                      # stub embedding feature dim
    num_patches: int = 0
    # MLP variant: "swiglu" (3 mats) or "gelu" (2 mats — starcoder2/whisper)
    mlp_variant: str = "swiglu"
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # serving
    decode_only: bool = False
    sub_quadratic: bool = False                # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer)."""
        d, hd = self.d_model, self.hd
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)
        mats = 2 if self.mlp_variant == "gelu" else 3
        dense_mlp = mats * d * self.d_ff if self.d_ff else 0
        per_layer = attn + dense_mlp
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            moe_mlp = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            router = d * self.n_experts
            moe_layers = self.n_layers - self.first_dense_layers
            total += self.first_dense_layers * (attn + dense_mlp)
            total += moe_layers * (attn + moe_mlp + router)
        elif self.family == "ssm_hybrid":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            ssm = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d
            total += self.n_layers * ssm
            if self.attn_every:
                total += attn + dense_mlp  # one shared transformer block
        elif self.family == "xlstm":
            total += self.n_layers * (4 * d * d + 2 * d * (2 * d))  # approx
        elif self.family == "encdec":
            total += (self.encoder_layers * per_layer
                      + self.n_layers * (per_layer + attn))
        else:
            total += self.n_layers * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = (d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd
                + self.n_heads * self.hd * d)
        active_mlp = (3 * d * self.moe_d_ff
                      * (self.experts_per_token + self.n_shared_experts))
        moe_layers = self.n_layers - self.first_dense_layers
        total = self.vocab * d
        total += self.first_dense_layers * (attn + 3 * d * self.d_ff)
        total += moe_layers * (attn + active_mlp + d * self.n_experts)
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // max(1, cfg.n_heads))),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=8 if cfg.n_experts else 0,
        n_shared_experts=min(1, cfg.n_shared_experts),
        experts_per_token=2 if cfg.experts_per_token else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        attn_every=2 if cfg.attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=32 if cfg.encoder_layers else 1500,
        sliding_window=64 if cfg.sliding_window else None,
        num_patches=4 if cfg.num_patches else 0,
        frontend_dim=64 if cfg.frontend_dim else 0,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        dtype="float32",
        param_dtype="float32",
    )
