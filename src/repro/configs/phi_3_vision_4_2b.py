"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct; hf-verified].

phi3-mini backbone + CLIP frontend STUB: input_specs() supplies precomputed
(B, 576, 1024) patch embeddings, projected and prepended to the sequence.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, rope_theta=1e4,
    frontend="patch", frontend_dim=1024, num_patches=576,
    tie_embeddings=False,
)
