"""Architecture registry: --arch <id> resolves here."""
from .base import ArchConfig, SHAPES, ShapeConfig, shape_by_name, smoke_config

from .qwen3_1_7b import CONFIG as _qwen3
from .starcoder2_15b import CONFIG as _sc15
from .gemma3_12b import CONFIG as _gemma3
from .starcoder2_3b import CONFIG as _sc3
from .whisper_base import CONFIG as _whisper
from .zamba2_2_7b import CONFIG as _zamba2
from .phi_3_vision_4_2b import CONFIG as _phi3v
from .deepseek_moe_16b import CONFIG as _dsmoe
from .kimi_k2_1t_a32b import CONFIG as _kimi
from .xlstm_350m import CONFIG as _xlstm

REGISTRY = {c.name: c for c in [
    _qwen3, _sc15, _gemma3, _sc3, _whisper, _zamba2, _phi3v, _dsmoe, _kimi,
    _xlstm,
]}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
