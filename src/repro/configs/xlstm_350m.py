"""xlstm-350m [arXiv:2405.04517; unverified]: sLSTM + mLSTM blocks.

24 blocks, every 4th an sLSTM (serial recurrence), rest mLSTM (parallel
chunked matrix-memory).  d_ff=0: blocks carry internal up/down projections.
Recurrent O(1)-state decode => eligible for long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304, slstm_every=4,
    sub_quadratic=True, tie_embeddings=True,
)
