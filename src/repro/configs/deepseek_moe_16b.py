"""deepseek-moe-16b [arXiv:2401.06066; hf-verified]: fine-grained MoE.

2 shared + 64 routed experts, top-6, expert d_ff=1408; first layer dense
(d_ff=10944) as in the paper.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    n_experts=64, n_shared_experts=2, experts_per_token=6, moe_d_ff=1408,
    first_dense_layers=1, tie_embeddings=True,
)
