"""whisper-base [arXiv:2212.04356; unverified]: enc-dec; conv frontend STUB.

input_specs() supplies precomputed (B, 1500, 80) frame embeddings; the model
projects them to d_model (the conv1d+mel pipeline is out of scope per the
assignment).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=51865, mlp_variant="gelu",
    frontend="audio", frontend_dim=80, encoder_seq=1500,
    tie_embeddings=True,
)
