"""starcoder2-3b [arXiv:2402.19173; hf-verified]: dense GQA + RoPE, GeLU MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152, rope_theta=1e5, mlp_variant="gelu",
    tie_embeddings=True,
)
