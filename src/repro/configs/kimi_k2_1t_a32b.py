"""kimi-k2-1t-a32b [arXiv:2501 Kimi K2; paper-table, unverified].

Trillion-parameter MoE: 384 routed experts top-8 + 1 shared, expert
d_ff=2048 (fine-grained), 61 layers at d_model=7168.  ~1.03T total params,
~32B active per token.  Requires full (pod x data x model) parameter
sharding — see EXPERIMENTS.md §Dry-run for the memory analysis.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=18432, vocab=163840,
    n_experts=384, n_shared_experts=1, experts_per_token=8, moe_d_ff=2048,
    first_dense_layers=1, tie_embeddings=True,
    # 1T params: bf16 master + bf16 optimizer state (6 B/param total) is the
    # only way 512 x 16 GiB chips hold the training state — see EXPERIMENTS.
    param_dtype="bfloat16",
)
