"""zamba2-2.7b [arXiv:2411.15242; hf-verified]: Mamba2 + shared attn blocks.

54 Mamba2 blocks; one parameter-shared GQA attention block applied every 6
blocks.  O(1)-state decode => eligible for long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="ssm_hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
    sub_quadratic=True, tie_embeddings=True,
)
