"""Async admission & micro-batching front-end primitives.

The batched executor (``repro.exec``) wants signature-coherent ``(B, …)``
buckets; live traffic arrives as single queries from many concurrent
callers.  This module is the adapter between the two: an
:class:`AdmissionQueue` accumulates submissions into per-key micro-batches
(the key is a :class:`~repro.exec.plan.ShapeSig` in the search front-end,
but the queue is generic) and hands a bucket back for execution when

- **tier flush** — the bucket reaches the configured power-of-two
  ``flush_tier`` (a full bucket pads to exactly its own size, zero waste), or
- **deadline flush** — the *oldest* queued submission's deadline budget
  expires (default 2 ms), bounding the tail latency a query can lose to
  waiting for batch-mates,

whichever comes first.  Flush causes are counted in
``EXEC_COUNTERS["tier_flushes"]`` / ``["deadline_flushes"]``.

Each submission returns a :class:`Ticket` — a minimal future: callers poll
``ticket.done`` / read ``ticket.value`` after the owning engine flushes.
Tickets also carry queue-wait telemetry (``wait_us``), which is exactly the
quantity the deadline budget bounds (total latency = wait + bucket
execution).

The queue itself does no execution and holds no device state; an engine
(e.g. ``serve.search.AsyncSearchEngine``) drives it: ``submit`` into it,
``take_due(now)`` out of it, execute, resolve tickets.  All methods are
lock-protected so many caller threads can submit concurrently; the clock is
injectable so tests can fire deadlines deterministically.

Concurrency contract (audited for the background-flusher runtime): the
internal lock is held only for bucket-dict bookkeeping — never across
ticket resolution or execution — so ``submit`` cannot block behind a flush.
Every ``take_*`` method removes whole buckets from the dict *atomically
under the lock*; a (ticket, item) pair therefore leaves the queue exactly
once, no matter how ``take_full`` / ``take_due`` / ``take_all`` interleave
across threads.  That single property is what makes a drain idempotent and
safe to run concurrently with a flusher's pump: the second taker simply
finds the bucket gone.  A submission that lands *after* a take has started
goes into a fresh bucket and is picked up by the next take — never lost,
never double-flushed.  (``Ticket`` resolution being single-shot is the
backstop: a logic bug that double-flushed would raise, not clobber.)

Audit note for the overlapped (dispatch/collect-split) flusher: ticket
resolution now happens at *collect* time, outside the engine's exec lock
and potentially on a different thread than the one that took the bucket.
That is safe against this queue precisely because of the contract above —
once a ``take_*`` pops a bucket, the queue holds no reference to its
tickets, so resolution order/thread is invisible here; and because
``next_deadline_in_us`` reports 0 for full tiers, the flusher's
deadline-sleep wake covers the tier-flush case without polling.  The only
queue-side requirement the overlap adds is that ``take_*`` stay atomic
whole-bucket pops (a half-taken bucket could dispatch twice), which the
single lock already guarantees.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

from ..core.engine import EXEC_COUNTERS

__all__ = ["Ticket", "AdmissionQueue"]


@dataclasses.dataclass
class Ticket:
    """Minimal future for one admitted request.

    ``submitted_at`` / ``deadline_us`` define the flush budget; after
    resolution ``value`` holds the engine's result, ``wait_us`` the time the
    request sat in the queue (0 for requests answered at submit time, e.g.
    result-cache hits), and ``done`` flips True.  Reading ``value`` before
    resolution raises.  A ticket whose bucket failed to execute resolves
    with the error instead: ``done`` is True, ``error`` holds the
    exception, and ``value`` re-raises it — callers polling ``done`` never
    hang on a failed bucket.

    Cross-thread contract: resolution is published through a
    ``threading.Event`` — the payload fields are written *before* the event
    is set, and the Event's internal lock gives the release/acquire pairing
    a bare bool would lack, so a caller thread that observes ``done`` (or
    returns from :meth:`wait`) is guaranteed to see the resolved value.
    Resolution is single-shot: a second ``resolve`` / ``resolve_error``
    raises instead of clobbering a result some caller may already have
    read (the failed-then-retried-bucket hazard).

    Tracing: the submitting engine may stamp ``span`` (the request's root
    span) and ``admission_span`` (the queue-wait child) plus ``obs``.
    The root span is closed inside :meth:`_record_wait` — i.e. exactly
    once, under the same single-shot guarantee as resolution itself, on
    every path (value, error, cache hit, host plan) — which is the
    "every submitted ticket yields exactly one closed root span"
    invariant the observability tests gate.
    """

    submitted_at: float
    deadline_us: float
    wait_us: float = 0.0
    error: Optional[BaseException] = None
    span: Any = dataclasses.field(default=None, repr=False, compare=False)
    admission_span: Any = dataclasses.field(
        default=None, repr=False, compare=False)
    obs: Any = dataclasses.field(default=None, repr=False, compare=False)
    _value: Any = None
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def done(self) -> bool:
        """True once resolved (value or error) — Event-backed, safe to poll
        from any thread."""
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until resolved (or ``timeout`` seconds); returns ``done``.
        The blocking complement of polling ``done`` for caller threads."""
        return self._done.wait(timeout)

    @property
    def value(self) -> Any:
        if not self._done.is_set():
            raise RuntimeError("ticket not resolved yet — flush/drain first")
        if self.error is not None:
            raise self.error
        return self._value

    def _record_wait(self, wait_us: float) -> None:
        """Per-ticket wait telemetry, stamped exactly once at resolution.

        ``queue_wait_us`` accumulates integer microseconds;
        ``deadline_violations`` counts waits that exceeded this ticket's
        own budget by more than the 0.5 us virtual-clock float epsilon
        (tickets with no budget — e.g. resolved-at-submit paths with
        ``deadline_us == 0`` — can't violate).  This is the raw material
        for the load harness's SLO-burn accounting.

        The three counters are one :meth:`ExecCounters.bump_many` — a
        concurrent ``EXEC_COUNTERS.snapshot()`` sees either none or all
        of this resolution (the tearing fix).  With ``obs`` stamped, the
        wait also lands in the typed ``queue_wait_us`` histogram and the
        request's root span closes here (exactly once per ticket).
        """
        violated = (self.deadline_us > 0
                    and wait_us > self.deadline_us + 0.5)
        EXEC_COUNTERS.bump_many({
            "tickets_resolved": 1,
            "queue_wait_us": int(wait_us),
            "deadline_violations": 1 if violated else 0,
        })
        if self.obs is not None:
            self.obs.queue_wait.observe(wait_us)
        if self.span is not None:
            self.span.end(wait_us=round(wait_us, 1),
                          deadline_violation=violated,
                          error=(type(self.error).__name__
                                 if self.error is not None else None))

    def resolve(self, value: Any, wait_us: float = 0.0) -> None:
        if self._done.is_set():
            raise RuntimeError("ticket already resolved — single-shot")
        self._value = value
        self.wait_us = wait_us
        self._record_wait(wait_us)
        self._done.set()  # publish AFTER the payload writes

    def resolve_error(self, exc: BaseException, wait_us: float = 0.0) -> None:
        if self._done.is_set():
            raise RuntimeError("ticket already resolved — single-shot")
        self.error = exc
        self.wait_us = wait_us
        self._record_wait(wait_us)
        self._done.set()  # publish AFTER the payload writes

    def deadline_at(self) -> float:
        """Absolute clock time at which this ticket forces a flush."""
        return self.submitted_at + self.deadline_us * 1e-6


class AdmissionQueue:
    """Deadline-aware per-key micro-batch accumulator (execution-free).

    Buckets are keyed by any hashable (the search engine uses ``ShapeSig``);
    each bucket remembers insertion order, and its binding deadline is the
    *earliest* entry deadline — normally the oldest entry's, unless a later
    submission carried a tighter per-query budget.  Thread-safe.
    """

    def __init__(self, flush_tier: int = 64, deadline_us: float = 2000.0,
                 clock: Callable[[], float] = time.perf_counter):
        assert flush_tier >= 1 and (flush_tier & (flush_tier - 1)) == 0, (
            "flush_tier must be a power of two (bucket pads to pow2 tiers)"
        )
        self.flush_tier = flush_tier
        self.deadline_us = float(deadline_us)
        self.clock = clock
        self._lock = threading.Lock()
        self._buckets: Dict[Hashable, List[Tuple[Ticket, Any]]] = {}

    def submit(self, key: Hashable, item: Any,
               deadline_us: Optional[float] = None,
               submitted_at: Optional[float] = None,
               span: Any = None, obs: Any = None) -> Ticket:
        """Queue ``item`` under ``key``; returns its unresolved Ticket.

        The per-submission ``deadline_us`` overrides the queue default.
        ``submitted_at`` (engine-clock seconds) back-stamps the ticket's
        arrival time — an open-loop load generator passes the *scheduled*
        arrival so queue waits (and the deadline budget) are measured from
        when the query should have arrived, not from when the submitter
        thread got scheduled; the coordinated-omission correction.
        Submission never flushes by itself — call :meth:`take_full` /
        :meth:`take_due` afterwards so the engine (which owns execution)
        controls when device work happens.

        ``span`` / ``obs`` stamp the request's root span and telemetry
        bundle onto the ticket *before* it becomes visible to any
        concurrent flush (an "admission" child span opens here and is
        ended by the flusher when the bucket is picked up).
        """
        ticket = Ticket(
            submitted_at=(self.clock() if submitted_at is None
                          else float(submitted_at)),
            deadline_us=self.deadline_us if deadline_us is None else float(deadline_us),
        )
        if span is not None:
            ticket.span = span
            ticket.admission_span = span.child("admission")
        if obs is not None:
            ticket.obs = obs
        with self._lock:
            self._buckets.setdefault(key, []).append((ticket, item))
        return ticket

    def take_full(self) -> List[Tuple[Hashable, List[Tuple[Ticket, Any]]]]:
        """Remove and return buckets that reached the full flush tier."""
        out = []
        with self._lock:
            for key in [k for k, b in self._buckets.items()
                        if len(b) >= self.flush_tier]:
                out.append((key, self._buckets.pop(key)))
                EXEC_COUNTERS["tier_flushes"] += 1
        return out

    @staticmethod
    def _bucket_deadline(bucket) -> float:
        """Earliest absolute deadline in a bucket.  Usually the oldest
        entry's, but a later submission with a tighter per-query budget
        (``submit(..., deadline_us=...)``) can be the binding one."""
        return min(t.deadline_at() for t, _ in bucket)

    def take_due(self, now: Optional[float] = None
                 ) -> List[Tuple[Hashable, List[Tuple[Ticket, Any]]]]:
        """Remove and return buckets whose earliest deadline has expired.

        Full-tier buckets are also taken (counted as tier flushes) — a
        caller that only ever calls ``take_due`` still flushes correctly.
        """
        now = self.clock() if now is None else now
        out = []
        with self._lock:
            for key in list(self._buckets):
                bucket = self._buckets[key]
                if len(bucket) >= self.flush_tier:
                    out.append((key, self._buckets.pop(key)))
                    EXEC_COUNTERS["tier_flushes"] += 1
                elif bucket and self._bucket_deadline(bucket) <= now:
                    out.append((key, self._buckets.pop(key)))
                    EXEC_COUNTERS["deadline_flushes"] += 1
        return out

    def take_all(self) -> List[Tuple[Hashable, List[Tuple[Ticket, Any]]]]:
        """Remove and return every pending bucket (drain path).

        Counted as deadline flushes for partial buckets and tier flushes
        for full ones — drain is "the deadline is now".
        """
        out = []
        with self._lock:
            for key in list(self._buckets):
                bucket = self._buckets.pop(key)
                cause = ("tier_flushes" if len(bucket) >= self.flush_tier
                         else "deadline_flushes")
                EXEC_COUNTERS[cause] += 1
                out.append((key, bucket))
        return out

    def pending(self) -> int:
        """Number of queued, not-yet-flushed submissions."""
        with self._lock:
            return sum(len(b) for b in self._buckets.values())

    def next_deadline_in_us(self, now: Optional[float] = None) -> Optional[float]:
        """Microseconds until the next flush is due (<= 0 = overdue); None
        when nothing is queued.  Lets a serving loop sleep exactly as long
        as the latency budget allows instead of busy-polling.

        A bucket that already reached ``flush_tier`` is ready NOW — the
        hint is 0 regardless of any deadline, so a sleep-based pump loop
        never idles on a full, flushable bucket (deadlines alone would let
        it sleep a whole budget with work queued).
        """
        now = self.clock() if now is None else now
        with self._lock:
            if not self._buckets:
                return None
            if any(len(b) >= self.flush_tier for b in self._buckets.values()):
                return 0.0
            soonest = min(self._bucket_deadline(b)
                          for b in self._buckets.values() if b)
            return (soonest - now) * 1e6
