"""Open-loop load generation for the async serving stack, with SLO-burn
reporting.

Closed-loop benchmarks (submit-as-fast-as-possible, wait, repeat) measure
*throughput* but hide *queueing*: a server that is too slow simply slows
its own offered load down, so tail waits look flat no matter how
overloaded the system is.  Production traffic is open-loop — arrivals are
scheduled by the outside world and do not care whether the server keeps
up.  This module generates that traffic and drives
:class:`~repro.serve.search.AsyncSearchEngine` with it, reporting **SLO
burn**: the fraction of completed queries whose queue wait exceeded the
deadline budget, alongside p50/p99 waits and a windowed burn-rate curve
over the run.

Three pieces:

- **Traffic synthesis** — :class:`TrafficShape` (base arrival rate, a
  diurnal sinusoid, Poisson burst clumps) + :class:`QueryMix` (the paper's
  keyword-count distribution, Zipf-skewed term popularity over the index
  vocabulary, optional finite distinct pool so exact repeats occur) →
  :func:`build_schedule` → an :class:`ArrivalSchedule` of
  ``(arrival_time_s, terms)`` pairs.  Fully deterministic from the seed:
  nonhomogeneous-Poisson arrivals are drawn by Lewis–Shedler thinning
  against the diurnal rate envelope.

- **Virtual-time driver** (:func:`run_virtual`) — a deterministic, CI-safe
  discrete-event simulation.  The engine's clock and admission queue are
  rebound to a :class:`VirtualClock`; the driver alternates between
  advancing to the next scheduled arrival (submitting with
  ``arrival_at`` back-stamping) and advancing to the next flush event,
  where it pumps the engine exactly as the background flusher's
  sleep-until-deadline loop would.  Execution cost is charged to the
  virtual clock through a calibrated :class:`CostModel` and a
  single-server ``busy_until`` horizon — without that charge a virtual
  server has infinite capacity and burn is identically zero; with it,
  offered load beyond the calibrated capacity queues and burns exactly as
  a real single-executor flusher does.  The *policy* (tier/deadline
  flushing, single flush owner) is what runs; the flusher *thread* is
  deliberately not started — determinism requires one owner of time, and
  the thread itself is exercised by the wall-clock mode below and the
  loadgen soak test.  Bucket executions are still real jit work, so
  results (and the bit-identity check against the host oracle) are real.

- **Wall-clock driver** (:func:`run_wallclock`) — the same schedule
  replayed in real time by N submitter threads against the *real*
  background flusher.  Each submitter sleeps until an arrival's scheduled
  wall time and submits with ``arrival_at`` stamped to that schedule, so
  a submitter thread that got scheduled late still charges its lateness
  to the measured wait (coordinated-omission correction).  The report
  carries a thread-hygiene check: every thread the run started is gone
  after ``stop()``.

Burn definition (shared by both modes): a completed query burns when its
queue wait exceeds its deadline budget by more than ``BURN_EPS_US``
(0.5 us — virtual-clock float error, never a scheduling miss; the same
epsilon the admission benchmark uses).  A deadline-flushed bucket's oldest
query waits *exactly* its budget by construction of the policy, so burn
measures genuine overload (flushes delayed past deadline by a busy
server), not the policy's own budget use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.engine import EXEC_COUNTERS
from .admission import AdmissionQueue, Ticket
from .search import AsyncSearchEngine

__all__ = [
    "BURN_EPS_US",
    "TrafficShape",
    "QueryMix",
    "ArrivalSchedule",
    "build_schedule",
    "VirtualClock",
    "attach_virtual_clock",
    "attach_wall_clock",
    "CostModel",
    "calibrate_cost",
    "calibrate_from_profile",
    "LoadReport",
    "run_virtual",
    "run_wallclock",
]

# virtual-time float epsilon: a wait this close to the budget is the
# deadline-flush policy doing its job, not a violation
BURN_EPS_US = 0.5


# ----------------------------------------------------------------------
# traffic synthesis
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficShape:
    """Open-loop arrival process: diurnal base rate + Poisson bursts.

    ``base_qps`` is the mean arrival rate; the instantaneous rate follows
    a sinusoid with relative amplitude ``diurnal_amplitude`` and period
    ``diurnal_period_s`` (a compressed day — benchmarks use a few seconds
    per "day").  On top of the smooth process, burst events arrive as a
    Poisson process at ``burst_rate_hz``; each event injects
    ``~Poisson(burst_size)`` extra queries spread uniformly over
    ``burst_width_s`` — the thundering-herd clumps that deadline-flush
    policies must absorb.
    """

    base_qps: float = 500.0
    duration_s: float = 4.0
    diurnal_amplitude: float = 0.5     # 0 disables; rate swings ±50%
    diurnal_period_s: float = 2.0
    burst_rate_hz: float = 1.0         # burst events per second
    burst_size: float = 20.0           # mean queries per burst event
    burst_width_s: float = 0.02        # clump spread

    def rate_at(self, t: float) -> float:
        """Instantaneous smooth arrival rate (queries/s) at time ``t``."""
        phase = 2.0 * np.pi * t / max(self.diurnal_period_s, 1e-9)
        return max(
            0.0, self.base_qps * (1.0 + self.diurnal_amplitude * np.sin(phase))
        )

    def scaled(self, factor: float) -> "TrafficShape":
        """The same shape at ``factor`` times the base (and burst) rate —
        how a benchmark sweeps offered load against a fixed capacity."""
        return dataclasses.replace(
            self,
            base_qps=self.base_qps * factor,
            burst_rate_hz=self.burst_rate_hz * factor,
        )


@dataclasses.dataclass(frozen=True)
class QueryMix:
    """What each arrival asks: k-term mix and term popularity skew.

    ``kw_dist`` is the paper's keyword-count distribution (68% 2-word,
    23% 3-word, 9% 4-word by default); term ids are drawn Pareto-skewed
    toward the low (frequent-under-Zipf) ids with tail index
    ``pareto_a`` and spread ``pareto_scale``.  A finite ``distinct_pool``
    first materializes that many distinct conjunctions and then draws
    arrivals Zipf-style from the pool — the live-traffic regime where
    exact repeats occur and the result cache pays.
    """

    kw_dist: Tuple[Tuple[int, float], ...] = ((2, 0.68), (3, 0.23), (4, 0.09))
    pareto_a: float = 1.0
    pareto_scale: float = 10.0
    distinct_pool: Optional[int] = None

    def _draw(self, terms: np.ndarray, rng: np.random.Generator) -> List[int]:
        ks, ps = zip(*self.kw_dist)
        k = int(rng.choice(ks, p=np.asarray(ps) / sum(ps)))
        idx = np.minimum(
            len(terms) - 1,
            (rng.pareto(self.pareto_a, size=k) * self.pareto_scale).astype(int),
        )
        return sorted(set(terms[idx].tolist())) or [int(terms[0])]

    def sample(self, index_terms: Sequence[int], n: int,
               rng: np.random.Generator) -> List[List[int]]:
        """Draw ``n`` queries over ``index_terms`` (deterministic in rng)."""
        terms = np.asarray(sorted(index_terms))
        if self.distinct_pool is None:
            return [self._draw(terms, rng) for _ in range(n)]
        pool = [self._draw(terms, rng) for _ in range(self.distinct_pool)]
        return _zipf_from_pool(pool, n, rng)


def _zipf_from_pool(pool: Sequence[Sequence[int]], n: int,
                    rng: np.random.Generator) -> List[List[int]]:
    """Draw ``n`` queries Zipf-by-rank from a finite pool of conjunctions
    (pool order = popularity rank)."""
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    p = (1.0 / ranks) / (1.0 / ranks).sum()
    return [list(pool[i]) for i in rng.choice(len(pool), size=n, p=p)]


@dataclasses.dataclass(frozen=True)
class ArrivalSchedule:
    """A realized open-loop run: sorted arrival times + the query per slot."""

    times: np.ndarray            # (N,) float seconds, sorted ascending
    queries: Tuple[Tuple[int, ...], ...]
    duration_s: float

    def __len__(self) -> int:
        return len(self.times)

    @property
    def offered_qps(self) -> float:
        """Mean offered load over the whole run (arrivals / duration) —
        the open-loop rate the drivers must absorb, independent of how
        fast the engine serves."""
        return len(self.times) / max(self.duration_s, 1e-12)


def build_schedule(shape: TrafficShape, index_terms: Sequence[int],
                   mix: QueryMix = QueryMix(), seed: int = 0,
                   pool: Optional[Sequence[Sequence[int]]] = None
                   ) -> ArrivalSchedule:
    """Materialize one deterministic open-loop schedule.

    Smooth arrivals come from Lewis–Shedler thinning: candidate arrivals
    are drawn from a homogeneous Poisson process at the rate envelope
    ``base_qps * (1 + |amplitude|)`` and kept with probability
    ``rate_at(t) / envelope`` — an exact sampler for the nonhomogeneous
    process, and deterministic given the seed.  Burst clumps are laid on
    top, then everything is merged, sorted, and truncated to the duration.

    An explicit ``pool`` pins the query universe: arrivals draw Zipf-by-
    rank from it instead of ``mix`` drawing its own — benchmarks pass one
    pool to every schedule so compile warming (and the oracle memo) covers
    every run from one place.
    """
    rng = np.random.default_rng(seed)
    envelope = shape.base_qps * (1.0 + abs(shape.diurnal_amplitude))
    arrivals: List[float] = []
    if envelope > 0:
        t = 0.0
        while True:
            t += rng.exponential(1.0 / envelope)
            if t >= shape.duration_s:
                break
            if rng.uniform() * envelope <= shape.rate_at(t):
                arrivals.append(t)
    n_bursts = rng.poisson(shape.burst_rate_hz * shape.duration_s)
    for _ in range(n_bursts):
        t_burst = rng.uniform(0.0, shape.duration_s)
        for _ in range(rng.poisson(shape.burst_size)):
            arrivals.append(t_burst + rng.uniform(0.0, shape.burst_width_s))
    times = np.sort(np.asarray(
        [a for a in arrivals if a < shape.duration_s], dtype=np.float64))
    if pool is not None:
        queries = _zipf_from_pool(pool, len(times), rng)
    else:
        queries = mix.sample(index_terms, len(times), rng)
    return ArrivalSchedule(
        times=times,
        queries=tuple(tuple(q) for q in queries),
        duration_s=shape.duration_s,
    )


# ----------------------------------------------------------------------
# virtual time
# ----------------------------------------------------------------------


class VirtualClock:
    """Virtual clock (seconds); only the driver advances it."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def attach_virtual_clock(eng: AsyncSearchEngine,
                         clock: Optional[VirtualClock] = None) -> VirtualClock:
    """Rebind ``eng`` onto a virtual clock (fresh admission queue, same
    flush parameters).  The engine must be idle — no running flusher, no
    queued submissions, no in-flight buckets — because pending tickets
    would be orphaned by the queue swap."""
    assert not eng.running, "stop the background flusher before rebinding"
    assert eng.pending() == 0 and eng._inflight_count() == 0, (
        "cannot swap the admission queue with work in flight"
    )
    clock = clock or VirtualClock()
    eng.clock = clock
    eng.admission = AdmissionQueue(flush_tier=eng.admission.flush_tier,
                                   deadline_us=eng.admission.deadline_us,
                                   clock=clock)
    return clock


def attach_wall_clock(eng: AsyncSearchEngine) -> None:
    """Undo :func:`attach_virtual_clock`: back onto ``time.perf_counter``
    (fresh admission queue, same flush parameters, same idle requirement).
    """
    assert not eng.running, "stop the background flusher before rebinding"
    assert eng.pending() == 0 and eng._inflight_count() == 0, (
        "cannot swap the admission queue with work in flight"
    )
    eng.clock = time.perf_counter
    eng.admission = AdmissionQueue(flush_tier=eng.admission.flush_tier,
                                   deadline_us=eng.admission.deadline_us,
                                   clock=time.perf_counter)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Affine service cost charged to the virtual clock per flush.

    ``per_bucket_us`` is the fixed dispatch+collect overhead of one bucket
    execution; ``per_query_us`` the marginal cost per batched query.  The
    single-server capacity at a given flush tier follows directly — it is
    the rate at which back-to-back full-tier buckets drain.
    """

    per_bucket_us: float
    per_query_us: float

    def flush_cost_us(self, n_buckets: int, n_queries: int) -> float:
        """Modeled wall time of one flush: fixed dispatch cost per bucket
        plus marginal cost per batched query.  ``run_virtual`` charges
        this to the single server's ``busy_until`` horizon per pump."""
        return n_buckets * self.per_bucket_us + n_queries * self.per_query_us

    def capacity_qps(self, tier: int) -> float:
        """Sustainable queries/s when every flush is a full ``tier``."""
        return tier / (self.flush_cost_us(1, tier) * 1e-6)


def calibrate_cost(eng, queries: Sequence[Sequence[int]],
                   tier: Optional[int] = None, passes: int = 3) -> CostModel:
    """Fit the affine cost model from real warmed bucket executions.

    Measures the median closed-loop wall of a 1-query bucket and a
    ``tier``-query bucket (``queries`` must share one shape signature so
    each batch is a single bucket) and solves the two-point affine fit.
    Run *before* rebinding the engine to a virtual clock, on a warmed
    engine — the fit should capture steady-state execution, not compiles.
    """
    tier = tier or eng.admission.flush_tier
    qs = [list(q) for q in queries]
    assert len(qs) >= tier, "need at least `tier` same-signature queries"

    def wall_us(batch) -> float:
        eng.cache.clear()
        t0 = time.perf_counter()
        eng.query_batch(batch)
        return (time.perf_counter() - t0) * 1e6

    w1 = float(np.median([wall_us([qs[0]]) for _ in range(passes)]))
    wt = float(np.median([wall_us(qs[:tier]) for _ in range(passes)]))
    per_query = max(0.0, (wt - w1) / max(1, tier - 1))
    per_bucket = max(1.0, w1 - per_query)
    return CostModel(per_bucket_us=per_bucket, per_query_us=per_query)


def calibrate_from_profile(profile) -> Optional[CostModel]:
    """Fit a :class:`CostModel` from production execution profiles.

    ``profile`` is an ``obs.profile.ProfileStore`` (anything with
    ``fit_cost()``); its samples come from *live* collected buckets, so
    unlike :func:`calibrate_cost` no synthetic probe traffic is needed —
    this is the ROADMAP calibration loop closed: serve → profile →
    refit → re-run the virtual-clock harness with the refreshed model.
    Returns ``None`` while the profile can't identify both coefficients
    (fewer than two distinct batch sizes observed).
    """
    fit = profile.fit_cost()
    if fit is None:
        return None
    per_bucket, per_query = fit
    return CostModel(per_bucket_us=max(1.0, per_bucket),
                     per_query_us=per_query)


# ----------------------------------------------------------------------
# reports
# ----------------------------------------------------------------------


@dataclasses.dataclass
class LoadReport:
    """Outcome of one open-loop run, centered on SLO burn.

    ``burn_rate`` is burned/completed over the whole run; ``burn_curve``
    is the same fraction per arrival-time window (the shape of an
    overload: a diurnal peak burns in its window, a steady overload burns
    everywhere).  Waits are reported for all completed queries and for the
    device-queued subset (cache hits and host paths are ~0-wait and would
    flatter the percentiles).  ``thread_leak`` is the wall-clock driver's
    hygiene check (always 0 in virtual mode).
    """

    mode: str
    deadline_us: float
    arrivals: int
    completed: int
    errors: int
    burned: int
    burn_rate: float
    p50_wait_us: float
    p99_wait_us: float
    p99_e2e_us: float
    queued_queries: int
    p50_queued_wait_us: float
    p99_queued_wait_us: float
    duration_s: float
    offered_qps: float
    served_qps: float
    burn_curve: List[Dict]
    thread_leak: int
    counters: Dict[str, int]

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def _budget_us(ticket: Ticket, default_us: float) -> float:
    """A ticket's burn budget: its own deadline when it queued, the run's
    deadline for resolved-at-submit paths (whose ``deadline_us`` is 0)."""
    return ticket.deadline_us if ticket.deadline_us > 0 else default_us


def _make_report(mode: str, entries: List[Tuple[float, Ticket]],
                 deadline_us: float, duration_s: float,
                 windows: int = 10, thread_leak: int = 0) -> LoadReport:
    done = [(t_arr, t) for t_arr, t in entries if t.done]
    ok = [(t_arr, t) for t_arr, t in done if t.error is None]
    errors = len(done) - len(ok)
    waits = np.asarray([t.wait_us for _, t in ok], dtype=np.float64)
    burned_flags = [t.wait_us > _budget_us(t, deadline_us) + BURN_EPS_US
                    for _, t in ok]
    burned = int(sum(burned_flags))
    e2e = np.asarray([t.wait_us + t.value.latency_us for _, t in ok])
    queued = np.asarray([t.wait_us for _, t in ok
                         if t.value.stats.get("batch_size")
                         and not t.value.stats.get("cached")])

    horizon = max(duration_s, 1e-9)
    edges = np.linspace(0.0, horizon, windows + 1)
    curve = []
    for w in range(windows):
        lo, hi = edges[w], edges[w + 1]
        in_w = [(b, t_arr) for (t_arr, _), b in zip(ok, burned_flags)
                if lo <= t_arr < hi or (w == windows - 1 and t_arr >= hi)]
        n_w = len(in_w)
        b_w = sum(b for b, _ in in_w)
        curve.append({
            "t0_s": float(lo), "t1_s": float(hi),
            "completed": n_w, "burned": int(b_w),
            "burn_rate": (b_w / n_w) if n_w else 0.0,
        })

    def pct(arr, q):
        return float(np.percentile(arr, q)) if len(arr) else 0.0

    return LoadReport(
        mode=mode,
        deadline_us=deadline_us,
        arrivals=len(entries),
        completed=len(ok),
        errors=errors,
        burned=burned,
        burn_rate=burned / max(1, len(ok)),
        p50_wait_us=pct(waits, 50),
        p99_wait_us=pct(waits, 99),
        p99_e2e_us=pct(e2e, 99),
        queued_queries=int(len(queued)),
        p50_queued_wait_us=pct(queued, 50),
        p99_queued_wait_us=pct(queued, 99),
        duration_s=duration_s,
        offered_qps=len(entries) / max(duration_s, 1e-9),
        served_qps=len(ok) / max(duration_s, 1e-9),
        burn_curve=curve,
        thread_leak=thread_leak,
        counters={k: EXEC_COUNTERS[k] for k in (
            "inflight_dispatches", "inflight_collects",
            "tier_flushes", "deadline_flushes",
            "tickets_resolved", "deadline_violations",
            "rerun_calls", "batch_traces",
        )},
    )


# ----------------------------------------------------------------------
# drivers
# ----------------------------------------------------------------------


def run_virtual(eng: AsyncSearchEngine, schedule: ArrivalSchedule,
                cost: CostModel, windows: int = 10,
                ) -> Tuple[LoadReport, List[Tuple[float, Ticket]]]:
    """Deterministic discrete-event replay of ``schedule`` on ``eng``.

    The driver owns time: it repeatedly picks the earlier of (a) the next
    scheduled arrival and (b) the next *effective* flush — the admission
    queue's next deadline hint (0 for full tiers) pushed back to the
    single server's ``busy_until`` horizon — advances the virtual clock
    there, and either submits (with ``arrival_at`` back-stamping) or
    pumps.  Each pump's cost is charged to ``busy_until`` through the
    calibrated model, so offered load beyond capacity queues up and waits
    grow exactly as a serial flusher's would.  Ticket waits are therefore
    deterministic functions of the schedule, the policy, and the cost
    model; bucket executions still run for real, so the results (and any
    oracle comparison) are real.  Returns ``(report, entries)`` where
    ``entries`` is the ``(arrival_s, ticket)`` list for identity checks.
    """
    assert not eng.running, "virtual mode owns flush timing; stop the flusher"
    clk = attach_virtual_clock(eng)
    inline_before = eng.inline_tier_flush
    eng.inline_tier_flush = False  # the driver is the only flush owner
    EXEC_COUNTERS.reset()
    busy_until = 0.0
    entries: List[Tuple[float, Ticket]] = []
    i, n = 0, len(schedule)
    try:
        while i < n or eng.pending():
            nd = eng.admission.next_deadline_in_us()
            t_flush = (None if nd is None
                       else max(clk.t + max(0.0, nd) * 1e-6, busy_until))
            t_arr = float(schedule.times[i]) if i < n else None
            if t_flush is not None and (t_arr is None or t_flush <= t_arr):
                clk.t = max(clk.t, t_flush)
                before = eng.pending()
                n_buckets = eng.pump()
                n_queries = before - eng.pending()
                if n_buckets:
                    busy_until = max(busy_until, clk.t) + (
                        cost.flush_cost_us(n_buckets, n_queries) * 1e-6)
            else:
                clk.t = max(clk.t, t_arr)
                ticket = eng.submit(list(schedule.queries[i]),
                                    arrival_at=t_arr)
                entries.append((t_arr, ticket))
                i += 1
    finally:
        eng.inline_tier_flush = inline_before
    assert eng.pending() == 0 and all(t.done for _, t in entries)
    duration = max(clk.t, schedule.duration_s)
    report = _make_report("virtual", entries, eng.admission.deadline_us,
                          duration, windows=windows)
    return report, entries


def run_wallclock(eng: AsyncSearchEngine, schedule: ArrivalSchedule,
                  submitters: int = 2, windows: int = 10,
                  timeout_s: float = 120.0,
                  ) -> Tuple[LoadReport, List[Tuple[float, Ticket]]]:
    """Replay ``schedule`` in real time against the real background flusher.

    ``submitters`` threads split the schedule round-robin; each sleeps
    until an arrival's scheduled wall time and submits with ``arrival_at``
    stamped to the schedule, so late thread wakeups charge the measured
    wait rather than silently stretching the run (open-loop discipline).
    The engine's flusher is started and stopped here; the report's
    ``thread_leak`` counts threads that survived the run (submitters and
    flusher must all be gone).  Requires the engine's default wall clock.
    """
    assert not eng.running, "run_wallclock owns the flusher lifecycle"
    assert eng.clock is time.perf_counter, (
        "wall-clock mode needs the engine on time.perf_counter"
    )
    EXEC_COUNTERS.reset()
    threads_before = set(threading.enumerate())
    tickets: List[Optional[Ticket]] = [None] * len(schedule)
    eng.start()
    t0 = time.perf_counter() + 0.05  # small lead so slot 0 isn't late

    def submit_slice(offset: int) -> None:
        for j in range(offset, len(schedule), submitters):
            t_sched = t0 + float(schedule.times[j])
            delay = t_sched - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            tickets[j] = eng.submit(list(schedule.queries[j]),
                                    arrival_at=t_sched)

    workers = [threading.Thread(target=submit_slice, args=(k,),
                                name=f"loadgen-submit-{k}", daemon=True)
               for k in range(submitters)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    for t in tickets:
        assert t is not None
        if not t.wait(timeout=timeout_s):
            raise RuntimeError("ticket unresolved past timeout — flusher hung?")
    duration = time.perf_counter() - t0
    eng.stop()
    leaked = [th for th in threading.enumerate()
              if th not in threads_before and th.is_alive()]
    entries = [(float(schedule.times[j]), tickets[j])
               for j in range(len(schedule))]
    report = _make_report("wallclock", entries, eng.admission.deadline_us,
                          duration, windows=windows,
                          thread_leak=len(leaked))
    return report, entries
