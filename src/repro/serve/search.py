"""Conjunctive-query search serving — the paper's own application.

Builds the pre-processed index (one PrefixIndex per term posting list) and
serves batched k-word AND-queries through the device engine.  Algorithm
selection follows the paper's online policy (Section 3.4): HashBin when
the size ratio is extreme, RanGroupScan otherwise; both run off the same
pre-processed structures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.baselines import merge
from ..core.engine import BatchedEngine, DeviceSet, intersect_device
from ..core.hashing import default_permutation, random_hash_family
from ..core.intersect import hashbin, rangroupscan
from ..core.partition import preprocess_prefix


@dataclasses.dataclass
class QueryResult:
    doc_ids: np.ndarray
    latency_us: float
    algorithm: str
    stats: Dict


class SearchEngine:
    """In-memory conjunctive search over an inverted index."""

    def __init__(self, postings: Dict[int, np.ndarray], w: int = 256,
                 m: int = 2, seed: int = 0, use_device: bool = False,
                 hashbin_ratio: float = 100.0):
        self.family = random_hash_family(m, w, seed=seed)
        self.perm = default_permutation(seed)
        self.w, self.m = w, m
        self.hashbin_ratio = hashbin_ratio
        self.use_device = use_device
        t0 = time.perf_counter()
        self.index = {
            t: preprocess_prefix(p, w=w, m=m, family=self.family,
                                 perm=self.perm)
            for t, p in postings.items() if len(p)
        }
        self.build_s = time.perf_counter() - t0
        self.device = BatchedEngine(use_pallas="auto") if use_device else None
        if self.device:
            for t, idx in self.index.items():
                self.device.add(str(t), idx)

    def query(self, terms: Sequence[int]) -> QueryResult:
        idxs = [self.index[t] for t in terms if t in self.index]
        if len(idxs) < len(terms):
            return QueryResult(np.empty(0, np.uint32), 0.0, "empty", {})
        idxs.sort(key=lambda i: i.n)
        t0 = time.perf_counter()
        if len(idxs) == 2 and idxs[-1].n / max(1, idxs[0].n) > self.hashbin_ratio:
            res, stats = hashbin(idxs[0], idxs[1])
            algo = "hashbin"
        elif self.device is not None:
            res, stats = self.device.query([str(t) for t in terms])
            algo = "rangroupscan/device"
        else:
            res, stats = rangroupscan(idxs)
            algo = "rangroupscan"
        dt = (time.perf_counter() - t0) * 1e6
        return QueryResult(res, dt, algo, stats if isinstance(stats, dict) else stats.__dict__)

    def query_batch(self, queries: Sequence[Sequence[int]]) -> List[QueryResult]:
        return [self.query(q) for q in queries]


def zipf_query_log(index_terms: Sequence[int], n_queries: int = 1000,
                   seed: int = 1, kw_dist=((2, 0.68), (3, 0.23), (4, 0.09))
                   ) -> List[List[int]]:
    """Synthetic query log with the paper's keyword-count distribution
    (68% 2-word, 23% 3-word, ...) and Zipf-skewed term popularity."""
    rng = np.random.default_rng(seed)
    terms = np.asarray(sorted(index_terms))
    ks, ps = zip(*kw_dist)
    out = []
    for _ in range(n_queries):
        k = rng.choice(ks, p=np.asarray(ps) / sum(ps))
        # skewed term choice: favor low term-ids (frequent under Zipf corpus)
        idx = np.minimum(len(terms) - 1,
                         (rng.pareto(1.0, size=k) * 10).astype(int))
        out.append(sorted(set(terms[idx].tolist())) or [int(terms[0])])
    return out
